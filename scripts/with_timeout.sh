#!/usr/bin/env bash
# with_timeout.sh SECONDS CMD [ARGS...]
#
# Run CMD under a hard wall-clock timeout.  Used by the `dist-tests`
# CI job to run each distributed integration test individually: a
# hung reactor or a deadlocked node then fails that one test fast
# (exit 124) instead of stalling the whole pipeline until the job
# timeout.  SIGTERM first, SIGKILL 15 s later if the process ignores
# it.
set -u

if [ "$#" -lt 2 ]; then
    echo "usage: $0 SECONDS CMD [ARGS...]" >&2
    exit 2
fi

secs="$1"
shift

timeout --kill-after=15 "$secs" "$@"
rc=$?
if [ "$rc" -eq 124 ]; then
    echo "with_timeout: '$*' exceeded ${secs}s and was killed" >&2
fi
exit "$rc"
