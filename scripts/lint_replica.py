#!/usr/bin/env python3
"""Reference replica of rust/src/lint's source scanner.

Used once while authoring PR 10 to inventory violations and generate
scripts/lint_baseline.txt; the binding implementation is the Rust one
(`cargo run --bin pem_lint`).  Kept in-tree so a future session can
cross-check the two scanners against each other.
"""
import os, re, sys, bisect, json

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "rust", "src")

def mask(src: bytes):
    """comments -> spaces, string contents -> spaces (quotes kept),
    raw strings fully masked, char literals masked; newlines kept.
    Returns (masked bytearray, {quote_offset: literal_text})."""
    out = bytearray(src)
    lits = {}
    i, n = 0, len(src)
    def blank(a, b):
        for k in range(a, b):
            if out[k] != 0x0A:
                out[k] = 0x20
    def is_ident(c):
        return (0x30 <= c <= 0x39) or (0x41 <= c <= 0x5A) or (0x61 <= c <= 0x7A) or c == 0x5F
    while i < n:
        c = src[i]
        if c == 0x2F and i + 1 < n and src[i+1] == 0x2F:  # //
            j = i
            while j < n and src[j] != 0x0A:
                j += 1
            blank(i, j); i = j
        elif c == 0x2F and i + 1 < n and src[i+1] == 0x2A:  # /*
            depth, j = 1, i + 2
            while j < n and depth > 0:
                if src[j] == 0x2F and j + 1 < n and src[j+1] == 0x2A:
                    depth += 1; j += 2
                elif src[j] == 0x2A and j + 1 < n and src[j+1] == 0x2F:
                    depth -= 1; j += 2
                else:
                    j += 1
            blank(i, j); i = j
        elif c == 0x22:  # "
            j = i + 1
            while j < n and src[j] != 0x22:
                if src[j] == 0x5C:
                    j += 2
                else:
                    j += 1
            lits[i] = src[i+1:j].decode("utf-8", "replace")
            blank(i + 1, min(j, n))  # keep both quotes
            i = min(j + 1, n)
        elif c in (0x72, 0x62):  # r / b : raw or byte string?
            prev = src[i-1] if i > 0 else 0
            j = i + 1
            if c == 0x62 and j < n and src[j] == 0x72:
                j += 1
            hashes = 0
            while j < n and src[j] == 0x23:
                hashes += 1; j += 1
            if (not is_ident(prev)) and src[i] in (0x72, 0x62) and j < n and src[j] == 0x22 and (c == 0x72 or (i+1 < n and src[i+1] == 0x72)):
                # raw string r"..." / r#"..."# / br"..."
                k = j + 1
                close = b'"' + b'#' * hashes
                while k < n and src[k:k+len(close)] != close:
                    k += 1
                k = min(k + len(close), n)
                blank(i, k); i = k
            elif c == 0x62 and i + 1 < n and src[i+1] == 0x27 and not is_ident(prev):
                # byte char b'x'
                j = i + 2
                if j < n and src[j] == 0x5C:
                    j += 2
                while j < n and src[j] != 0x27:
                    j += 1
                blank(i, min(j+1, n)); i = min(j + 1, n)
            else:
                i += 1
        elif c == 0x27:  # ' : char literal or lifetime
            if i + 1 < n and src[i+1] == 0x5C:
                j = i + 2 + 1
                while j < n and src[j] != 0x27:
                    j += 1
                blank(i, min(j+1, n)); i = min(j + 1, n)
            else:
                # closing quote within the next 4 bytes => char literal
                j = i + 1
                limit = min(i + 6, n)
                k = i + 2
                found = -1
                while k < limit:
                    if src[k] == 0x27:
                        found = k; break
                    k += 1
                if found > 0 and found > i + 1:
                    blank(i, found + 1); i = found + 1
                else:
                    i += 1  # lifetime
        else:
            i += 1
    return out, lits

def cfg_test_mask(masked: bytearray):
    src = bytes(masked)
    n = len(src)
    i = 0
    def skip_ws(j):
        while j < n and src[j] in b" \t\r\n":
            j += 1
        return j
    def expect(j, tok: bytes):
        j = skip_ws(j)
        if src[j:j+len(tok)] == tok:
            return j + len(tok)
        return -1
    def blank(a, b):
        for k in range(a, b):
            if masked[k] != 0x0A:
                masked[k] = 0x20
    while i < n:
        if src[i] != 0x23:  # '#'
            i += 1; continue
        j = expect(i + 1, b"[")
        if j < 0: i += 1; continue
        j = expect(j, b"cfg")
        if j < 0: i += 1; continue
        j = expect(j, b"(")
        if j < 0: i += 1; continue
        j = expect(j, b"test")
        if j < 0: i += 1; continue
        j = expect(j, b")")
        if j < 0: i += 1; continue
        j = expect(j, b"]")
        if j < 0: i += 1; continue
        # attribute matched: [i, j). skip further attributes
        k = skip_ws(j)
        while k < n and src[k] == 0x23:
            k2 = skip_ws(k + 1)
            if k2 < n and src[k2] == 0x5B:  # [
                depth = 1; k2 += 1
                while k2 < n and depth > 0:
                    if src[k2] == 0x5B: depth += 1
                    elif src[k2] == 0x5D: depth -= 1
                    k2 += 1
                k = skip_ws(k2)
            else:
                break
        # scan to first '{' or ';'
        while k < n and src[k] not in b"{;":
            k += 1
        if k < n and src[k] == 0x7B:  # {
            depth = 1; k += 1
            while k < n and depth > 0:
                if src[k] == 0x7B: depth += 1
                elif src[k] == 0x7D: depth -= 1
                k += 1
        else:
            k = min(k + 1, n)
        blank(i, k)
        i = k
    return masked

def condense(masked: bytes):
    text = []
    pos = []
    for i, c in enumerate(masked):
        if c not in b" \t\r\n":
            text.append(chr(c))
            pos.append(i)
    return "".join(text), pos

class File:
    def __init__(self, path):
        self.rel = os.path.relpath(path, ROOT).replace(os.sep, "/")
        raw = open(path, "rb").read()
        m, self.lits = mask(raw)
        m = cfg_test_mask(m)
        self.masked = bytes(m)
        self.cond, self.pos = condense(self.masked)
        self.newlines = [i for i, c in enumerate(raw) if c == 0x0A]
    def line(self, off):
        return bisect.bisect_right(self.newlines, off) + 1
    def find_all(self, pat):
        out = []
        start = 0
        while True:
            k = self.cond.find(pat, start)
            if k < 0:
                return out
            out.append(k)
            start = k + 1

def walk():
    for dirpath, _, names in sorted(os.walk(SRC)):
        for name in sorted(names):
            if name.endswith(".rs"):
                yield File(os.path.join(dirpath, name))

IDENT = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_")

def main():
    files = list(walk())
    report = {"L1": [], "L2": [], "L5": {}, "L4": {}}
    for f in files:
        srcrel = f.rel  # like rust/src/obs/clock.rs
        # L1
        if not (srcrel == "rust/src/obs/clock.rs" or srcrel.startswith("rust/src/bench/")):
            for pat in ("Instant::now()", "SystemTime::now()"):
                for k in f.find_all(pat):
                    report["L1"].append((srcrel, f.line(f.pos[k]), pat))
        # L2
        for pat in (".lock().unwrap()", ".read().unwrap()", ".write().unwrap()"):
            for k in f.find_all(pat):
                report["L2"].append((srcrel, f.line(f.pos[k]), pat))
        # L5
        if any(srcrel.startswith("rust/src/" + d + "/") for d in ("service", "rpc", "net", "store")):
            sites = []
            for pat in (".unwrap()", ".expect(", "panic!("):
                for k in f.find_all(pat):
                    sites.append((f.line(f.pos[k]), pat))
            if sites:
                report["L5"][srcrel] = sorted(sites)
        # L4 code-side names
        names = []
        for pat in (".counter(", ".gauge(", ".histogram(", ".set_label(", ".label("):
            for k in f.find_all(pat):
                after = k + len(pat)
                if f.cond[after:after+1] == '"':
                    lit = f.lits.get(f.pos[after])
                    if lit is not None:
                        names.append((lit, f.line(f.pos[k])))
                elif f.cond[after:].startswith('&format!("'):
                    q = after + len('&format!("') - 1
                    lit = f.lits.get(f.pos[q])
                    if lit is not None:
                        names.append((lit, f.line(f.pos[k])))
        for pat in ("tenant_gauge(", "metric_name("):
            for k in f.find_all(pat):
                if k > 0 and f.cond[k-1] in IDENT:
                    continue
                # first literal within balanced parens
                depth = 0
                j = k + len(pat) - 1
                lit = None
                while j < len(f.cond):
                    c = f.cond[j]
                    if c == '(':
                        depth += 1
                    elif c == ')':
                        depth -= 1
                        if depth == 0:
                            break
                    elif c == '"' and f.pos[j] in f.lits:
                        lit = f.lits[f.pos[j]]
                        break
                    j += 1
                if lit is not None:
                    if pat == "tenant_gauge(":
                        names.append(("tenant.<*>." + lit, f.line(f.pos[k])))
                    else:
                        names.append((lit, f.line(f.pos[k])))
        for lit, line in names:
            norm = re.sub(r"\{[^}]*\}", "<*>", lit)
            report["L4"].setdefault(norm, []).append((srcrel, line))
    print(json.dumps(report, indent=1, default=list))

if __name__ == "__main__":
    main()
