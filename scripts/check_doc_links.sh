#!/usr/bin/env bash
# Link check for the markdown docs: every relative link target in
# docs/*.md, README.md and ROADMAP.md must exist in the repo.
# External (http/https/mailto) links are syntax-checked only — CI must
# not flake on the network.  Run from the repo root.
set -euo pipefail

fail=0
for f in docs/*.md README.md ROADMAP.md; do
    [ -f "$f" ] || continue
    dir=$(dirname "$f")
    # inline markdown links: [text](target)
    while IFS= read -r target; do
        case "$target" in
            http://*|https://*|mailto:*)
                continue ;;
            '#'*)
                # intra-document anchor; heading text is not checked
                continue ;;
        esac
        # strip a trailing #anchor from relative file links
        path="${target%%#*}"
        [ -n "$path" ] || continue
        if [ ! -e "$dir/$path" ]; then
            echo "BROKEN: $f -> $target"
            fail=1
        fi
    done < <(grep -oE '\]\([^)]+\)' "$f" | sed -E 's/^\]\(//; s/\)$//')
done

# backtick-quoted repo paths in the docs that look like files should
# exist too (e.g. `rust/src/rpc/mod.rs`, `docs/WIRE_PROTOCOL.md`)
for f in docs/*.md README.md; do
    [ -f "$f" ] || continue
    while IFS= read -r path; do
        if [ ! -e "$path" ]; then
            echo "BROKEN (path mention): $f -> $path"
            fail=1
        fi
    done < <(grep -oE '`(docs|rust|python|examples|scripts)/[A-Za-z0-9_./-]+`' "$f" \
             | tr -d '`' | sort -u)
done

if [ "$fail" -ne 0 ]; then
    echo "doc link check FAILED"
    exit 1
fi
echo "doc link check OK"
