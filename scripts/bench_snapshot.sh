#!/usr/bin/env bash
# bench_snapshot.sh [--smoke] [--out DIR] [BENCH...]
#
# Run the figure/overhead/micro benches and collect their schema'd
# JSON snapshots (`BENCH_<name>.json`, schema pem-bench-snapshot/1)
# into one directory — the committed bench trajectory.
#
#   --smoke     quick mode: PEM_BENCH_QUICK=1 shrinks every workload
#               so the whole sweep finishes in CI-smoke time; the
#               snapshots are still written (marked "quick": true)
#   --out DIR   where to put the JSON files (default bench_snapshots/)
#   BENCH...    subset of bench targets (default: the full list below)
#
# Each bench runs under scripts/with_timeout.sh so one hung distributed
# run fails that bench instead of stalling the sweep.  Provenance: set
# PEM_BENCH_PROVENANCE to describe the hardware; committed snapshots
# must not pretend to be from machines they never ran on.
set -u

cd "$(dirname "$0")/.."

BENCHES_DEFAULT="fig5_threads fig6_max_partition fig7_min_partition \
fig8_scaleout_small fig9_scaleout_large dist_overhead micro_hotpath"

out="bench_snapshots"
smoke=0
benches=""
while [ "$#" -gt 0 ]; do
    case "$1" in
        --smoke) smoke=1 ;;
        --out)
            shift
            out="${1:?--out needs a directory}"
            ;;
        -h | --help)
            sed -n '2,18p' "$0" | sed 's/^# \{0,1\}//'
            exit 0
            ;;
        *) benches="$benches $1" ;;
    esac
    shift
done
[ -n "$benches" ] || benches="$BENCHES_DEFAULT"

mkdir -p "$out"
export PEM_BENCH_JSON="$(cd "$out" && pwd)"
if [ "$smoke" -eq 1 ]; then
    export PEM_BENCH_QUICK=1
    per_bench_timeout=300
else
    per_bench_timeout=1800
fi
: "${PEM_BENCH_PROVENANCE:=unrecorded}"
export PEM_BENCH_PROVENANCE

echo "bench snapshot sweep → $PEM_BENCH_JSON (smoke=$smoke," \
    "provenance=$PEM_BENCH_PROVENANCE)"

failed=""
for b in $benches; do
    echo "=== $b ==="
    if ! bash scripts/with_timeout.sh "$per_bench_timeout" \
        cargo bench --manifest-path rust/Cargo.toml --bench "$b"; then
        echo "bench $b FAILED" >&2
        failed="$failed $b"
    fi
done

echo
echo "snapshots in $PEM_BENCH_JSON:"
ls -l "$PEM_BENCH_JSON"/BENCH_*.json 2>/dev/null || echo "  (none)"
if [ -n "$failed" ]; then
    echo "failed benches:$failed" >&2
    exit 1
fi
