//! Ablation: misc-block share sweep.
//!
//! The misc block must be matched against everything (paper §3.2), so
//! the share of entities with missing blocking keys directly controls
//! how much of the blocking benefit survives.  This sweep varies the
//! generator's missing-product-type fraction and reports task counts,
//! comparisons and simulated time.

mod common;

use pem::cluster::ComputingEnv;
use pem::coordinator::{run_workflow, WorkflowConfig};
use pem::datagen::GeneratorConfig;
use pem::matching::StrategyKind;
use pem::util::{fmt_nanos, GIB};

fn main() {
    pem::bench::report_header(
        "Ablation — misc-block share",
        "more unblockable entities → more misc tasks → less blocking benefit",
    );
    let n = if common::paper_scale() { 20_000 } else { 4_000 };
    let ce = ComputingEnv::new(2, 4, 3 * GIB);

    println!("misc%  partitions  misc-parts  tasks  comparisons  time");
    for miss in [0.0, 0.05, 0.17, 0.30, 0.50] {
        let data = GeneratorConfig {
            n_entities: n,
            missing_product_type: miss,
            ..GeneratorConfig::default()
        }
        .generate();
        let mut cfg = WorkflowConfig::blocking_based(StrategyKind::Wam);
        {
            use pem::coordinator::workflow::{
                default_max_size, default_min_size,
            };
            use pem::coordinator::PartitioningChoice;
            if let PartitioningChoice::BlockingBased {
                max_size, min_size, ..
            } = &mut cfg.partitioning
            {
                *max_size =
                    Some(common::scaled(default_max_size(StrategyKind::Wam)));
                *min_size =
                    common::scaled(default_min_size(StrategyKind::Wam));
            }
        }
        common::apply_net(&mut cfg);
            let out = run_workflow(&data, &cfg, &ce).expect("workflow");
        println!(
            "{:>4.0}%  {:>10}  {:>10}  {:>5}  {:>11}  {}",
            miss * 100.0,
            out.n_partitions,
            out.n_misc_partitions,
            out.n_tasks,
            out.metrics.comparisons,
            fmt_nanos(out.metrics.makespan_ns),
        );
    }
}
