//! Micro-benchmarks of the L3 hot paths — the §Perf working set.
//!
//! Covers the units the profiler fingers: matcher inner loops, feature
//! construction, scheduler assignment, LRU cache ops, feature-matrix
//! assembly, and one full simulated workflow.

mod common;

use pem::bench::Bencher;
use pem::coordinator::scheduler::{Policy, Scheduler, ServiceId};
use pem::coordinator::{run_workflow, WorkflowConfig};
use pem::datagen::GeneratorConfig;
use pem::features::{EntityFeatures, QGramSet, DEFAULT_DIM};
use pem::matching::{
    cosine_concat, editdist, jaccard, trigram_dice, MatchStrategy,
    StrategyKind,
};
use pem::model::EntityId;
use pem::partition::{generate_tasks, partition_size_based, MatchTask, PartitionId};
use pem::util::LruCache;

fn main() {
    pem::bench::report_header(
        "Micro — L3 hot paths",
        "per-unit costs feeding EXPERIMENTS.md §Perf",
    );
    let data = GeneratorConfig::tiny().with_entities(400).generate();
    let feats: Vec<EntityFeatures> = data
        .dataset
        .entities
        .iter()
        .map(|e| EntityFeatures::of(e, &data.dataset))
        .collect();
    let mut b = Bencher::default();

    // matcher kernels
    b.bench("edit_similarity (full)", || {
        for i in 0..40 {
            std::hint::black_box(editdist::edit_similarity(
                &feats[i].title_norm,
                &feats[i + 40].title_norm,
            ));
        }
    });
    b.bench("edit_similarity_min (banded 0.5)", || {
        for i in 0..40 {
            std::hint::black_box(editdist::edit_similarity_min(
                &feats[i].title_norm,
                &feats[i + 40].title_norm,
                0.5,
            ));
        }
    });
    b.bench("trigram_dice", || {
        for i in 0..40 {
            std::hint::black_box(trigram_dice(
                &feats[i].desc_grams,
                &feats[i + 40].desc_grams,
            ));
        }
    });
    b.bench("jaccard tokens", || {
        for i in 0..40 {
            std::hint::black_box(jaccard(
                &feats[i].title_tokens,
                &feats[i + 40].title_tokens,
            ));
        }
    });
    b.bench("cosine_concat (1024-d)", || {
        for i in 0..8 {
            std::hint::black_box(cosine_concat(
                &feats[i].title_grams,
                &feats[i].desc_grams,
                &feats[i + 40].title_grams,
                &feats[i + 40].desc_grams,
            ));
        }
    });
    b.bench("wam strategy pair", || {
        let s = MatchStrategy::new(StrategyKind::Wam);
        for i in 0..40 {
            std::hint::black_box(s.similarity(&feats[i], &feats[i + 40]));
        }
    });
    b.bench("lrm strategy pair", || {
        let s = MatchStrategy::new(StrategyKind::Lrm);
        for i in 0..8 {
            std::hint::black_box(s.similarity(&feats[i], &feats[i + 40]));
        }
    });

    // feature construction
    b.bench("EntityFeatures::of", || {
        for e in data.dataset.entities.iter().take(20) {
            std::hint::black_box(EntityFeatures::of(e, &data.dataset));
        }
    });
    b.bench("hashed_counts 256-d", || {
        for f in feats.iter().take(50) {
            std::hint::black_box(f.title_grams.hashed_counts(DEFAULT_DIM));
        }
    });
    b.bench("feature matrix 128x256", || {
        let grams: Vec<&QGramSet> =
            feats.iter().take(128).map(|f| &f.title_grams).collect();
        std::hint::black_box(
            pem::features::FeatureMatrix::from_qgrams(&grams, 128, 256),
        );
    });

    // scheduler + cache
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 20);
    let tasks: Vec<MatchTask> = generate_tasks(&parts);
    b.bench(&format!("scheduler affinity assign ({} tasks)", tasks.len()), || {
        let mut s = Scheduler::new(tasks.clone(), Policy::Affinity);
        let mut held: Vec<MatchTask> = Vec::new();
        while let Some(t) = s.next_task(ServiceId(0)) {
            held.push(t);
            if held.len() > 4 {
                let t = held.remove(0);
                s.report_complete(ServiceId(0), t.id, t.needed_partitions());
            }
        }
        for t in held.drain(..) {
            s.report_complete(ServiceId(0), t.id, vec![]);
        }
    });
    b.bench("lru cache get/put (c=16)", || {
        let mut c: LruCache<PartitionId, u64> = LruCache::new(16);
        for i in 0..200u32 {
            let id = PartitionId(i % 24);
            if c.get(&id).is_none() {
                c.put(id, i as u64);
            }
        }
    });

    // end-to-end simulated workflow (no calibration for stability)
    b.bench("simulated workflow (tiny, 16 cores)", || {
        let mut cfg = WorkflowConfig::blocking_based(StrategyKind::Wam);
        cfg.calibrate = false;
        let out = run_workflow(&data, &cfg, &common::testbed(16)).unwrap();
        std::hint::black_box(out.metrics.makespan_ns);
    });

    b.write_snapshot("micro_hotpath").expect("bench snapshot");
}
