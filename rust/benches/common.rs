//! Shared helpers for the per-figure/table reproduction benches.
//!
//! Every bench regenerates one table or figure of the paper's §5 on the
//! synthetic workload (DESIGN.md §Substitutions), printing the same rows
//! or series the paper reports.  Scale flags:
//!
//! * default       — scaled-down workload, finishes in ~a minute
//! * `--paper-scale` / `PEM_PAPER_SCALE=1` — the paper's 20k/114k sizes

#![allow(dead_code)]

use pem::cluster::ComputingEnv;
use pem::datagen::{GeneratedData, GeneratorConfig};
use pem::engine::{calibrate, CostParams};
use pem::matching::StrategyKind;
use pem::util::GIB;

pub fn paper_scale() -> bool {
    std::env::args().any(|a| a == "--paper-scale")
        || std::env::var("PEM_PAPER_SCALE").is_ok_and(|v| v != "0")
}

/// The small match problem: 20,000 offers (paper) or a scaled-down 4,000.
pub fn small_problem() -> GeneratedData {
    let n = if paper_scale() { 20_000 } else { 4_000 };
    GeneratorConfig::default().with_entities(n).generate()
}

/// The large match problem: 114,000 offers (paper) or 12,000 scaled.
pub fn large_problem() -> GeneratedData {
    let n = if paper_scale() { 114_000 } else { 12_000 };
    GeneratorConfig::default().with_entities(n).generate()
}

/// Scale partition-size parameters in proportion to the dataset scale so
/// task counts keep the paper's shape on scaled-down runs.
pub fn scaled(size: usize) -> usize {
    if paper_scale() {
        size
    } else {
        (size / 5).max(10)
    }
}

/// Node memory: the paper's 3 GB heap, scaled by the square of the
/// partition-size scale on scaled-down runs (task memory is c_ms·m², so
/// memory must shrink with m² for the paging effects of Figs 5/6 to
/// appear at reduced scale).
pub fn node_mem() -> u64 {
    if paper_scale() {
        3 * GIB
    } else {
        3 * GIB / 25
    }
}

/// Paper testbed slice with `cores` total cores (4 cores per node).
pub fn testbed(cores: usize) -> ComputingEnv {
    let nodes = cores.div_ceil(4).max(1);
    let per_node = cores.div_ceil(nodes);
    ComputingEnv::new(nodes, per_node, node_mem())
}

/// Data-service cost model, scaled: on reduced workloads partitions are
/// 5× smaller and per-task compute 25× smaller, so the DBMS fetch path
/// must scale down too or fetch would dominate in a way the paper's
/// full-scale runs never saw.
pub fn data_net() -> pem::net::CostModel {
    if paper_scale() {
        pem::net::CostModel::dbms()
    } else {
        pem::net::CostModel {
            latency_ns: 1_400_000,      // 7 ms / 5
            bandwidth_bps: 75_000_000,  // 15 MB/s × 5
        }
    }
}

/// Apply the scaled cost models to a workflow config.
pub fn apply_net(cfg: &mut pem::coordinator::WorkflowConfig) {
    cfg.data_net = data_net();
}

/// The scaled cost models + a pinned calibration as `Sim` backend
/// options (the builder-API form of `apply_net` + `with_cost`).
pub fn sim_options(cost: CostParams) -> pem::engine::backend::SimOptions {
    pem::engine::backend::SimOptions {
        data_net: data_net(),
        cost_override: Some(cost),
        ..Default::default()
    }
}

/// Calibrate both strategies once on a dataset sample.
pub fn calibrated(data: &GeneratedData) -> (CostParams, CostParams) {
    let wam =
        calibrate::calibrated_params(&data.dataset, StrategyKind::Wam, 100, 1);
    let lrm =
        calibrate::calibrated_params(&data.dataset, StrategyKind::Lrm, 100, 1);
    (wam, lrm)
}

/// Format virtual nanoseconds as minutes (the paper's tables are minutes).
pub fn as_min(ns: u64) -> f64 {
    ns as f64 / 60e9
}
