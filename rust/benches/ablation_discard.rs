//! Ablation: WAM's threshold-discard optimization (paper §5.1).
//!
//! WAM discards every correspondence whose achievable combined
//! similarity already misses the threshold; this is both a memory
//! optimization (c_ms ≈ 20 B/pair) and a compute optimization (the
//! banded edit distance exits early).  This bench measures the real
//! per-pair cost with the optimization on vs off.

mod common;

use pem::engine::calibrate::calibrate;
use pem::features::EntityFeatures;
use pem::matching::{
    editdist, trigram_dice, MatchStrategy, StrategyKind,
};
use pem::util::Rng;

fn main() {
    pem::bench::report_header(
        "Ablation — WAM threshold-discard on/off",
        "discard keeps memory at candidates-only and cuts matcher cost",
    );
    let data = common::small_problem();

    // real per-pair cost through the discard path
    let with = calibrate(&data.dataset, StrategyKind::Wam, 150, 3);
    println!(
        "with discard:    {:>8.0} ns/pair  ({} pairs measured)",
        with.pair_ns, with.pairs_measured
    );

    // without: full edit distance + trigram on every pair
    let mut rng = Rng::new(3);
    let mut idx: Vec<usize> = (0..data.dataset.len()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(150);
    let feats: Vec<EntityFeatures> = idx
        .iter()
        .map(|&i| EntityFeatures::of(&data.dataset.entities[i], &data.dataset))
        .collect();
    let start = std::time::Instant::now();
    let mut pairs = 0u64;
    let mut kept = 0u64;
    let strategy = MatchStrategy::new(StrategyKind::Wam);
    for i in 0..feats.len() {
        for j in (i + 1)..feats.len() {
            let s_title = editdist::edit_similarity(
                &feats[i].title_norm,
                &feats[j].title_norm,
            );
            let s_desc =
                trigram_dice(&feats[i].desc_grams, &feats[j].desc_grams);
            let combined = 0.5 * s_title + 0.5 * s_desc;
            // without discard every intermediate correspondence is kept
            kept += 1;
            if combined >= strategy.threshold {
                std::hint::black_box(combined);
            }
            pairs += 1;
        }
    }
    let without_ns =
        start.elapsed().as_nanos() as f64 / pairs.max(1) as f64;
    println!(
        "without discard: {:>8.0} ns/pair  (keeps {} intermediate correspondences)",
        without_ns, kept
    );
    println!(
        "speedup from discard: {:.2}x; intermediate memory {}x smaller",
        without_ns / with.pair_ns,
        kept.max(1), // with discard only candidates survive
    );
    println!(
        "\nmemory model: c_ms(WAM)={} B/pair, c_ms(LRM)={} B/pair",
        StrategyKind::Wam.memory_per_pair(),
        StrategyKind::Lrm.memory_per_pair()
    );
}
