//! Figure 9: speedup on the large match problem (114k offers),
//! blocking-based partitioning only (the Cartesian product — ~6.5
//! billion pairs — is deliberately not evaluated, as in the paper).
//!
//! Expected shape: ~1,200 match tasks for WAM vs ~3,900 for LRM (smaller
//! max partition size); more than half the tasks involve misc
//! sub-partitions; linear speedup to 16 cores; WAM ≈ 6 h → 24 min,
//! LRM ≈ 8 h → 51 min on the paper's hardware.

mod common;

use pem::coordinator::{run_workflow, WorkflowConfig};
use pem::matching::StrategyKind;
use pem::metrics::speedups;
use pem::partition::generate_tasks;
use pem::util::fmt_nanos;

fn main() {
    pem::bench::report_header(
        "Figure 9 — speedup, large problem, blocking-based",
        "~1200 tasks WAM / ~3900 LRM; >50% misc-involved; linear to 16 cores",
    );
    let data = common::large_problem();
    let cores_list = [1usize, 2, 4, 8, 12, 16];
    let (cost_wam, cost_lrm) = common::calibrated(&data);
    let mut snap = Vec::new();

    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        let mut cfg = WorkflowConfig::blocking_based(kind).with_cost(
            if kind == StrategyKind::Wam { cost_wam } else { cost_lrm },
        );
        if !common::paper_scale() {
            use pem::coordinator::workflow::{
                default_max_size, default_min_size,
            };
            use pem::coordinator::PartitioningChoice;
            if let PartitioningChoice::BlockingBased {
                max_size,
                min_size,
                ..
            } = &mut cfg.partitioning
            {
                *max_size = Some(common::scaled(default_max_size(kind)));
                *min_size = common::scaled(default_min_size(kind));
            }
        }

        // task structure report (misc share)
        let ce1 = common::testbed(1);
        let parts = pem::coordinator::workflow::build_partitions(
            &data, &cfg, &ce1,
        )
        .expect("partitions");
        let tasks = generate_tasks(&parts);
        let misc: std::collections::HashSet<_> =
            parts.misc_ids().into_iter().collect();
        let misc_tasks = tasks
            .iter()
            .filter(|t| misc.contains(&t.left) || misc.contains(&t.right))
            .count();
        println!(
            "strategy {}: partitions={} (misc {}), tasks={} ({}% misc-involved)",
            kind.name(),
            parts.len(),
            parts.n_misc(),
            tasks.len(),
            100 * misc_tasks / tasks.len().max(1)
        );

        println!("cores  time          speedup");
        let mut times = Vec::new();
        for &cores in &cores_list {
            let ce = common::testbed(cores);
            common::apply_net(&mut cfg);
            let out = run_workflow(&data, &cfg, &ce).expect("workflow");
            times.push(out.metrics.makespan_ns);
            snap.push(pem::bench::point(
                format!("{}/cores={cores}", kind.name()),
                out.metrics.makespan_ns,
            ));
            let s = speedups(&times);
            println!(
                "{:>5}  {:>12}  {:>7.2}",
                cores,
                fmt_nanos(out.metrics.makespan_ns),
                s.last().unwrap()
            );
        }
        println!();
    }
    pem::bench::write_json_snapshot("fig9_scaleout_large", &snap)
        .expect("bench snapshot");
}
