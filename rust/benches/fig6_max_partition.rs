//! Figure 6: influence of the maximum partition size.
//!
//! Paper setup: small problem, size-based partitioning (Cartesian),
//! 1 node / 4 threads, partition sizes 100–1000.  Expected shape: going
//! 100 → 200 strongly improves both strategies (fewer tasks, less
//! overhead); WAM keeps improving to 1000; LRM's memory consumption
//! grows with m² and its time deteriorates past 500.

mod common;

use pem::cluster::ComputingEnv;
use pem::coordinator::{run_workflow, PartitioningChoice, WorkflowConfig};
use pem::matching::StrategyKind;
use pem::partition::task_memory_bytes;
use pem::util::{fmt_bytes, fmt_nanos};

fn main() {
    pem::bench::report_header(
        "Figure 6 — influence of the maximum partition size",
        "WAM improves to m=1000; LRM deteriorates past m=500 (memory)",
    );
    let data = common::small_problem();
    let ce = ComputingEnv::new(1, 4, common::node_mem());
    let sizes: Vec<usize> = [100usize, 200, 300, 400, 500, 700, 1000]
        .iter()
        .map(|&s| common::scaled(s))
        .collect();

    let (cost_wam, cost_lrm) = common::calibrated(&data);
    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        println!("strategy {}", kind.name());
        println!("m        time          tasks   peak-mem(model)");
        for &m in &sizes {
            let mut cfg = WorkflowConfig::size_based(kind).with_cost(
                if kind == StrategyKind::Wam { cost_wam } else { cost_lrm },
            );
            cfg.partitioning =
                PartitioningChoice::SizeBased { max_size: Some(m) };
            common::apply_net(&mut cfg);
            let out = run_workflow(&data, &cfg, &ce).expect("workflow");
            // modeled peak memory: 4 concurrent tasks of m×m pairs
            let peak =
                task_memory_bytes(m, m, kind) * ce.threads_per_node as u64;
            println!(
                "{:>5}  {:>12}  {:>6}  {:>12}",
                m,
                fmt_nanos(out.metrics.makespan_ns),
                out.n_tasks,
                fmt_bytes(peak)
            );
        }
        println!();
    }
}
