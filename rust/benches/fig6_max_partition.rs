//! Figure 6: influence of the maximum partition size.
//!
//! Paper setup: small problem, size-based partitioning (Cartesian),
//! 1 node / 4 threads, partition sizes 100–1000.  Expected shape: going
//! 100 → 200 strongly improves both strategies (fewer tasks, less
//! overhead); WAM keeps improving to 1000; LRM's memory consumption
//! grows with m² and its time deteriorates past 500.
//!
//! Runs through the plan/execute builder: each cell's `MatchPlan`
//! supplies the task count and the §3.1 peak-memory model that the
//! paper's figure annotates.

mod common;

use pem::cluster::ComputingEnv;
use pem::coordinator::Workflow;
use pem::engine::backend::Sim;
use pem::matching::StrategyKind;
use pem::partition::SizeBased;
use pem::util::{fmt_bytes, fmt_nanos};

fn main() {
    pem::bench::report_header(
        "Figure 6 — influence of the maximum partition size",
        "WAM improves to m=1000; LRM deteriorates past m=500 (memory)",
    );
    let data = common::small_problem();
    let ce = ComputingEnv::new(1, 4, common::node_mem());
    let sizes: Vec<usize> = [100usize, 200, 300, 400, 500, 700, 1000]
        .iter()
        .map(|&s| common::scaled(s))
        .collect();

    let (cost_wam, cost_lrm) = common::calibrated(&data);
    let mut snap = Vec::new();
    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        println!("strategy {}", kind.name());
        println!("m        time          tasks   peak-mem(model)");
        for &m in &sizes {
            let cost =
                if kind == StrategyKind::Wam { cost_wam } else { cost_lrm };
            let planned = Workflow::for_dataset(&data.dataset)
                .matching(kind)
                .strategy(SizeBased::with_max_size(m))
                .backend(Sim(common::sim_options(cost)))
                .env(ce)
                .plan()
                .expect("plan");
            // modeled peak memory: `threads` concurrent copies of the
            // heaviest task's §3.1 footprint, straight from the plan
            let peak = planned.plan().skew().max_task_mem
                * ce.threads_per_node as u64;
            let out = planned.execute().expect("workflow");
            snap.push(pem::bench::point(
                format!("{}/m={m}", kind.name()),
                out.metrics.makespan_ns,
            ));
            println!(
                "{:>5}  {:>12}  {:>6}  {:>12}",
                m,
                fmt_nanos(out.metrics.makespan_ns),
                out.n_tasks,
                fmt_bytes(peak)
            );
        }
        println!();
    }
    pem::bench::write_json_snapshot("fig6_max_partition", &snap)
        .expect("bench snapshot");
}
