//! Distributed-runtime overhead: thread engine vs TCP services on the
//! same workload.
//!
//! Quantifies what crossing real sockets costs relative to the shared-
//! memory thread engine — wall time, data-plane wire bytes, control
//! messages — and derives a per-task round-trip overhead.  The paper's
//! §4 design (partition caching + affinity scheduling + one-round-trip
//! pull) exists precisely to keep this overhead small.

mod common;

use pem::cluster::ComputingEnv;
use pem::datagen::GeneratorConfig;
use pem::engine::{dist, threads};
use pem::matching::{MatchStrategy, StrategyKind};
use pem::model::EntityId;
use pem::partition::{generate_tasks, partition_size_based};
use pem::store::DataService;
use pem::util::{fmt_bytes, fmt_nanos};
use pem::worker::{RustExecutor, TaskExecutor};
use std::sync::Arc;

fn main() {
    pem::bench::report_header(
        "Distributed runtime overhead — threads vs TCP services",
        "same tasks, same executor; difference = wire + scheduling RPC",
    );

    let n = if common::paper_scale() { 8_000 } else { 2_000 };
    let m = common::scaled(500).max(50);
    let data = GeneratorConfig::default().with_entities(n).generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, m);
    let strategy = MatchStrategy::new(StrategyKind::Wam);

    println!(
        "workload: {} entities → {} partitions → {} tasks\n",
        n,
        parts.len(),
        generate_tasks(&parts).len()
    );
    let mut snap = Vec::new();
    println!("engine    nodes  time         hr     data plane      ctl msgs");

    for nodes in [1usize, 2, 4] {
        let ce = ComputingEnv::new(nodes, 2, common::node_mem());
        let tasks = generate_tasks(&parts);
        let n_tasks = tasks.len();

        // thread engine (shared memory)
        let store = DataService::build(&data.dataset, &parts);
        let exec = RustExecutor::new(strategy);
        let t = threads::run(
            &ce,
            &parts,
            tasks.clone(),
            &store,
            &exec,
            threads::ThreadConfig {
                cache_capacity: 8,
                policy: pem::coordinator::Policy::Affinity,
                tracer: None,
            },
        );
        snap.push(pem::bench::point(
            format!("threads/nodes={nodes}"),
            t.metrics.makespan_ns,
        ));
        println!(
            "threads   {:>5}  {:>11}  {:>4.0}%  {:>14}  {:>8}",
            nodes,
            fmt_nanos(t.metrics.makespan_ns),
            t.metrics.hit_ratio() * 100.0,
            format!("({})", fmt_bytes(t.metrics.bytes_fetched)),
            t.metrics.control_messages,
        );

        // distributed engine (real sockets)
        let store = Arc::new(DataService::build(&data.dataset, &parts));
        let exec: Arc<dyn TaskExecutor> =
            Arc::new(RustExecutor::new(strategy));
        let d = dist::run(
            &ce,
            &parts,
            tasks,
            store,
            exec,
            dist::DistConfig {
                cache_capacity: 8,
                ..dist::DistConfig::default()
            },
        )
        .expect("distributed run");
        snap.push(pem::bench::point(
            format!("dist/nodes={nodes}"),
            d.metrics.makespan_ns,
        ));
        println!(
            "dist      {:>5}  {:>11}  {:>4.0}%  {:>14}  {:>8}",
            nodes,
            fmt_nanos(d.metrics.makespan_ns),
            d.metrics.hit_ratio() * 100.0,
            fmt_bytes(d.metrics.bytes_fetched),
            d.metrics.control_messages,
        );
        let overhead_ns = d
            .metrics
            .makespan_ns
            .saturating_sub(t.metrics.makespan_ns);
        println!(
            "          → wire overhead {} total, {} per task\n",
            fmt_nanos(overhead_ns),
            fmt_nanos(overhead_ns / n_tasks.max(1) as u64),
        );
    }

    println!(
        "(thread-engine \"data plane\" is modeled approx_bytes; the dist \
         row is bytes actually written to sockets, frames included)\n"
    );

    // ---------------------------------------------------- replication
    // Fetch-throughput scaling of the replicated data plane: caches
    // off, so every task pays two wire fetches and the data plane is
    // the bottleneck; more replicas = more aggregate serving capacity.
    pem::bench::report_header(
        "Replicated data plane — fetch throughput vs replica count",
        "cache disabled; per-replica wire bytes show the fetch spread",
    );
    println!("replicas  time         data plane      throughput  per-replica");
    for replicas in [1usize, 2, 3] {
        let ce = ComputingEnv::new(3, 2, common::node_mem());
        let tasks = generate_tasks(&parts);
        let store = Arc::new(DataService::build(&data.dataset, &parts));
        let exec: Arc<dyn TaskExecutor> =
            Arc::new(RustExecutor::new(strategy));
        let d = dist::run(
            &ce,
            &parts,
            tasks,
            store,
            exec,
            dist::DistConfig {
                cache_capacity: 0,
                data_replicas: replicas,
                ..dist::DistConfig::default()
            },
        )
        .expect("replicated distributed run");
        snap.push(pem::bench::point(
            format!("dist/replicas={replicas}"),
            d.metrics.makespan_ns,
        ));
        let secs = d.metrics.makespan_ns as f64 / 1e9;
        let mibps = if secs > 0.0 {
            d.data_wire_bytes as f64 / (1024.0 * 1024.0) / secs
        } else {
            0.0
        };
        println!(
            "{:>8}  {:>11}  {:>14}  {:>7.1} MiB/s  [{}]",
            replicas,
            fmt_nanos(d.metrics.makespan_ns),
            fmt_bytes(d.data_wire_bytes),
            mibps,
            d.replica_wire_bytes
                .iter()
                .map(|b| fmt_bytes(*b))
                .collect::<Vec<_>>()
                .join(", "),
        );
    }
    println!(
        "\n(replica counts include the primary; its bytes include the \
         one-time replication push to each replica)"
    );

    // ------------------------------------------------------- batching
    // Assignment round trips vs batch size: one TaskRequestBatch
    // reports k completions and pulls k tasks, so the control-plane
    // coordination cost per task falls from ~1 round trip (the
    // classic Complete→Assign cycle) toward 1/k — and the *dedicated*
    // assignment pulls (requests carrying no completions: startup and
    // drain polls) sit far below 1/k for every k, because assignment
    // otherwise rides entirely on completion piggybacking.
    pem::bench::report_header(
        "Batched task assignment — control round trips vs batch size",
        "k tasks per TaskRequestBatch; completions piggybacked",
    );
    println!(
        "batch  time         coord/task  target 1/k  pure pulls/task"
    );
    for k in [1usize, 2, 4, 8] {
        let ce = ComputingEnv::new(2, 2, common::node_mem());
        let tasks = generate_tasks(&parts);
        let n_tasks = tasks.len() as f64;
        let store = Arc::new(DataService::build(&data.dataset, &parts));
        let exec: Arc<dyn TaskExecutor> =
            Arc::new(RustExecutor::new(strategy));
        let d = dist::run(
            &ce,
            &parts,
            tasks,
            store,
            exec,
            dist::DistConfig {
                cache_capacity: 8,
                batch: k,
                ..dist::DistConfig::default()
            },
        )
        .expect("batched distributed run");
        snap.push(pem::bench::point(
            format!("dist/batch={k}"),
            d.metrics.makespan_ns,
        ));
        let wf = &d.workflow;
        // task-coordination frames: everything except liveness
        let coordination =
            wf.control_messages.saturating_sub(wf.heartbeats) as f64;
        println!(
            "{:>5}  {:>11}  {:>10.3}  {:>10.3}  {:>15.4}",
            k,
            fmt_nanos(d.metrics.makespan_ns),
            coordination / n_tasks,
            1.0 / k as f64,
            wf.assignment_pulls as f64 / n_tasks,
        );
    }
    println!(
        "\n(\"coord/task\" counts all non-heartbeat control frames per \
         task — joins, pulls, completions; \"pure pulls\" are the \
         assignment round trips that carried no completion report, \
         the only per-task coordination that is not piggybacked — \
         below 1/k for every batch size)"
    );

    // ------------------------------------------- scheduler fast path
    // The pull hot path: with no oversize rejection anywhere (the
    // normal case), a FIFO pull is an O(1) front pop; one recorded
    // rejection forces the per-pull exclusion scan.  This section
    // shows what the empty-map short circuit saves.
    pem::bench::report_header(
        "Scheduler pull fast path — empty vs populated oversize map",
        "drain n tasks via next_task; empty map must skip the scan",
    );
    use pem::coordinator::{Policy, Scheduler, ServiceId};
    use pem::partition::{MatchTask, PartitionId};
    let n = 100_000u32;
    let mk_tasks = || -> Vec<MatchTask> {
        (0..n)
            .map(|i| MatchTask {
                id: i,
                left: PartitionId(i % 97),
                right: PartitionId((i * 31) % 97),
            })
            .collect()
    };
    println!("oversize map  drain time    per pull");
    for poison in [false, true] {
        let mut s = Scheduler::new(mk_tasks(), Policy::Fifo);
        s.add_service(ServiceId(0));
        s.add_service(ServiceId(1));
        if poison {
            // one rejection by the *other* service: every pull by
            // service 0 now pays the exclusion scan
            let t = s.next_task(ServiceId(1)).expect("task");
            s.reject_task(ServiceId(1), t.id);
        }
        let t0 = std::time::Instant::now();
        let mut pulled = 0u64;
        while let Some(t) = s.next_task(ServiceId(0)) {
            s.report_complete(ServiceId(0), t.id, vec![]);
            pulled += 1;
        }
        let el = t0.elapsed().as_nanos() as u64;
        snap.push(pem::bench::point(
            format!(
                "scheduler_drain/oversize_map={}",
                if poison { "populated" } else { "empty" }
            ),
            el,
        ));
        println!(
            "{:>11}  {:>11}  {:>7.0} ns",
            if poison { "1 entry" } else { "empty" },
            fmt_nanos(el),
            el as f64 / pulled.max(1) as f64,
        );
    }
    println!(
        "\n(one recorded rejection — against the *other* service — \
         makes the map non-empty, forcing the exclusion scan on every \
         pull; the delta between the rows is what the normal-case \
         fast path avoids)"
    );
    pem::bench::write_json_snapshot("dist_overhead", &snap)
        .expect("bench snapshot");
}
