//! Distributed-runtime overhead: thread engine vs TCP services on the
//! same workload.
//!
//! Quantifies what crossing real sockets costs relative to the shared-
//! memory thread engine — wall time, data-plane wire bytes, control
//! messages — and derives a per-task round-trip overhead.  The paper's
//! §4 design (partition caching + affinity scheduling + one-round-trip
//! pull) exists precisely to keep this overhead small.

mod common;

use pem::cluster::ComputingEnv;
use pem::datagen::GeneratorConfig;
use pem::engine::{dist, threads};
use pem::matching::{MatchStrategy, StrategyKind};
use pem::model::EntityId;
use pem::partition::{generate_tasks, partition_size_based};
use pem::store::DataService;
use pem::util::{fmt_bytes, fmt_nanos};
use pem::rpc::{Message, Transport, PROTOCOL_VERSION};
use pem::service::{
    DataServiceServer, WorkflowServerConfig, WorkflowServiceServer,
};
use pem::worker::{RustExecutor, TaskExecutor};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    pem::bench::report_header(
        "Distributed runtime overhead — threads vs TCP services",
        "same tasks, same executor; difference = wire + scheduling RPC",
    );

    let n = if common::paper_scale() { 8_000 } else { 2_000 };
    let m = common::scaled(500).max(50);
    let data = GeneratorConfig::default().with_entities(n).generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, m);
    let strategy = MatchStrategy::new(StrategyKind::Wam);

    println!(
        "workload: {} entities → {} partitions → {} tasks\n",
        n,
        parts.len(),
        generate_tasks(&parts).len()
    );
    let mut snap = Vec::new();
    println!("engine    nodes  time         hr     data plane      ctl msgs");

    for nodes in [1usize, 2, 4] {
        let ce = ComputingEnv::new(nodes, 2, common::node_mem());
        let tasks = generate_tasks(&parts);
        let n_tasks = tasks.len();

        // thread engine (shared memory)
        let store = DataService::build(&data.dataset, &parts);
        let exec = RustExecutor::new(strategy);
        let t = threads::run(
            &ce,
            &parts,
            tasks.clone(),
            &store,
            &exec,
            threads::ThreadConfig {
                cache_capacity: 8,
                policy: pem::coordinator::Policy::Affinity,
                tracer: None,
            },
        );
        snap.push(pem::bench::point(
            format!("threads/nodes={nodes}"),
            t.metrics.makespan_ns,
        ));
        println!(
            "threads   {:>5}  {:>11}  {:>4.0}%  {:>14}  {:>8}",
            nodes,
            fmt_nanos(t.metrics.makespan_ns),
            t.metrics.hit_ratio() * 100.0,
            format!("({})", fmt_bytes(t.metrics.bytes_fetched)),
            t.metrics.control_messages,
        );

        // distributed engine (real sockets)
        let store = Arc::new(DataService::build(&data.dataset, &parts));
        let exec: Arc<dyn TaskExecutor> =
            Arc::new(RustExecutor::new(strategy));
        let d = dist::run(
            &ce,
            &parts,
            tasks,
            store,
            exec,
            dist::DistConfig {
                cache_capacity: 8,
                ..dist::DistConfig::default()
            },
        )
        .expect("distributed run");
        snap.push(pem::bench::point(
            format!("dist/nodes={nodes}"),
            d.metrics.makespan_ns,
        ));
        println!(
            "dist      {:>5}  {:>11}  {:>4.0}%  {:>14}  {:>8}",
            nodes,
            fmt_nanos(d.metrics.makespan_ns),
            d.metrics.hit_ratio() * 100.0,
            fmt_bytes(d.metrics.bytes_fetched),
            d.metrics.control_messages,
        );
        let overhead_ns = d
            .metrics
            .makespan_ns
            .saturating_sub(t.metrics.makespan_ns);
        println!(
            "          → wire overhead {} total, {} per task\n",
            fmt_nanos(overhead_ns),
            fmt_nanos(overhead_ns / n_tasks.max(1) as u64),
        );
    }

    println!(
        "(thread-engine \"data plane\" is modeled approx_bytes; the dist \
         row is bytes actually written to sockets, frames included)\n"
    );

    // ---------------------------------------------------- replication
    // Fetch-throughput scaling of the replicated data plane: caches
    // off, so every task pays two wire fetches and the data plane is
    // the bottleneck; more replicas = more aggregate serving capacity.
    pem::bench::report_header(
        "Replicated data plane — fetch throughput vs replica count",
        "cache disabled; per-replica wire bytes show the fetch spread",
    );
    println!("replicas  time         data plane      throughput  per-replica");
    for replicas in [1usize, 2, 3] {
        let ce = ComputingEnv::new(3, 2, common::node_mem());
        let tasks = generate_tasks(&parts);
        let store = Arc::new(DataService::build(&data.dataset, &parts));
        let exec: Arc<dyn TaskExecutor> =
            Arc::new(RustExecutor::new(strategy));
        let d = dist::run(
            &ce,
            &parts,
            tasks,
            store,
            exec,
            dist::DistConfig {
                cache_capacity: 0,
                data_replicas: replicas,
                ..dist::DistConfig::default()
            },
        )
        .expect("replicated distributed run");
        snap.push(pem::bench::point(
            format!("dist/replicas={replicas}"),
            d.metrics.makespan_ns,
        ));
        let secs = d.metrics.makespan_ns as f64 / 1e9;
        let mibps = if secs > 0.0 {
            d.data_wire_bytes as f64 / (1024.0 * 1024.0) / secs
        } else {
            0.0
        };
        println!(
            "{:>8}  {:>11}  {:>14}  {:>7.1} MiB/s  [{}]",
            replicas,
            fmt_nanos(d.metrics.makespan_ns),
            fmt_bytes(d.data_wire_bytes),
            mibps,
            d.replica_wire_bytes
                .iter()
                .map(|b| fmt_bytes(*b))
                .collect::<Vec<_>>()
                .join(", "),
        );
    }
    println!(
        "\n(replica counts include the primary; its bytes include the \
         one-time replication push to each replica)"
    );

    // ------------------------------------------------------- batching
    // Assignment round trips vs batch size: one TaskRequestBatch
    // reports k completions and pulls k tasks, so the control-plane
    // coordination cost per task falls from ~1 round trip (the
    // classic Complete→Assign cycle) toward 1/k — and the *dedicated*
    // assignment pulls (requests carrying no completions: startup and
    // drain polls) sit far below 1/k for every k, because assignment
    // otherwise rides entirely on completion piggybacking.
    pem::bench::report_header(
        "Batched task assignment — control round trips vs batch size",
        "k tasks per TaskRequestBatch; completions piggybacked",
    );
    println!(
        "batch  time         coord/task  target 1/k  pure pulls/task"
    );
    for k in [1usize, 2, 4, 8] {
        let ce = ComputingEnv::new(2, 2, common::node_mem());
        let tasks = generate_tasks(&parts);
        let n_tasks = tasks.len() as f64;
        let store = Arc::new(DataService::build(&data.dataset, &parts));
        let exec: Arc<dyn TaskExecutor> =
            Arc::new(RustExecutor::new(strategy));
        let d = dist::run(
            &ce,
            &parts,
            tasks,
            store,
            exec,
            dist::DistConfig {
                cache_capacity: 8,
                batch: k,
                ..dist::DistConfig::default()
            },
        )
        .expect("batched distributed run");
        snap.push(pem::bench::point(
            format!("dist/batch={k}"),
            d.metrics.makespan_ns,
        ));
        let wf = &d.workflow;
        // task-coordination frames: everything except liveness
        let coordination =
            wf.control_messages.saturating_sub(wf.heartbeats) as f64;
        println!(
            "{:>5}  {:>11}  {:>10.3}  {:>10.3}  {:>15.4}",
            k,
            fmt_nanos(d.metrics.makespan_ns),
            coordination / n_tasks,
            1.0 / k as f64,
            wf.assignment_pulls as f64 / n_tasks,
        );
    }
    println!(
        "\n(\"coord/task\" counts all non-heartbeat control frames per \
         task — joins, pulls, completions; \"pure pulls\" are the \
         assignment round trips that carried no completion report, \
         the only per-task coordination that is not piggybacked — \
         below 1/k for every batch size)"
    );

    // ------------------------------------------- scheduler fast path
    // The pull hot path: with no oversize rejection anywhere (the
    // normal case), a FIFO pull is an O(1) front pop; one recorded
    // rejection forces the per-pull exclusion scan.  This section
    // shows what the empty-map short circuit saves.
    pem::bench::report_header(
        "Scheduler pull fast path — empty vs populated oversize map",
        "drain n tasks via next_task; empty map must skip the scan",
    );
    use pem::coordinator::{Policy, Scheduler, ServiceId};
    use pem::partition::{MatchTask, PartitionId};
    let n = 100_000u32;
    let mk_tasks = || -> Vec<MatchTask> {
        (0..n)
            .map(|i| MatchTask {
                id: i,
                left: PartitionId(i % 97),
                right: PartitionId((i * 31) % 97),
            })
            .collect()
    };
    println!("oversize map  drain time    per pull");
    for poison in [false, true] {
        let mut s = Scheduler::new(mk_tasks(), Policy::Fifo);
        s.add_service(ServiceId(0));
        s.add_service(ServiceId(1));
        if poison {
            // one rejection by the *other* service: every pull by
            // service 0 now pays the exclusion scan
            let t = s.next_task(ServiceId(1)).expect("task");
            s.reject_task(ServiceId(1), t.id);
        }
        let t0 = std::time::Instant::now();
        let mut pulled = 0u64;
        while let Some(t) = s.next_task(ServiceId(0)) {
            s.report_complete(ServiceId(0), t.id, vec![]);
            pulled += 1;
        }
        let el = t0.elapsed().as_nanos() as u64;
        snap.push(pem::bench::point(
            format!(
                "scheduler_drain/oversize_map={}",
                if poison { "populated" } else { "empty" }
            ),
            el,
        ));
        println!(
            "{:>11}  {:>11}  {:>7.0} ns",
            if poison { "1 entry" } else { "empty" },
            fmt_nanos(el),
            el as f64 / pulled.max(1) as f64,
        );
    }
    println!(
        "\n(one recorded rejection — against the *other* service — \
         makes the map non-empty, forcing the exclusion scan on every \
         pull; the delta between the rows is what the normal-case \
         fast path avoids)"
    );
    // ------------------------------------------------ reactor idle cost
    // PR 8's tentpole claim: a parked reactor costs ~nothing while k
    // connections sit open.  The pre-PR-8 loop spun on a 500 µs tick, so
    // an idle interval accumulated wall-clock-order wakeups and
    // visible CPU; parked in the kernel, both deltas stay near zero.
    pem::bench::report_header(
        "Reactor idle cost — parked event loop with k open connections",
        "reactor.busy_ns / reactor.wakeups deltas over an idle interval",
    );
    let store = Arc::new(DataService::build(&data.dataset, &parts));
    let idle_ms: u64 = if common::paper_scale() { 2_000 } else { 400 };
    println!("conns  idle wall  busy cpu     wakeups");
    for k in [1usize, 8] {
        let srv = DataServiceServer::start(store.clone(), "127.0.0.1:0")
            .expect("data server");
        let mut conns: Vec<Transport> = (0..k)
            .map(|_| {
                Transport::connect(srv.addr(), Duration::from_secs(5))
                    .expect("connect")
            })
            .collect();
        for c in conns.iter_mut() {
            let reply =
                c.request(&Message::StatsRequest).expect("stats round trip");
            assert!(matches!(reply, Message::StatsReport { .. }));
        }
        let s0 = srv.stats();
        let busy0 = s0.gauge("reactor.busy_ns").unwrap_or(0);
        let wake0 = s0.counter("reactor.wakeups").unwrap_or(0);
        std::thread::sleep(Duration::from_millis(idle_ms));
        // one probe round trip wakes the reactor so it refreshes the
        // busy_ns gauge; it adds a single wakeup to the delta
        let _ = conns[0].request(&Message::StatsRequest).expect("probe");
        let s1 = srv.stats();
        let busy_ns = s1
            .gauge("reactor.busy_ns")
            .unwrap_or(0)
            .saturating_sub(busy0);
        let wakeups = s1
            .counter("reactor.wakeups")
            .unwrap_or(0)
            .saturating_sub(wake0);
        snap.push(pem::bench::point(
            format!("reactor_idle/conns={k}/busy_ns"),
            busy_ns,
        ));
        snap.push(pem::bench::point(
            format!("reactor_idle/conns={k}/wakeups"),
            wakeups,
        ));
        println!(
            "{:>5}  {:>9}  {:>11}  {:>7}",
            k,
            fmt_nanos(idle_ms * 1_000_000),
            fmt_nanos(busy_ns),
            wakeups,
        );
        srv.shutdown();
    }
    println!(
        "\n(the pre-PR-8 spin loop woke ~2000×/s regardless of load; a \
         parked reactor's wakeups here are the probe plus fallback \
         ticks, and its busy CPU is noise)"
    );

    // --------------------------------------------- zero-copy fetch path
    // Throughput of repeated fetches of one partition over one
    // connection: the server serves the Arc-cached frame with a
    // vectored header+payload write, no per-fetch payload copy.
    pem::bench::report_header(
        "Zero-copy partition fetch — repeated fetch, one connection",
        "server writes the cached frame by Arc; ns and MiB/s per fetch",
    );
    let srv = DataServiceServer::start(store.clone(), "127.0.0.1:0")
        .expect("data server");
    let mut c = Transport::connect(srv.addr(), Duration::from_secs(5))
        .expect("connect");
    let fetch_id = parts.iter().next().expect("partitions").id;
    let reply = c
        .request(&Message::FetchPartition { id: fetch_id })
        .expect("warm fetch");
    assert!(matches!(reply, Message::Partition { .. }));
    let iters = common::scaled(2_000).max(200) as u64;
    let t0 = std::time::Instant::now();
    let mut wire_bytes = 0u64;
    for _ in 0..iters {
        c.send(&Message::FetchPartition { id: fetch_id })
            .expect("send fetch");
        let raw = c.recv_raw().expect("fetch reply");
        wire_bytes += raw.len() as u64 + 4;
    }
    let el = t0.elapsed().as_nanos() as u64;
    let ns_per_fetch = el / iters.max(1);
    snap.push(pem::bench::point(
        "fetch_throughput/ns_per_fetch",
        ns_per_fetch,
    ));
    let mibps = if el > 0 {
        wire_bytes as f64 / (1024.0 * 1024.0) / (el as f64 / 1e9)
    } else {
        0.0
    };
    println!(
        "{iters} fetches of {} in {}: {} per fetch, {mibps:.0} MiB/s",
        fmt_bytes(wire_bytes / iters.max(1)),
        fmt_nanos(el),
        fmt_nanos(ns_per_fetch),
    );
    srv.shutdown();

    // --------------------------------------------- assignment latency
    // Control-plane tail latency: the Complete→TaskAssign round trip
    // a match node pays per task, drained through a real workflow
    // server with the reactor parked between frames.
    pem::bench::report_header(
        "Assignment tail latency — Complete→TaskAssign round trips",
        "one puller drains the task list; p50/p99 over all round trips",
    );
    let rtt_tasks: Vec<MatchTask> = (0..common::scaled(2_000).max(100) as u32)
        .map(|i| MatchTask {
            id: i,
            left: PartitionId(i % 97),
            right: PartitionId((i * 31) % 97),
        })
        .collect();
    let n_rtt_tasks = rtt_tasks.len();
    let wf = WorkflowServiceServer::start(
        rtt_tasks,
        WorkflowServerConfig::default(),
        "127.0.0.1:0",
    )
    .expect("workflow server");
    let mut c = Transport::connect(wf.addr(), Duration::from_secs(5))
        .expect("connect");
    let joined = c
        .request(&Message::Join {
            name: "bench-puller".into(),
            version: PROTOCOL_VERSION,
            mem_budget: 0,
        })
        .expect("join");
    let Message::JoinAck { service, .. } = joined else {
        panic!("expected JoinAck, got {}", joined.kind());
    };
    let mut samples: Vec<u64> = Vec::with_capacity(n_rtt_tasks);
    let mut next = c
        .request(&Message::TaskRequest { service })
        .expect("first pull");
    loop {
        match next {
            Message::TaskAssign { task, .. } => {
                let t0 = std::time::Instant::now();
                next = c
                    .request(&Message::Complete {
                        service,
                        task_id: task.id,
                        comparisons: 0,
                        cached: vec![],
                        matches: vec![],
                    })
                    .expect("complete round trip");
                samples.push(t0.elapsed().as_nanos() as u64);
            }
            Message::NoTask { .. } => break,
            other => panic!("unexpected {}", other.kind()),
        }
    }
    wf.abort();
    samples.sort_unstable();
    let pct = |q: f64| -> u64 {
        samples[((samples.len() - 1) as f64 * q) as usize]
    };
    let (p50, p99) = (pct(0.50), pct(0.99));
    snap.push(pem::bench::point("assign_rtt/p50", p50));
    snap.push(pem::bench::point("assign_rtt/p99", p99));
    println!(
        "{} round trips: p50 {}, p99 {}",
        samples.len(),
        fmt_nanos(p50),
        fmt_nanos(p99),
    );

    // ------------------------------------------- out-of-core store
    // PR 9's tentpole: the spill tier trades RAM for disk.  Two
    // price tags matter — the cold *fault* (read + fnv1a verify +
    // decode of a spill file when the hot set missed) and the hot
    // *hit* (an Arc clone of the resident payload).  A tiny byte
    // budget makes every round-robin fetch fault; an uncapped one
    // makes every fetch after warm-up a hit.
    pem::bench::report_header(
        "Out-of-core store — spill-fault latency vs hot-hit throughput",
        "SpillStore fetch: cold = checksummed file re-read, hot = Arc",
    );
    use pem::store::SpillStore;
    let spill_parts = partition_size_based(&ids, m);
    let part_ids: Vec<PartitionId> =
        spill_parts.iter().map(|p| p.id).collect();
    let spill_iters = common::scaled(2_000).max(200) as u64;
    println!("mode   budget     fetches  per fetch    throughput");
    for (mode, budget) in [("fault", 1u64), ("hot", u64::MAX)] {
        let svc = DataService::build_with(
            &data.dataset,
            &spill_parts,
            Arc::new(SpillStore::new(budget, None).expect("spill dir")),
        )
        .expect("spill store load");
        // warm-up pass: the hot run must start with the set resident
        for &p in &part_ids {
            svc.fetch(p).expect("warm fetch");
        }
        let before = svc.store_stats();
        let t0 = std::time::Instant::now();
        let mut bytes = 0u64;
        for i in 0..spill_iters {
            let p = part_ids[(i % part_ids.len() as u64) as usize];
            bytes += svc.fetch(p).expect("bench fetch").approx_bytes;
        }
        let el = t0.elapsed().as_nanos() as u64;
        let st = svc.store_stats();
        match mode {
            "fault" => assert!(
                st.faults > before.faults,
                "1-byte budget must fault on every rotation"
            ),
            _ => assert_eq!(
                st.faults, before.faults,
                "uncapped budget must never fault after warm-up"
            ),
        }
        let ns_per = el / spill_iters.max(1);
        snap.push(pem::bench::point(
            format!("store/spill_{mode}_ns_per_fetch"),
            ns_per,
        ));
        let mibps = if el > 0 {
            bytes as f64 / (1024.0 * 1024.0) / (el as f64 / 1e9)
        } else {
            0.0
        };
        println!(
            "{:>5}  {:>9}  {:>7}  {:>9}  {:>8.0} MiB/s",
            mode,
            if budget == u64::MAX {
                "uncapped".to_string()
            } else {
                fmt_bytes(budget)
            },
            spill_iters,
            fmt_nanos(ns_per),
            mibps,
        );
    }
    println!(
        "\n(the fault row re-reads, re-verifies, and re-decodes a spill \
         file per fetch; the hot row is the Arc-clone fast path — the \
         delta is what the byte budget buys back per access)"
    );

    pem::bench::write_json_snapshot("dist_overhead", &snap)
        .expect("bench snapshot");
}
