//! Figure 8: speedup on the small match problem, scaling to 4 nodes /
//! 16 cores, size-based vs blocking-based partitioning, WAM and LRM.
//!
//! Expected shape: near-linear speedup to 16 cores (up to ~14×) for
//! *both* partitioning strategies; blocking-based is faster in absolute
//! time; LRM consistently slower than WAM.

mod common;

use pem::coordinator::{run_workflow, WorkflowConfig};
use pem::matching::StrategyKind;
use pem::metrics::speedups;
use pem::util::fmt_nanos;

fn main() {
    pem::bench::report_header(
        "Figure 8 — speedup, small problem, 1..16 cores",
        "near-linear to 16 cores (~14x) for both partitionings; WAM < LRM time",
    );
    let data = common::small_problem();
    let cores_list = [1usize, 2, 4, 8, 12, 16];
    let (cost_wam, cost_lrm) = common::calibrated(&data);
    let mut snap = Vec::new();

    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        let cost = if kind == StrategyKind::Wam { cost_wam } else { cost_lrm };
        for (pname, cfg) in [
            ("size-based", WorkflowConfig::size_based(kind)),
            ("blocking-based", WorkflowConfig::blocking_based(kind)),
        ] {
            let mut cfg = cfg.with_cost(cost);
            // scale tuning bounds with the dataset
            scale_partitioning(&mut cfg, kind);
            println!("strategy {} / {pname}", kind.name());
            println!("cores  time          speedup  tasks");
            let mut times = Vec::new();
            for &cores in &cores_list {
                let ce = common::testbed(cores);
                common::apply_net(&mut cfg);
            let out = run_workflow(&data, &cfg, &ce).expect("workflow");
                times.push(out.metrics.makespan_ns);
                snap.push(pem::bench::point(
                    format!("{}/{pname}/cores={cores}", kind.name()),
                    out.metrics.makespan_ns,
                ));
                let s = speedups(&times);
                println!(
                    "{:>5}  {:>12}  {:>7.2}  {}",
                    cores,
                    fmt_nanos(out.metrics.makespan_ns),
                    s.last().unwrap(),
                    out.n_tasks
                );
            }
            println!();
        }
    }
    pem::bench::write_json_snapshot("fig8_scaleout_small", &snap)
        .expect("bench snapshot");
}

fn scale_partitioning(cfg: &mut WorkflowConfig, kind: StrategyKind) {
    use pem::coordinator::workflow::{default_max_size, default_min_size};
    use pem::coordinator::PartitioningChoice;
    match &mut cfg.partitioning {
        PartitioningChoice::SizeBased { max_size } => {
            *max_size = Some(common::scaled(default_max_size(kind)));
        }
        PartitioningChoice::BlockingBased {
            max_size, min_size, ..
        } => {
            *max_size = Some(common::scaled(default_max_size(kind)));
            *min_size = common::scaled(default_min_size(kind));
        }
    }
}
