//! Figure 7: influence of the minimum partition size (partition tuning).
//!
//! Paper setup: small problem, blocking on the **manufacturer**
//! attribute, 1 node / 4 threads, max partition size 1000 (WAM) / 500
//! (LRM), minimum partition size swept 1–700.  Expected shape: merging
//! small blocks sharply cuts the number of match tasks and execution
//! time, especially for LRM (more tasks due to the smaller max size);
//! beyond a favorable minimum (200 WAM / 100 LRM) gains flatten or
//! reverse (aggregation introduces unnecessary comparisons).

mod common;

use pem::blocking::BlockingMethod;
use pem::cluster::ComputingEnv;
use pem::coordinator::{run_workflow, PartitioningChoice, WorkflowConfig};
use pem::matching::StrategyKind;
use pem::util::fmt_nanos;

fn main() {
    pem::bench::report_header(
        "Figure 7 — influence of the minimum partition size",
        "merging small blocks cuts tasks/overhead; flattens past ~200/100",
    );
    let data = common::small_problem();
    let ce = ComputingEnv::new(1, 4, common::node_mem());
    let mins: Vec<usize> = [1usize, 50, 100, 200, 300, 500, 700]
        .iter()
        .map(|&s| if s == 1 { 1 } else { common::scaled(s) })
        .collect();

    let (cost_wam, cost_lrm) = common::calibrated(&data);
    let mut snap = Vec::new();
    for (kind, max) in
        [(StrategyKind::Wam, 1000), (StrategyKind::Lrm, 500)]
    {
        let max = common::scaled(max);
        println!("strategy {} (max={max}, blocking=manufacturer)", kind.name());
        println!("min      time          tasks  comparisons(model)");
        for &min in &mins {
            if min > max {
                continue;
            }
            let mut cfg = WorkflowConfig::blocking_based(kind).with_cost(
                if kind == StrategyKind::Wam { cost_wam } else { cost_lrm },
            );
            cfg.partitioning = PartitioningChoice::BlockingBased {
                method: BlockingMethod::manufacturer(),
                max_size: Some(max),
                min_size: min,
            };
            common::apply_net(&mut cfg);
            let out = run_workflow(&data, &cfg, &ce).expect("workflow");
            snap.push(pem::bench::point(
                format!("{}/min={min}", kind.name()),
                out.metrics.makespan_ns,
            ));
            println!(
                "{:>5}  {:>12}  {:>5}  {:>12}",
                min,
                fmt_nanos(out.metrics.makespan_ns),
                out.n_tasks,
                out.metrics.comparisons,
            );
        }
        println!();
    }
    pem::bench::write_json_snapshot("fig7_min_partition", &snap)
        .expect("bench snapshot");
}
