//! Tables 1 & 2: caching + affinity-based scheduling on the large
//! match problem (blocking-based partitioning).
//!
//! Paper setup: large problem; 306 partitions incl. 7 misc; cache
//! capacity c = 16 partitions per match node (~5% of input); cores 1, 2,
//! 4, 8, 12, 16.  Reported: t_nc (no cache), t_c (cache), Δ, Δ/t_nc and
//! the hit ratio `hr`.  Expected shape: hr ≈ 76–83%, improvements
//! ~10–26% (largest at 1 core), similar speedup with and without cache.

mod common;

use pem::coordinator::{run_workflow, WorkflowConfig};
use pem::matching::StrategyKind;
use pem::util::stats::Table;

const CACHE_CAPACITY: usize = 16;

fn main() {
    pem::bench::report_header(
        "Tables 1 & 2 — execution times with/without partition caching",
        "hr 76-83%, Δ/t_nc ≈ 10-26%, best at 1 core",
    );
    let data = common::large_problem();
    let cores_list = [1usize, 2, 4, 8, 12, 16];
    let (cost_wam, cost_lrm) = common::calibrated(&data);

    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        let mut base = WorkflowConfig::blocking_based(kind).with_cost(
            if kind == StrategyKind::Wam { cost_wam } else { cost_lrm },
        );
        if !common::paper_scale() {
            use pem::coordinator::workflow::{
                default_max_size, default_min_size,
            };
            use pem::coordinator::PartitioningChoice;
            if let PartitioningChoice::BlockingBased {
                max_size,
                min_size,
                ..
            } = &mut base.partitioning
            {
                *max_size = Some(common::scaled(default_max_size(kind)));
                *min_size = common::scaled(default_min_size(kind));
            }
        }

        let mut table = Table::new(vec![
            "cores", "t_nc(min)", "t_c(min)", "delta", "delta/t_nc", "hr",
        ]);
        for &cores in &cores_list {
            let ce = common::testbed(cores);
            common::apply_net(&mut base);
            let nc = run_workflow(&data, &base.clone().with_cache(0), &ce)
                .expect("nc");
            let c = run_workflow(
                &data,
                &base.clone().with_cache(CACHE_CAPACITY),
                &ce,
            )
            .expect("c");
            let t_nc = common::as_min(nc.metrics.makespan_ns);
            let t_c = common::as_min(c.metrics.makespan_ns);
            table.row(vec![
                format!("{cores}"),
                format!("{t_nc:.2}"),
                format!("{t_c:.2}"),
                format!("{:.2}", t_nc - t_c),
                format!("{:.0}%", 100.0 * (t_nc - t_c) / t_nc.max(1e-12)),
                format!("{:.0}%", 100.0 * c.metrics.hit_ratio()),
            ]);
        }
        println!(
            "Table {} — {} (c = {CACHE_CAPACITY})",
            if kind == StrategyKind::Wam { 1 } else { 2 },
            kind.name()
        );
        println!("{}", table.render());
    }
}
