//! Ablation: affinity-based scheduling vs plain FIFO at fixed cache size.
//!
//! The paper attributes its high hit ratios (§5.4) to the combination of
//! the small misc-block set *and* affinity routing.  This ablation holds
//! the cache fixed (c=16) and toggles only the scheduling policy.

mod common;

use pem::coordinator::{run_workflow, Policy, WorkflowConfig};
use pem::matching::StrategyKind;
use pem::util::fmt_nanos;

fn main() {
    pem::bench::report_header(
        "Ablation — affinity scheduling vs FIFO (c = 16)",
        "affinity should raise hr and cut bytes fetched",
    );
    let data = common::large_problem();
    let (cost_wam, cost_lrm) = common::calibrated(&data);

    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        println!("strategy {}", kind.name());
        println!("policy    cores  time          hr     bytes-fetched  affinity-assignments");
        for policy in [Policy::Fifo, Policy::Affinity] {
            for cores in [4usize, 16] {
                let mut cfg = WorkflowConfig::blocking_based(kind)
                    .with_cache(16)
                    .with_cost(if kind == StrategyKind::Wam {
                        cost_wam
                    } else {
                        cost_lrm
                    });
                common_scale(&mut cfg, kind);
                cfg.policy = policy;
                let ce = common::testbed(cores);
                common::apply_net(&mut cfg);
            let out = run_workflow(&data, &cfg, &ce).expect("workflow");
                println!(
                    "{:<9} {:>5}  {:>12}  {:>4.0}%  {:>13}  {}",
                    format!("{policy:?}"),
                    cores,
                    fmt_nanos(out.metrics.makespan_ns),
                    out.metrics.hit_ratio() * 100.0,
                    out.metrics.bytes_fetched,
                    out.metrics.affinity_hits,
                );
            }
        }
        println!();
    }
}

fn common_scale(cfg: &mut WorkflowConfig, kind: StrategyKind) {
    use pem::coordinator::workflow::{default_max_size, default_min_size};
    use pem::coordinator::PartitioningChoice;
    if !common::paper_scale() {
        if let PartitioningChoice::BlockingBased {
            max_size, min_size, ..
        } = &mut cfg.partitioning
        {
            *max_size = Some(common::scaled(default_max_size(kind)));
            *min_size = common::scaled(default_min_size(kind));
        }
    }
}
