//! Ablation: pure-Rust matchers vs the accelerated PJRT path.
//!
//! Compares per-task latency of the RustExecutor (exact matchers) with
//! the PjrtExecutor (AOT-compiled XLA module whose hot loop is the
//! Pallas similarity kernel under interpret=True) and reports their
//! match-decision agreement.  Skips gracefully when `make artifacts`
//! has not been run.

mod common;

use pem::bench::Bencher;
use pem::datagen::GeneratorConfig;
use pem::matching::{MatchStrategy, StrategyKind};
use pem::model::EntityId;
use pem::partition::partition_size_based;
use pem::runtime::{default_artifact_dir, MatchEngine, PjrtExecutor};
use pem::store::DataService;
use pem::worker::{RustExecutor, TaskExecutor};
use std::sync::Arc;

fn main() {
    pem::bench::report_header(
        "Ablation — Rust matchers vs accelerated PJRT path",
        "same decisions; latency comparison per 64x64 match task",
    );
    let dir = default_artifact_dir();
    let engine = match MatchEngine::new(&dir) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!(
                "skipping: artifacts not available ({e:#}); run `make artifacts`"
            );
            return;
        }
    };

    let data = GeneratorConfig::tiny().with_entities(128).generate();
    let ids: Vec<EntityId> =
        data.dataset.entities.iter().map(|e| e.id).collect();
    let parts = partition_size_based(&ids, 64);
    let store = DataService::build(&data.dataset, &parts);
    let p0 = store.fetch(pem::partition::PartitionId(0)).unwrap();
    let p1 = store.fetch(pem::partition::PartitionId(1)).unwrap();

    let mut b = Bencher::default();
    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        let strategy = MatchStrategy::new(kind);
        let rust = RustExecutor::new(strategy);
        let pjrt = PjrtExecutor::new(engine.clone(), strategy);

        // intra-partition: injected duplicates are id-adjacent, so the
        // agreement check needs p0 × p0
        let r_rust = rust.execute(&p0, &p0, true);
        let r_pjrt = pjrt.execute(&p0, &p0, true);
        let set = |cs: &[pem::model::Correspondence]| {
            cs.iter().map(|c| c.pair()).collect::<std::collections::HashSet<_>>()
        };
        let (sr, sp) = (set(&r_rust), set(&r_pjrt));
        let inter = sr.intersection(&sp).count();
        let union = sr.union(&sp).count().max(1);
        println!(
            "{}: rust={} pjrt={} decision-jaccard={:.2}",
            kind.name(),
            sr.len(),
            sp.len(),
            inter as f64 / union as f64
        );

        b.bench(&format!("{}/rust 64x64 task", kind.name()), || {
            std::hint::black_box(rust.execute(&p0, &p1, false));
        });
        b.bench(&format!("{}/pjrt 64x64 task", kind.name()), || {
            std::hint::black_box(pjrt.execute(&p0, &p1, false));
        });
    }
}
