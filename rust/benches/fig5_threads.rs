//! Figure 5: execution time and speedup per multiprocessor node.
//!
//! Paper setup: small problem (20k), size-based partitioning, m = 500,
//! one 4-core node, 1–8 match threads, strategies WAM and LRM.
//! Expected shape: WAM near-linear to 4 threads (≈3.5×), LRM ≈2.5×;
//! beyond 4 threads WAM gains marginally, LRM not at all.

mod common;

use pem::cluster::ComputingEnv;
use pem::coordinator::workflow::EngineChoice;
use pem::coordinator::{run_workflow, PartitioningChoice, WorkflowConfig};
use pem::matching::StrategyKind;
use pem::metrics::speedups;
use pem::util::fmt_nanos;

fn main() {
    pem::bench::report_header(
        "Figure 5 — speedup vs #threads on one node",
        "WAM ~3.5x at 4 threads, LRM ~2.5x; little beyond 4 threads",
    );
    let data = common::small_problem();
    let m = common::scaled(500);
    let (cost_wam, cost_lrm) = common::calibrated(&data);
    let mut snap = Vec::new();

    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        let mut cfg = WorkflowConfig::size_based(kind).with_cost(
            if kind == StrategyKind::Wam { cost_wam } else { cost_lrm },
        );
        cfg.partitioning = PartitioningChoice::SizeBased { max_size: Some(m) };
        cfg.engine = EngineChoice::Simulated;
        println!("strategy {} (m={m})", kind.name());
        println!("threads  time          speedup");
        let mut times = Vec::new();
        for threads in 1..=8 {
            let ce = ComputingEnv::new(1, 4, common::node_mem()).with_threads(threads);
            common::apply_net(&mut cfg);
            let out = run_workflow(&data, &cfg, &ce).expect("workflow");
            times.push(out.metrics.makespan_ns);
            snap.push(pem::bench::point(
                format!("{}/threads={threads}", kind.name()),
                out.metrics.makespan_ns,
            ));
            let s = speedups(&times);
            println!(
                "{:>7}  {:>12}  {:>7.2}",
                threads,
                fmt_nanos(out.metrics.makespan_ns),
                s.last().unwrap()
            );
        }
        let s = speedups(&times);
        // shape assertions (soft): parallel speedup at 4 threads, WAM > LRM
        println!(
            "=> speedup@4 = {:.2}, speedup@8 = {:.2}\n",
            s[3], s[7]
        );
    }
    pem::bench::write_json_snapshot("fig5_threads", &snap)
        .expect("bench snapshot");
}
