//! The computing environment `CE = (#nodes, #cores, max_mem)` (paper §2)
//! and its simulated realization.
//!
//! The paper assumes loosely coupled homogeneous nodes sharing the input
//! via a central data service.  This module only *describes* the
//! environment; execution is handled by [`crate::engine`] — either on
//! real OS threads (bounded by this host's cores) or on the
//! deterministic virtual-time simulator, which can model any `CE`
//! (see DESIGN.md §Substitutions: this host has a single core, so the
//! 16-core scale-out experiments run on the simulator with calibrated
//! per-pair costs).

/// Description of the computing environment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ComputingEnv {
    /// Number of loosely coupled match nodes.
    pub nodes: usize,
    /// Cores per node (homogeneous; see [`HeterogeneousEnv`] otherwise).
    pub cores_per_node: usize,
    /// Main memory per node, in bytes, shared by the node's cores.
    pub max_mem: u64,
    /// Match threads per node. Usually == cores (the paper's default);
    /// Fig 5 varies this from 1 to 8 on a 4-core node.
    pub threads_per_node: usize,
}

impl ComputingEnv {
    pub fn new(nodes: usize, cores_per_node: usize, max_mem: u64) -> ComputingEnv {
        assert!(nodes >= 1 && cores_per_node >= 1 && max_mem > 0);
        ComputingEnv {
            nodes,
            cores_per_node,
            max_mem,
            threads_per_node: cores_per_node,
        }
    }

    /// Override the number of match threads per node (Fig 5: 1..8 threads
    /// on a 4-core node).
    pub fn with_threads(mut self, threads_per_node: usize) -> Self {
        assert!(threads_per_node >= 1);
        self.threads_per_node = threads_per_node;
        self
    }

    /// The paper's evaluation testbed: up to 4 match nodes, 4 cores each,
    /// 3 GB heap per node → `CE = (4, 4, 3GB)`.
    pub fn paper_testbed(nodes: usize) -> ComputingEnv {
        ComputingEnv::new(nodes, 4, 3 * crate::util::GIB)
    }

    /// Total match threads in the environment.
    pub fn total_threads(&self) -> usize {
        self.nodes * self.threads_per_node
    }

    /// Total cores in the environment.
    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }

    /// Memory budget per match thread (drives partition sizing and the
    /// paging model).
    pub fn mem_per_thread(&self) -> u64 {
        self.max_mem / self.threads_per_node as u64
    }
}

/// Heterogeneous environments (paper §2: “the model can easily be
/// extended”): per-node specs with a speed factor.  The scheduler's
/// pull-based design load-balances across them without changes.
#[derive(Clone, Debug)]
pub struct HeterogeneousEnv {
    pub nodes: Vec<NodeSpec>,
}

/// One node's capabilities.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeSpec {
    pub cores: usize,
    pub max_mem: u64,
    pub threads: usize,
    /// Relative speed: 1.0 = the calibrated reference; 0.5 = half speed.
    pub speed: f64,
}

impl NodeSpec {
    pub fn uniform(ce: &ComputingEnv) -> NodeSpec {
        NodeSpec {
            cores: ce.cores_per_node,
            max_mem: ce.max_mem,
            threads: ce.threads_per_node,
            speed: 1.0,
        }
    }
}

impl HeterogeneousEnv {
    pub fn uniform(ce: &ComputingEnv) -> HeterogeneousEnv {
        HeterogeneousEnv {
            nodes: vec![NodeSpec::uniform(ce); ce.nodes],
        }
    }

    pub fn total_threads(&self) -> usize {
        self.nodes.iter().map(|n| n.threads).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::GIB;

    #[test]
    fn paper_testbed_shape() {
        let ce = ComputingEnv::paper_testbed(4);
        assert_eq!(ce.nodes, 4);
        assert_eq!(ce.cores_per_node, 4);
        assert_eq!(ce.max_mem, 3 * GIB);
        assert_eq!(ce.total_threads(), 16);
        assert_eq!(ce.total_cores(), 16);
    }

    #[test]
    fn thread_override() {
        let ce = ComputingEnv::paper_testbed(1).with_threads(8);
        assert_eq!(ce.total_threads(), 8);
        assert_eq!(ce.total_cores(), 4);
        assert_eq!(ce.mem_per_thread(), 3 * GIB / 8);
    }

    #[test]
    fn heterogeneous_from_uniform() {
        let ce = ComputingEnv::paper_testbed(3);
        let h = HeterogeneousEnv::uniform(&ce);
        assert_eq!(h.nodes.len(), 3);
        assert_eq!(h.total_threads(), 12);
        assert!(h.nodes.iter().all(|n| (n.speed - 1.0).abs() < 1e-12));
    }

    #[test]
    #[should_panic]
    fn zero_nodes_rejected() {
        ComputingEnv::new(0, 4, GIB);
    }
}
