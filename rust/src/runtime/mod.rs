//! The PJRT runtime: loads the AOT-compiled match executables and runs
//! them from the Layer-3 hot path.
//!
//! `make artifacts` (python, build-time only) lowers each match strategy
//! to HLO **text** per partition-capacity variant and writes
//! `artifacts/manifest.txt`.  This module:
//!
//! 1. parses the manifest ([`Manifest`]);
//! 2. compiles each needed artifact once on a `PjRtClient::cpu()` and
//!    caches the loaded executable ([`MatchEngine`]);
//! 3. exposes [`PjrtExecutor`] — a [`TaskExecutor`] that marshals the two
//!    partitions' hashed-q-gram feature matrices into `xla::Literal`s,
//!    executes the `f32[M,M]`-combined-similarity module, and extracts
//!    correspondences above the decision threshold.
//!
//! Python never runs at match time: the artifacts are self-contained HLO.
//!
//! **Feature gating:** the PJRT bridge needs the vendored `xla` crate
//! and a `libxla_extension` install, so it sits behind the **`xla`**
//! cargo feature.  The default (std-only) build keeps the same public
//! API — [`MatchEngine::new`] then returns an error, and everything
//! that probes for the accelerated path (tests, benches, examples,
//! `pem artifacts --smoke`) skips gracefully, exactly as it does when
//! `make artifacts` has not been run.

pub mod vmem;

use crate::features::DEFAULT_DIM;
use crate::matching::StrategyKind;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

pub use pjrt::{MatchEngine, PjrtExecutor};

/// One artifact entry from `manifest.txt`:
/// `name strategy capacity feature_dim n_params`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    pub strategy: StrategyKind,
    pub capacity: usize,
    pub feature_dim: usize,
    pub n_params: usize,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                bail!("manifest line {}: want 5 fields, got {}", lineno + 1, parts.len());
            }
            entries.push(ArtifactEntry {
                name: parts[0].to_string(),
                strategy: StrategyKind::parse(parts[1])
                    .ok_or_else(|| anyhow!("unknown strategy {:?}", parts[1]))?,
                capacity: parts[2].parse()?,
                feature_dim: parts[3].parse()?,
                n_params: parts[4].parse()?,
            });
        }
        Ok(Manifest {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Smallest-capacity artifact for `strategy` that fits `n` rows.
    pub fn pick(&self, strategy: StrategyKind, n: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.strategy == strategy && e.capacity >= n)
            .min_by_key(|e| e.capacity)
    }

    pub fn artifact_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.name)
    }
}

/// Default artifacts directory: `$PEM_ARTIFACTS` or `./artifacts`
/// relative to the workspace root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("PEM_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // try workspace-relative candidates (cwd may be rust/ under cargo)
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.txt").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// The real PJRT bridge (requires the vendored `xla` crate).
#[cfg(feature = "xla")]
mod pjrt {
    use super::Manifest;
    use crate::matching::{MatchStrategy, StrategyKind};
    use crate::model::Correspondence;
    use crate::store::PartitionData;
    use crate::worker::TaskExecutor;
    use anyhow::{anyhow, Result};
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    /// A compiled match executable (one artifact on one PJRT client).
    struct LoadedExec {
        exe: xla::PjRtLoadedExecutable,
        capacity: usize,
        feature_dim: usize,
    }

    /// PJRT client + compile cache for the match executables.
    ///
    /// The xla crate's handles are not `Sync`; the engine serializes
    /// compilation and execution behind one mutex (one executable runs at a
    /// time per engine — use one engine per match service for parallelism).
    pub struct MatchEngine {
        manifest: Manifest,
        inner: Mutex<EngineInner>,
    }

    struct EngineInner {
        client: xla::PjRtClient,
        cache: HashMap<String, LoadedExec>,
    }

    // SAFETY: all access to the non-Sync xla handles goes through the mutex.
    unsafe impl Send for MatchEngine {}
    unsafe impl Sync for MatchEngine {}

    impl MatchEngine {
        /// Create a CPU PJRT engine over the given artifact directory.
        pub fn new(artifact_dir: &Path) -> Result<MatchEngine> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
            Ok(MatchEngine {
                manifest,
                inner: Mutex::new(EngineInner {
                    client,
                    cache: HashMap::new(),
                }),
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Execute one match task on the accelerated path.
        ///
        /// Marshals both partitions' (title, description) feature
        /// matrices padded to the chosen artifact capacity, executes,
        /// and returns the dense `capacity × capacity`
        /// combined-similarity matrix (row-major; entries past the real
        /// row counts are zero by construction).
        pub fn run_pair(
            &self,
            strategy: StrategyKind,
            params: [f32; 4],
            left: &PartitionData,
            right: &PartitionData,
        ) -> Result<(Vec<f32>, usize)> {
            let n = left.len().max(right.len());
            let entry = self
                .manifest
                .pick(strategy, n)
                .ok_or_else(|| {
                    anyhow!(
                        "no artifact for {} with capacity >= {n}",
                        strategy.name()
                    )
                })?
                .clone();
            let mut inner = crate::util::lock_poisonless(&self.inner);
            if !inner.cache.contains_key(&entry.name) {
                let path = self.manifest.artifact_path(&entry);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().expect("utf8 path"),
                )
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = inner
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {}: {e:?}", entry.name))?;
                inner.cache.insert(
                    entry.name.clone(),
                    LoadedExec {
                        exe,
                        capacity: entry.capacity,
                        feature_dim: entry.feature_dim,
                    },
                );
            }
            let le = &inner.cache[&entry.name];
            let (cap, dim) = (le.capacity, le.feature_dim);

            let (a_title, a_desc) = left.feature_matrices(cap, dim);
            let (b_title, b_desc) = right.feature_matrices(cap, dim);
            let lit =
                |m: &crate::features::FeatureMatrix| -> Result<xla::Literal> {
                    xla::Literal::vec1(&m.data)
                        .reshape(&[cap as i64, dim as i64])
                        .map_err(|e| anyhow!("reshape: {e:?}"))
                };
            let params_lit = xla::Literal::vec1(&params);
            let inputs = [
                lit(&a_title)?,
                lit(&a_desc)?,
                lit(&b_title)?,
                lit(&b_desc)?,
                params_lit,
            ];
            let result = le
                .exe
                .execute::<xla::Literal>(&inputs)
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let out = result
                .to_tuple1()
                .map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
            let values = out
                .to_vec::<f32>()
                .map_err(|e| anyhow!("to_vec: {e:?}"))?;
            debug_assert_eq!(values.len(), cap * cap);
            Ok((values, cap))
        }
    }

    /// [`TaskExecutor`] over the accelerated PJRT path.
    pub struct PjrtExecutor {
        engine: std::sync::Arc<MatchEngine>,
        pub strategy: MatchStrategy,
    }

    impl PjrtExecutor {
        pub fn new(
            engine: std::sync::Arc<MatchEngine>,
            strategy: MatchStrategy,
        ) -> PjrtExecutor {
            PjrtExecutor { engine, strategy }
        }
    }

    impl TaskExecutor for PjrtExecutor {
        fn execute(
            &self,
            left: &PartitionData,
            right: &PartitionData,
            intra: bool,
        ) -> Vec<Correspondence> {
            let (sims, cap) = self
                .engine
                .run_pair(
                    self.strategy.kind,
                    self.strategy.params.values,
                    left,
                    right,
                )
                .expect("PJRT execution failed");
            let threshold = self.strategy.threshold as f32;
            let mut out = Vec::new();
            for i in 0..left.len() {
                let row = &sims[i * cap..i * cap + right.len()];
                let j0 = if intra { i + 1 } else { 0 };
                for (j, &sim) in row.iter().enumerate().skip(j0) {
                    if sim >= threshold
                        && left.entities[i] != right.entities[j]
                    {
                        out.push(Correspondence::new(
                            left.entities[i],
                            right.entities[j],
                            sim,
                        ));
                    }
                }
            }
            out
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

/// Stub used when the crate is built without the `xla` feature: same
/// API, but [`MatchEngine::new`] always fails, so every accelerated-path
/// consumer takes its existing "artifacts unavailable" skip path.
#[cfg(not(feature = "xla"))]
mod pjrt {
    use super::Manifest;
    use crate::matching::{MatchStrategy, StrategyKind};
    use crate::model::Correspondence;
    use crate::store::PartitionData;
    use crate::worker::TaskExecutor;
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Disabled accelerated engine ([`MatchEngine::new`] always errs).
    pub struct MatchEngine {
        manifest: Manifest,
    }

    impl MatchEngine {
        /// Always fails: the accelerated path needs the `xla` feature.
        pub fn new(_artifact_dir: &Path) -> Result<MatchEngine> {
            bail!(
                "accelerated PJRT path unavailable: pem was built without \
                 the `xla` cargo feature (it needs the vendored xla bridge \
                 crate and libxla_extension)"
            )
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Unreachable in practice — [`MatchEngine::new`] never
        /// succeeds without the `xla` feature.
        pub fn run_pair(
            &self,
            _strategy: StrategyKind,
            _params: [f32; 4],
            _left: &PartitionData,
            _right: &PartitionData,
        ) -> Result<(Vec<f32>, usize)> {
            bail!("accelerated PJRT path unavailable (no `xla` feature)")
        }
    }

    /// Disabled [`TaskExecutor`] counterpart (cannot be constructed in
    /// practice, since no [`MatchEngine`] ever exists).
    pub struct PjrtExecutor {
        engine: std::sync::Arc<MatchEngine>,
        pub strategy: MatchStrategy,
    }

    impl PjrtExecutor {
        pub fn new(
            engine: std::sync::Arc<MatchEngine>,
            strategy: MatchStrategy,
        ) -> PjrtExecutor {
            PjrtExecutor { engine, strategy }
        }
    }

    impl TaskExecutor for PjrtExecutor {
        fn execute(
            &self,
            left: &PartitionData,
            right: &PartitionData,
            _intra: bool,
        ) -> Vec<Correspondence> {
            // keep the stub honest if someone ever conjures one up
            let err = self
                .engine
                .run_pair(
                    self.strategy.kind,
                    self.strategy.params.values,
                    left,
                    right,
                )
                .expect_err("stub run_pair cannot succeed");
            panic!("PJRT execution failed: {err}")
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

/// Feature dimension consistency check (Rust ↔ aot.py).
pub fn expected_feature_dim() -> usize {
    DEFAULT_DIM
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_and_pick() {
        let text = "\
# comment
wam_m128_d256.hlo.txt wam 128 256 4
wam_m512_d256.hlo.txt wam 512 256 4
lrm_m128_d256.hlo.txt lrm 128 256 4
";
        let m = Manifest::parse(text, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(
            m.pick(StrategyKind::Wam, 100).unwrap().capacity,
            128
        );
        assert_eq!(
            m.pick(StrategyKind::Wam, 200).unwrap().capacity,
            512
        );
        assert!(m.pick(StrategyKind::Wam, 1000).is_none());
        assert_eq!(
            m.pick(StrategyKind::Lrm, 1).unwrap().name,
            "lrm_m128_d256.hlo.txt"
        );
        assert_eq!(
            m.artifact_path(&m.entries[0]),
            Path::new("/tmp/a/wam_m128_d256.hlo.txt")
        );
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("one two", Path::new(".")).is_err());
        assert!(
            Manifest::parse("x svm 128 256 4", Path::new(".")).is_err()
        );
    }

    #[test]
    fn dim_constant_matches_features() {
        assert_eq!(expected_feature_dim(), 256);
    }
}
