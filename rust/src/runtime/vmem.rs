//! Structural TPU performance estimates for the Layer-1 Pallas kernel.
//!
//! Mirrors `python/compile/kernels/similarity.py` (`vmem_footprint_bytes`,
//! `mxu_utilization_estimate`): the kernel runs under `interpret=True` on
//! CPU, so real-TPU numbers are *estimated* from the BlockSpec schedule —
//! peak VMEM per grid step and the MXU occupancy of the dot tile.  The
//! §Perf section of EXPERIMENTS.md sweeps these for candidate tiles.

/// Peak VMEM bytes for one grid step of the similarity kernel (f32):
/// two input strips + the broadcast-min intermediate + two output tiles.
pub fn vmem_footprint_bytes(tile_m: usize, tile_n: usize, d: usize) -> u64 {
    let strips = (tile_m + tile_n) * d;
    let broadcast = tile_m * tile_n * d;
    let outs = 2 * tile_m * tile_n;
    4 * (strips + broadcast + outs) as u64
}

/// Fraction of a 128×128 MXU the dot tile keeps busy (structural).
pub fn mxu_utilization_estimate(tile_m: usize, tile_n: usize, d: usize) -> f64 {
    let eff = |x: usize| (x.min(128) as f64) / 128.0;
    eff(tile_m) * eff(tile_n) * eff(d)
}

/// HBM traffic (bytes) to produce an `m × n` stats matrix with the tiled
/// schedule vs. the naive broadcast materialization — the kernel's whole
/// point (DESIGN.md §Hardware-Adaptation).
pub fn hbm_traffic_tiled(m: usize, n: usize, d: usize, tile_m: usize, tile_n: usize) -> u64 {
    // every output tile re-reads one (tile_m × d) strip of A and one
    // (tile_n × d) strip of B, and writes two (tile_m × tile_n) tiles
    let tiles = (m.div_ceil(tile_m)) * (n.div_ceil(tile_n));
    let per_tile = (tile_m + tile_n) * d + 2 * tile_m * tile_n;
    4 * (tiles * per_tile) as u64
}

pub fn hbm_traffic_naive(m: usize, n: usize, d: usize) -> u64 {
    // materializing the broadcast-min intermediate costs m·n·d
    4 * (m * n * d + 2 * m * n) as u64
}

/// A candidate BlockSpec with its estimates — for the §Perf sweep table.
#[derive(Clone, Copy, Debug)]
pub struct TileEstimate {
    pub tile_m: usize,
    pub tile_n: usize,
    pub d: usize,
    pub vmem_bytes: u64,
    pub mxu_utilization: f64,
    pub fits_vmem_16mib: bool,
}

pub fn estimate(tile_m: usize, tile_n: usize, d: usize) -> TileEstimate {
    let vmem = vmem_footprint_bytes(tile_m, tile_n, d);
    TileEstimate {
        tile_m,
        tile_n,
        d,
        vmem_bytes: vmem,
        mxu_utilization: mxu_utilization_estimate(tile_m, tile_n, d),
        fits_vmem_16mib: vmem <= 16 * crate::util::MIB,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    #[test]
    fn matches_python_formulas() {
        // values cross-checked against python/tests/test_kernel.py
        assert_eq!(
            vmem_footprint_bytes(32, 32, 256),
            4 * ((32 + 32) * 256 + 32 * 32 * 256 + 2 * 32 * 32)
        );
        assert!((mxu_utilization_estimate(128, 128, 128) - 1.0).abs() < 1e-12);
        assert!(mxu_utilization_estimate(32, 32, 256) < 1.0);
    }

    #[test]
    fn default_tile_fits_vmem() {
        let e = estimate(32, 32, 256);
        assert!(e.fits_vmem_16mib);
        assert!(e.vmem_bytes < 4 * MIB);
    }

    #[test]
    fn tiled_beats_naive_traffic_at_scale() {
        let tiled = hbm_traffic_tiled(1024, 1024, 256, 32, 32);
        let naive = hbm_traffic_naive(1024, 1024, 256);
        assert!(
            tiled < naive,
            "tiled {tiled} should be < naive {naive}"
        );
    }

    #[test]
    fn bigger_tiles_less_traffic_more_vmem() {
        let small = estimate(16, 16, 256);
        let big = estimate(64, 64, 256);
        assert!(big.vmem_bytes > small.vmem_bytes);
        assert!(
            hbm_traffic_tiled(512, 512, 256, 64, 64)
                < hbm_traffic_tiled(512, 512, 256, 16, 16)
        );
    }
}
