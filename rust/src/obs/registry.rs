//! Lock-cheap metrics registry: atomic counters, gauges and fixed-bucket
//! latency histograms, snapshottable into one serializable value.
//!
//! Every server (workflow, data, match node) owns a [`Registry`] and
//! hands out [`Counter`]/[`Gauge`]/[`Histogram`] handles at startup; the
//! hot paths then touch a single relaxed atomic — no locks, no string
//! lookups.  A [`MetricsSnapshot`] is a consistent-enough point-in-time
//! copy (each metric is read atomically; the set is not a global
//! transaction) that serializes with the same strict binary discipline
//! as `MatchPlan` (magic prefix, canonical field order, trailing-bytes
//! rejection) so it can cross the wire in a `StatsReport` frame and be
//! diffed or merged downstream.
//!
//! Histogram buckets are base-2: bucket 0 counts zero values, bucket
//! `i ≥ 1` counts values in `[2^(i-1), 2^i)`.  That makes merge a plain
//! element-wise sum — associative, commutative and lossless on counts,
//! property-tested below — which is what lets per-node snapshots be
//! folded into cluster totals in any order.

use crate::util::{fmt_nanos, read_poisonless, write_poisonless};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Magic prefix + format version of a serialized [`MetricsSnapshot`].
const STATS_MAGIC: &[u8; 8] = b"PEMSTAT\x01";

/// Number of histogram buckets: one zero bucket + one per power of two
/// up to `2^63`.
pub const HISTOGRAM_BUCKETS: usize = 65;

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// A counter at zero.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add `n` to the counter.
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (queue depth, live nodes, …).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    /// A gauge at zero.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Overwrite the value.
    #[inline]
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket base-2 histogram (see module docs for the bucket
/// boundaries).  `observe` is three relaxed atomic adds.
pub struct Histogram {
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count.load(Ordering::Relaxed))
            .field("sum", &self.sum.load(Ordering::Relaxed))
            .finish_non_exhaustive()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

/// Bucket index of a value: 0 for 0, else `1 + floor(log2(v))`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Inclusive lower bound of bucket `i`.
pub fn bucket_lower(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// Name of a per-tenant gauge on a resident coordinator (protocol
/// v7): `tenant.{id}.state`, `tenant.{id}.tasks_completed`,
/// `tenant.{id}.tasks_total`.  The one formatter shared by the
/// workflow server's emitters and the `pem stats` renderer, so the
/// two cannot drift apart.
pub fn tenant_gauge(id: u32, field: &str) -> String {
    format!("tenant.{id}.{field}")
}

/// Marker for a metric name that is *built* somewhere other than the
/// `Registry::counter`/`gauge`/`histogram` call that registers it
/// (e.g. the `store.*` names assembled inside
/// `PartitionStore::metrics` snapshots).  Identity at runtime; its
/// value is that `pem-lint`'s L4 metrics-conformance pass recognizes
/// the call site and cross-checks the literal against
/// `docs/OBSERVABILITY.md`.  Any new metric name that doesn't appear
/// literally inside an instrument call must pass through here or
/// [`tenant_gauge`], or L4 cannot see it.
#[inline]
pub const fn metric_name(name: &'static str) -> &'static str {
    name
}

impl Histogram {
    /// A histogram with all buckets empty.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one value.
    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Point-in-time copy of the histogram.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].load(Ordering::Relaxed)
            }),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Immutable copy of a [`Histogram`]; the unit that merges and
/// serializes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket counts (see [`bucket_lower`] for boundaries).
    pub buckets: [u64; HISTOGRAM_BUCKETS],
    /// Total observations.
    pub count: u64,
    /// Sum of observed values.
    pub sum: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot {
            buckets: [0; HISTOGRAM_BUCKETS],
            count: 0,
            sum: 0,
        }
    }
}

impl HistogramSnapshot {
    /// An empty snapshot (the merge identity).
    pub fn empty() -> HistogramSnapshot {
        HistogramSnapshot::default()
    }

    /// Element-wise sum of two snapshots.  Associative, commutative,
    /// and lossless on counts (property-tested below).
    pub fn merge(&self, other: &HistogramSnapshot) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i] + other.buckets[i]
            }),
            count: self.count + other.count,
            sum: self.sum + other.sum,
        }
    }

    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate quantile: the lower bound of the bucket containing
    /// the `q`-quantile observation (`0.0 ≤ q ≤ 1.0`).  Exact to
    /// within one power of two — enough for the p50/p99 lines `pem
    /// stats` prints.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let rank = rank.max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_lower(i);
            }
        }
        bucket_lower(HISTOGRAM_BUCKETS - 1)
    }

    /// One-line human summary (`pem stats` output).
    pub fn summary(&self) -> String {
        format!(
            "n={} mean {} p50≥{} p99≥{}",
            self.count,
            fmt_nanos(self.mean() as u64),
            fmt_nanos(self.quantile(0.50)),
            fmt_nanos(self.quantile(0.99)),
        )
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
    labels: BTreeMap<String, String>,
}

/// Named collection of metrics.  Registration takes a write lock;
/// handles returned by [`Registry::counter`] & co. are lock-free to
/// update, so hot paths register once and hold the `Arc`.
#[derive(Default)]
pub struct Registry {
    inner: RwLock<RegistryInner>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = read_poisonless(&self.inner);
        f.debug_struct("Registry")
            .field("counters", &inner.counters.len())
            .field("gauges", &inner.gauges.len())
            .field("histograms", &inner.histograms.len())
            .finish()
    }
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or register the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut inner = write_poisonless(&self.inner);
        Arc::clone(
            inner
                .counters
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Counter::new())),
        )
    }

    /// Get or register the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut inner = write_poisonless(&self.inner);
        Arc::clone(
            inner
                .gauges
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Gauge::new())),
        )
    }

    /// Get or register the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut inner = write_poisonless(&self.inner);
        Arc::clone(
            inner
                .histograms
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    /// Set a non-numeric label (role, addresses, …) carried on
    /// snapshots.
    pub fn set_label(&self, key: &str, value: &str) {
        write_poisonless(&self.inner)
            .labels
            .insert(key.to_string(), value.to_string());
    }

    /// Point-in-time copy of every registered metric, sorted by name.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = read_poisonless(&self.inner);
        MetricsSnapshot {
            counters: inner
                .counters
                .iter()
                .map(|(k, c)| (k.clone(), c.get()))
                .collect(),
            gauges: inner
                .gauges
                .iter()
                .map(|(k, g)| (k.clone(), g.get()))
                .collect(),
            histograms: inner
                .histograms
                .iter()
                .map(|(k, h)| (k.clone(), h.snapshot()))
                .collect(),
            labels: inner
                .labels
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
        }
    }
}

/// Serializable point-in-time copy of a [`Registry`]; what a
/// `StatsReport` frame carries and what `pem stats` renders.  Entries
/// are sorted by name, so equal registries snapshot to equal bytes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// `(name, value)` counters, name-sorted.
    pub counters: Vec<(String, u64)>,
    /// `(name, value)` gauges, name-sorted.
    pub gauges: Vec<(String, u64)>,
    /// `(name, snapshot)` histograms, name-sorted.
    pub histograms: Vec<(String, HistogramSnapshot)>,
    /// `(key, value)` labels, key-sorted.
    pub labels: Vec<(String, String)>,
}

// one set of codec primitives for all canonical binary formats
use crate::rpc::{put_str, put_u32, put_u64};

impl MetricsSnapshot {
    /// Counter value by name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
    }

    /// Gauge value by name.
    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
    }

    /// Histogram snapshot by name.
    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, h)| h)
    }

    /// Label value by key.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Merge two snapshots by name: counters and histogram buckets
    /// add, gauges take the maximum (a cluster-level "worst of"),
    /// labels union with `self` winning ties.  Inherits the histogram
    /// merge's associativity/commutativity on counters and histograms.
    pub fn merge(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        fn merge_by_name<T: Clone>(
            a: &[(String, T)],
            b: &[(String, T)],
            combine: impl Fn(&T, &T) -> T,
        ) -> Vec<(String, T)> {
            let mut out: BTreeMap<String, T> = a.iter().cloned().collect();
            for (k, v) in b {
                let merged = match out.get(k) {
                    Some(prev) => combine(prev, v),
                    None => v.clone(),
                };
                out.insert(k.clone(), merged);
            }
            out.into_iter().collect()
        }
        MetricsSnapshot {
            counters: merge_by_name(&self.counters, &other.counters, |a, b| {
                a + b
            }),
            gauges: merge_by_name(&self.gauges, &other.gauges, |a, b| {
                *a.max(b)
            }),
            histograms: merge_by_name(
                &self.histograms,
                &other.histograms,
                |a, b| a.merge(b),
            ),
            labels: merge_by_name(&self.labels, &other.labels, |a, _b| {
                a.clone()
            }),
        }
    }

    // ------------------------------------------------ serialization

    /// Serialize to the canonical byte format (same discipline as
    /// `MatchPlan::to_bytes`: magic prefix, LE fields, canonical
    /// order).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(
            64 + self.counters.len() * 24
                + self.histograms.len() * (24 + HISTOGRAM_BUCKETS * 8),
        );
        b.extend_from_slice(STATS_MAGIC);
        put_u32(&mut b, self.counters.len() as u32);
        for (k, v) in &self.counters {
            put_str(&mut b, k);
            put_u64(&mut b, *v);
        }
        put_u32(&mut b, self.gauges.len() as u32);
        for (k, v) in &self.gauges {
            put_str(&mut b, k);
            put_u64(&mut b, *v);
        }
        put_u32(&mut b, self.histograms.len() as u32);
        for (k, h) in &self.histograms {
            put_str(&mut b, k);
            put_u64(&mut b, h.count);
            put_u64(&mut b, h.sum);
            for &bucket in &h.buckets {
                put_u64(&mut b, bucket);
            }
        }
        put_u32(&mut b, self.labels.len() as u32);
        for (k, v) in &self.labels {
            put_str(&mut b, k);
            put_str(&mut b, v);
        }
        b
    }

    /// Deserialize a snapshot written by [`MetricsSnapshot::to_bytes`].
    /// Strict: bad magic, truncation or trailing bytes are errors.
    pub fn from_bytes(bytes: &[u8]) -> Result<MetricsSnapshot> {
        let mut d = StatsDec { buf: bytes, pos: 0 };
        let magic = d.take(STATS_MAGIC.len())?;
        if magic != STATS_MAGIC {
            bail!("not a pem stats snapshot (bad magic)");
        }
        let n = d.len(12)?;
        let mut counters = Vec::with_capacity(n);
        for _ in 0..n {
            let k = d.string()?;
            counters.push((k, d.u64()?));
        }
        let n = d.len(12)?;
        let mut gauges = Vec::with_capacity(n);
        for _ in 0..n {
            let k = d.string()?;
            gauges.push((k, d.u64()?));
        }
        let n = d.len(20 + HISTOGRAM_BUCKETS * 8)?;
        let mut histograms = Vec::with_capacity(n);
        for _ in 0..n {
            let k = d.string()?;
            let count = d.u64()?;
            let sum = d.u64()?;
            let mut buckets = [0u64; HISTOGRAM_BUCKETS];
            for bucket in buckets.iter_mut() {
                *bucket = d.u64()?;
            }
            histograms.push((
                k,
                HistogramSnapshot {
                    buckets,
                    count,
                    sum,
                },
            ));
        }
        let n = d.len(8)?;
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let k = d.string()?;
            labels.push((k, d.string()?));
        }
        d.finish()?;
        Ok(MetricsSnapshot {
            counters,
            gauges,
            histograms,
            labels,
        })
    }

    /// Render as one JSON object (hand-rolled; no serde offline).
    /// Histograms serialize as `{count, sum, buckets}` with trailing
    /// empty buckets trimmed.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        out.push_str("\"counters\":{");
        push_kv_u64(&mut out, &self.counters);
        out.push_str("},\"gauges\":{");
        push_kv_u64(&mut out, &self.gauges);
        out.push_str("},\"histograms\":{");
        for (i, (k, h)) in self.histograms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let last = h
                .buckets
                .iter()
                .rposition(|&c| c != 0)
                .map_or(0, |p| p + 1);
            out.push_str(&format!(
                "{}:{{\"count\":{},\"sum\":{},\"buckets\":[{}]}}",
                json_string(k),
                h.count,
                h.sum,
                h.buckets[..last]
                    .iter()
                    .map(|c| c.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            ));
        }
        out.push_str("},\"labels\":{");
        for (i, (k, v)) in self.labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{}:{}",
                json_string(k),
                json_string(v)
            ));
        }
        out.push_str("}}");
        out
    }
}

fn push_kv_u64(out: &mut String, kvs: &[(String, u64)]) {
    for (i, (k, v)) in kvs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("{}:{}", json_string(k), v));
    }
}

/// Minimal JSON string escaping (quotes, backslash, control chars).
pub(crate) fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct StatsDec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> StatsDec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!("truncated stats snapshot");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A count whose elements need at least `min_elem_bytes` each,
    /// validated against the remaining buffer before allocation.
    fn len(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_bytes) > self.buf.len() - self.pos {
            bail!("truncated stats snapshot (lying count)");
        }
        Ok(n)
    }

    fn string(&mut self) -> Result<String> {
        let n = self.len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow::anyhow!("stats string is not UTF-8"))
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "{} trailing bytes after stats snapshot",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::Rng;

    fn arbitrary_hist(rng: &mut Rng) -> HistogramSnapshot {
        let h = Histogram::new();
        for _ in 0..rng.gen_range(64) {
            // span many buckets: uniform exponent, uniform mantissa
            let shift = rng.gen_range(40) as u64;
            h.observe(rng.next_u64() >> (23 + shift % 41));
        }
        h.snapshot()
    }

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_lower(0), 0);
        // every bucket's lower bound maps back into that bucket
        for i in 1..HISTOGRAM_BUCKETS {
            assert_eq!(bucket_index(bucket_lower(i)), i);
        }
    }

    #[test]
    fn histogram_merge_is_associative_commutative_lossless() {
        forall("histogram merge algebra", 128, |rng| {
            let a = arbitrary_hist(rng);
            let b = arbitrary_hist(rng);
            let c = arbitrary_hist(rng);
            // commutative
            assert_eq!(a.merge(&b), b.merge(&a));
            // associative
            assert_eq!(a.merge(&b).merge(&c), a.merge(&b.merge(&c)));
            // identity
            assert_eq!(a.merge(&HistogramSnapshot::empty()), a);
            // lossless on counts and sums
            let m = a.merge(&b);
            assert_eq!(m.count, a.count + b.count);
            assert_eq!(m.sum, a.sum + b.sum);
            assert_eq!(
                m.buckets.iter().sum::<u64>(),
                a.count + b.count,
                "bucket totals account for every observation"
            );
        });
    }

    #[test]
    fn histogram_quantiles_and_mean() {
        let h = Histogram::new();
        for v in [1u64, 1, 1, 1000, 1000, 1_000_000] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 3 + 2000 + 1_000_000);
        assert_eq!(s.quantile(0.0), 1);
        assert_eq!(s.quantile(0.5), 1);
        assert!(s.quantile(0.99) >= 512 * 1024);
        assert!(s.mean() > 0.0);
        assert!(!s.summary().is_empty());
        assert_eq!(HistogramSnapshot::empty().quantile(0.5), 0);
        assert_eq!(HistogramSnapshot::empty().mean(), 0.0);
    }

    #[test]
    fn concurrent_increments_are_not_lost() {
        let reg = Registry::new();
        let counter = reg.counter("ops");
        let hist = reg.histogram("lat");
        const THREADS: usize = 8;
        const PER_THREAD: u64 = 10_000;
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let counter = Arc::clone(&counter);
                let hist = Arc::clone(&hist);
                scope.spawn(move || {
                    for i in 0..PER_THREAD {
                        counter.inc();
                        hist.observe((t as u64 + 1) * 100 + i % 7);
                    }
                });
            }
        });
        let snap = reg.snapshot();
        let total = THREADS as u64 * PER_THREAD;
        assert_eq!(snap.counter("ops"), Some(total));
        let h = snap.histogram("lat").unwrap();
        assert_eq!(h.count, total);
        assert_eq!(h.buckets.iter().sum::<u64>(), total);
    }

    #[test]
    fn registry_handles_are_shared_by_name() {
        let reg = Registry::new();
        reg.counter("x").add(3);
        reg.counter("x").add(4);
        reg.gauge("g").set(9);
        reg.gauge("g").set(2);
        assert_eq!(reg.snapshot().counter("x"), Some(7));
        assert_eq!(reg.snapshot().gauge("g"), Some(2));
        assert_eq!(reg.snapshot().counter("missing"), None);
    }

    #[test]
    fn snapshot_roundtrips_byte_identical() {
        forall("snapshot codec roundtrip", 32, |rng| {
            let reg = Registry::new();
            for i in 0..rng.gen_range(6) {
                reg.counter(&format!("c{i}")).add(rng.next_u64() >> 30);
            }
            for i in 0..rng.gen_range(4) {
                reg.gauge(&format!("g{i}")).set(rng.next_u64() >> 40);
            }
            for i in 0..rng.gen_range(3) {
                let h = reg.histogram(&format!("h{i}"));
                for _ in 0..rng.gen_range(20) {
                    h.observe(rng.next_u64() >> 32);
                }
            }
            reg.set_label("role", "workflow");
            let snap = reg.snapshot();
            let bytes = snap.to_bytes();
            let back = MetricsSnapshot::from_bytes(&bytes).unwrap();
            assert_eq!(back, snap);
            assert_eq!(back.to_bytes(), bytes);
        });
    }

    #[test]
    fn corrupt_snapshots_rejected() {
        let reg = Registry::new();
        reg.counter("a").add(1);
        reg.histogram("h").observe(5);
        reg.set_label("role", "data");
        let bytes = reg.snapshot().to_bytes();
        assert!(MetricsSnapshot::from_bytes(&bytes[..bytes.len() - 1])
            .is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(MetricsSnapshot::from_bytes(&bad_magic).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(MetricsSnapshot::from_bytes(&trailing).is_err());
        assert!(MetricsSnapshot::from_bytes(b"").is_err());
    }

    #[test]
    fn snapshot_merge_sums_counters_and_histograms() {
        let a = Registry::new();
        a.counter("ops").add(3);
        a.gauge("depth").set(5);
        a.histogram("lat").observe(100);
        a.set_label("role", "node");
        let b = Registry::new();
        b.counter("ops").add(4);
        b.counter("only_b").add(1);
        b.gauge("depth").set(2);
        b.histogram("lat").observe(200);
        let m = a.snapshot().merge(&b.snapshot());
        assert_eq!(m.counter("ops"), Some(7));
        assert_eq!(m.counter("only_b"), Some(1));
        assert_eq!(m.gauge("depth"), Some(5), "gauges take the max");
        assert_eq!(m.histogram("lat").unwrap().count, 2);
        assert_eq!(m.label("role"), Some("node"));
    }

    #[test]
    fn json_rendering_is_wellformed_enough() {
        let reg = Registry::new();
        reg.counter("ops").add(2);
        reg.gauge("q\"uote").set(1);
        reg.histogram("lat").observe(3);
        reg.set_label("addr", "127.0.0.1:9000");
        let json = reg.snapshot().to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"ops\":2"));
        assert!(json.contains("\\\"uote"));
        assert!(json.contains("\"addr\":\"127.0.0.1:9000\""));
        assert!(json.contains("\"buckets\":[0,0,1]"));
    }
}
