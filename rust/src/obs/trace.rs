//! Per-task lifecycle tracing: structured events in a bounded ring
//! buffer, dumpable as JSONL and replayable by an exactly-once
//! verifier.
//!
//! The normal lifecycle of a plan task is
//!
//! ```text
//! Planned → Queued → Assigned(node) → PartitionsFetched → Executed → Completed
//! ```
//!
//! with three detours: a node whose §3.1 budget can't hold the task
//! emits `Rejected`; a task no live node fits is `Split` into child
//! tasks (each `Queued` with `parent` set to the originating plan
//! task, `SpanMerged` when its result folds back in); a task lost to
//! a dead node is `Requeued`.  Events are stamped by an
//! [`super::Clock`] at record time, so ordering within one tracer is
//! meaningful even though absolute values are per-process.
//!
//! The buffer is bounded ([`Tracer::new`] takes the capacity): when
//! full, the *oldest* events are dropped and counted, never the
//! newest — a stats scrape sees the recent past, and
//! [`verify_exactly_once`] refuses to certify a truncated trace.

use super::clock::{system_clock, Clock};
use super::registry::json_string;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// What happened to a task (see module docs for the lifecycle).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceEventKind {
    /// Task exists in the plan.
    Planned,
    /// Task entered the scheduler queue.
    Queued,
    /// Task handed to a service (`node` is the service id).
    Assigned,
    /// Node finished fetching the task's partitions.
    PartitionsFetched,
    /// Node finished comparing the task's pairs.
    Executed,
    /// A split child's result folded into its root task.
    SpanMerged,
    /// Task completed exactly once (roots only).
    Completed,
    /// A service's §3.1 budget could not hold the task.
    Rejected,
    /// Task was split into child tasks.
    Split,
    /// Task re-queued after its service died.
    Requeued,
}

impl TraceEventKind {
    /// Stable snake_case name (the JSONL `kind` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            TraceEventKind::Planned => "planned",
            TraceEventKind::Queued => "queued",
            TraceEventKind::Assigned => "assigned",
            TraceEventKind::PartitionsFetched => "partitions_fetched",
            TraceEventKind::Executed => "executed",
            TraceEventKind::SpanMerged => "span_merged",
            TraceEventKind::Completed => "completed",
            TraceEventKind::Rejected => "rejected",
            TraceEventKind::Split => "split",
            TraceEventKind::Requeued => "requeued",
        }
    }
}

/// One recorded lifecycle event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Nanosecond stamp from the tracer's clock.
    pub at_ns: u64,
    /// Task id the event is about.
    pub task: u32,
    /// What happened.
    pub kind: TraceEventKind,
    /// Service/node involved, if any.
    pub node: Option<u64>,
    /// Root plan task, set on events about split children.
    pub parent: Option<u32>,
}

impl TraceEvent {
    /// One JSONL line (no trailing newline).
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"at_ns\":{},\"task\":{},\"kind\":{}",
            self.at_ns,
            self.task,
            json_string(self.kind.as_str())
        );
        if let Some(n) = self.node {
            out.push_str(&format!(",\"node\":{n}"));
        }
        if let Some(p) = self.parent {
            out.push_str(&format!(",\"parent\":{p}"));
        }
        out.push('}');
        out
    }
}

/// Bounded, thread-safe ring buffer of [`TraceEvent`]s.
#[derive(Debug)]
pub struct Tracer {
    clock: Arc<dyn Clock>,
    cap: usize,
    buf: Mutex<VecDeque<TraceEvent>>,
    dropped: AtomicU64,
}

/// Default ring capacity: enough for every event of a ~100k-task run
/// (a task emits ≤ ~8 events) without unbounded growth on servers
/// that run forever.
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 20;

impl Tracer {
    /// A tracer over the system clock holding at most `cap` events.
    pub fn new(cap: usize) -> Arc<Tracer> {
        Tracer::with_clock(cap, system_clock())
    }

    /// A tracer over an injected clock (deterministic tests).
    pub fn with_clock(cap: usize, clock: Arc<dyn Clock>) -> Arc<Tracer> {
        Arc::new(Tracer {
            clock,
            cap: cap.max(1),
            buf: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        })
    }

    /// Record an event about `task`, stamped now.
    pub fn record(
        &self,
        task: u32,
        kind: TraceEventKind,
        node: Option<u64>,
        parent: Option<u32>,
    ) {
        let ev = TraceEvent {
            at_ns: self.clock.now_ns(),
            task,
            kind,
            node,
            parent,
        };
        let mut buf = crate::util::lock_poisonless(&self.buf);
        if buf.len() == self.cap {
            buf.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        buf.push_back(ev);
    }

    /// Copy of the buffered events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        crate::util::lock_poisonless(&self.buf)
            .iter()
            .copied()
            .collect()
    }

    /// Events evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Buffered event count.
    pub fn len(&self) -> usize {
        crate::util::lock_poisonless(&self.buf).len()
    }

    /// True when no events are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All buffered events as JSONL (one event per line).
    pub fn dump_jsonl(&self) -> String {
        let buf = crate::util::lock_poisonless(&self.buf);
        let mut out = String::with_capacity(buf.len() * 64);
        for ev in buf.iter() {
            out.push_str(&ev.to_json());
            out.push('\n');
        }
        out
    }

    /// Replay the buffered trace against `plan_tasks`, refusing if
    /// events were dropped (a truncated trace can't prove
    /// exactly-once).
    pub fn verify_plan(
        &self,
        plan_tasks: &[u32],
    ) -> Result<ReplaySummary, String> {
        let dropped = self.dropped();
        if dropped > 0 {
            return Err(format!(
                "{dropped} events dropped from the ring; trace is \
                 incomplete"
            ));
        }
        verify_exactly_once(&self.events(), plan_tasks)
    }
}

/// What a successful replay reconstructed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplaySummary {
    /// Plan tasks, each completed exactly once.
    pub plan_tasks: usize,
    /// Split children observed.
    pub subtasks: usize,
    /// Split events.
    pub splits: usize,
    /// Requeue events (tasks recovered from dead services).
    pub requeues: usize,
    /// Assignment events (> plan_tasks under rejection/requeue churn).
    pub assignments: usize,
}

/// Replay a trace and assert the exactly-once lifecycle invariants:
///
/// 1. every task in `plan_tasks` has exactly one `Completed` event;
/// 2. no other task id has a `Completed` event (children merge, only
///    roots complete);
/// 3. every split child (a task `Queued` with a `parent`) is either
///    `SpanMerged` exactly once or `Split` again — never both, never
///    twice, never silently lost;
/// 4. every `Executed` event's task was `Assigned` beforehand.
///
/// Together these prove no task was lost or double-completed, even
/// under chaos (requeues) and runtime splitting.
pub fn verify_exactly_once(
    events: &[TraceEvent],
    plan_tasks: &[u32],
) -> Result<ReplaySummary, String> {
    let plan: HashSet<u32> = plan_tasks.iter().copied().collect();
    let mut completed: HashMap<u32, usize> = HashMap::new();
    let mut merged: HashMap<u32, usize> = HashMap::new();
    let mut split: HashMap<u32, usize> = HashMap::new();
    let mut assigned: HashSet<u32> = HashSet::new();
    let mut subtasks: HashSet<u32> = HashSet::new();
    let mut summary = ReplaySummary::default();
    for ev in events {
        match ev.kind {
            TraceEventKind::Completed => {
                *completed.entry(ev.task).or_default() += 1;
            }
            TraceEventKind::SpanMerged => {
                *merged.entry(ev.task).or_default() += 1;
            }
            TraceEventKind::Split => {
                *split.entry(ev.task).or_default() += 1;
                summary.splits += 1;
            }
            TraceEventKind::Assigned => {
                assigned.insert(ev.task);
                summary.assignments += 1;
            }
            TraceEventKind::Executed => {
                if !assigned.contains(&ev.task) {
                    return Err(format!(
                        "task {} executed without assignment",
                        ev.task
                    ));
                }
            }
            TraceEventKind::Requeued => summary.requeues += 1,
            TraceEventKind::Queued => {
                if ev.parent.is_some() {
                    subtasks.insert(ev.task);
                }
            }
            _ => {}
        }
    }
    for &id in &plan {
        match completed.get(&id).copied().unwrap_or(0) {
            1 => {}
            0 => return Err(format!("plan task {id} never completed")),
            n => {
                return Err(format!(
                    "plan task {id} completed {n} times"
                ))
            }
        }
    }
    for (&id, &n) in &completed {
        if !plan.contains(&id) {
            return Err(format!(
                "non-plan task {id} has {n} Completed event(s); only \
                 roots complete"
            ));
        }
    }
    for &id in &subtasks {
        let m = merged.get(&id).copied().unwrap_or(0);
        let s = split.get(&id).copied().unwrap_or(0);
        match (m, s) {
            (1, 0) | (0, 1) => {}
            (0, 0) => {
                return Err(format!(
                    "split child {id} neither merged nor re-split \
                     (lost)"
                ))
            }
            _ => {
                return Err(format!(
                    "split child {id} merged {m}× / split {s}× \
                     (duplicated)"
                ))
            }
        }
    }
    for (&id, &n) in &merged {
        if n > 1 {
            return Err(format!("task {id} span-merged {n} times"));
        }
    }
    summary.plan_tasks = plan.len();
    summary.subtasks = subtasks.len();
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::super::clock::ManualClock;
    use super::*;

    fn ev(task: u32, kind: TraceEventKind) -> TraceEvent {
        TraceEvent {
            at_ns: 0,
            task,
            kind,
            node: None,
            parent: None,
        }
    }

    fn child_queued(task: u32, parent: u32) -> TraceEvent {
        TraceEvent {
            parent: Some(parent),
            ..ev(task, TraceEventKind::Queued)
        }
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let clock = Arc::new(ManualClock::new(0));
        let t = Tracer::with_clock(3, clock.clone());
        for i in 0..5u32 {
            clock.advance(10);
            t.record(i, TraceEventKind::Queued, None, None);
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.dropped(), 2);
        let evs = t.events();
        assert_eq!(
            evs.iter().map(|e| e.task).collect::<Vec<_>>(),
            vec![2, 3, 4],
            "oldest evicted first"
        );
        assert!(evs[0].at_ns < evs[2].at_ns);
        assert!(t.verify_plan(&[2, 3, 4]).is_err(), "truncated trace");
    }

    #[test]
    fn jsonl_dump_has_one_line_per_event() {
        let t = Tracer::new(16);
        t.record(7, TraceEventKind::Assigned, Some(1), None);
        t.record(8, TraceEventKind::Queued, None, Some(7));
        let dump = t.dump_jsonl();
        let lines: Vec<&str> = dump.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"task\":7"));
        assert!(lines[0].contains("\"kind\":\"assigned\""));
        assert!(lines[0].contains("\"node\":1"));
        assert!(lines[1].contains("\"parent\":7"));
        assert!(!t.is_empty());
    }

    #[test]
    fn verifier_accepts_plain_lifecycle() {
        let mut evs = Vec::new();
        for id in [0u32, 1, 2] {
            evs.push(ev(id, TraceEventKind::Planned));
            evs.push(ev(id, TraceEventKind::Queued));
            evs.push(ev(id, TraceEventKind::Assigned));
            evs.push(ev(id, TraceEventKind::Executed));
            evs.push(ev(id, TraceEventKind::Completed));
        }
        let s = verify_exactly_once(&evs, &[0, 1, 2]).unwrap();
        assert_eq!(s.plan_tasks, 3);
        assert_eq!(s.subtasks, 0);
        assert_eq!(s.assignments, 3);
    }

    #[test]
    fn verifier_accepts_split_and_requeue_lifecycle() {
        let mut evs = vec![
            ev(0, TraceEventKind::Planned),
            ev(0, TraceEventKind::Queued),
            ev(0, TraceEventKind::Assigned),
            ev(0, TraceEventKind::Rejected),
            ev(0, TraceEventKind::Split),
            child_queued(10, 0),
            child_queued(11, 0),
        ];
        // child 10 executes; child 11 is lost to a dead node, requeued,
        // then split again into 12/13
        for id in [10u32] {
            evs.push(ev(id, TraceEventKind::Assigned));
            evs.push(ev(id, TraceEventKind::Executed));
            evs.push(ev(id, TraceEventKind::SpanMerged));
        }
        evs.push(ev(11, TraceEventKind::Assigned));
        evs.push(ev(11, TraceEventKind::Requeued));
        evs.push(ev(11, TraceEventKind::Assigned));
        evs.push(ev(11, TraceEventKind::Rejected));
        evs.push(ev(11, TraceEventKind::Split));
        evs.push(child_queued(12, 0));
        evs.push(child_queued(13, 0));
        for id in [12u32, 13] {
            evs.push(ev(id, TraceEventKind::Assigned));
            evs.push(ev(id, TraceEventKind::Executed));
            evs.push(ev(id, TraceEventKind::SpanMerged));
        }
        evs.push(ev(0, TraceEventKind::Completed));
        let s = verify_exactly_once(&evs, &[0]).unwrap();
        assert_eq!(s.plan_tasks, 1);
        assert_eq!(s.subtasks, 4);
        assert_eq!(s.splits, 2);
        assert_eq!(s.requeues, 1);
    }

    #[test]
    fn verifier_rejects_lost_and_duplicated_lifecycles() {
        // missing completion
        let evs = vec![ev(0, TraceEventKind::Queued)];
        assert!(verify_exactly_once(&evs, &[0])
            .unwrap_err()
            .contains("never completed"));
        // double completion
        let evs = vec![
            ev(0, TraceEventKind::Assigned),
            ev(0, TraceEventKind::Completed),
            ev(0, TraceEventKind::Completed),
        ];
        assert!(verify_exactly_once(&evs, &[0])
            .unwrap_err()
            .contains("completed 2 times"));
        // completion of a non-plan task
        let evs = vec![
            ev(0, TraceEventKind::Assigned),
            ev(0, TraceEventKind::Completed),
            ev(9, TraceEventKind::Completed),
        ];
        assert!(verify_exactly_once(&evs, &[0])
            .unwrap_err()
            .contains("non-plan task 9"));
        // lost split child
        let evs = vec![
            ev(0, TraceEventKind::Assigned),
            child_queued(10, 0),
            ev(0, TraceEventKind::Completed),
        ];
        assert!(verify_exactly_once(&evs, &[0])
            .unwrap_err()
            .contains("neither merged"));
        // double-merged split child
        let evs = vec![
            ev(0, TraceEventKind::Assigned),
            child_queued(10, 0),
            ev(10, TraceEventKind::SpanMerged),
            ev(10, TraceEventKind::SpanMerged),
            ev(0, TraceEventKind::Completed),
        ];
        assert!(verify_exactly_once(&evs, &[0]).is_err());
        // execution without assignment
        let evs = vec![
            ev(0, TraceEventKind::Executed),
            ev(0, TraceEventKind::Completed),
        ];
        assert!(verify_exactly_once(&evs, &[0])
            .unwrap_err()
            .contains("without assignment"));
    }
}
