//! The one monotonic time source ([`Clock`]) behind all observability
//! timestamps.
//!
//! Everything in the crate that needs "now" for *accounting* — trace
//! event stamps, heartbeat liveness, replica-failover cooldowns, busy-ns
//! bookkeeping — goes through this trait instead of calling
//! `Instant::now()` directly, so deterministic tests can drive time with
//! [`ManualClock`] while production uses [`SystemClock`].  Timestamps
//! are plain `u64` nanoseconds since an arbitrary per-clock origin:
//! monotonic and comparable within one clock, meaningless across
//! processes (trace analysis only ever compares stamps from the same
//! server).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A monotonic nanosecond clock.
pub trait Clock: Send + Sync + std::fmt::Debug {
    /// Nanoseconds since this clock's origin.  Monotonic: never
    /// decreases between calls on the same clock.
    fn now_ns(&self) -> u64;
}

/// Real time: nanoseconds since the clock was created, backed by
/// [`Instant`].
#[derive(Debug)]
pub struct SystemClock {
    origin: Instant,
}

impl SystemClock {
    /// A clock whose origin is "now".
    pub fn new() -> SystemClock {
        SystemClock {
            origin: Instant::now(),
        }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// Deterministic test clock: time advances only when told to.
#[derive(Debug, Default)]
pub struct ManualClock {
    now: AtomicU64,
}

impl ManualClock {
    /// A clock starting at `start_ns`.
    pub fn new(start_ns: u64) -> ManualClock {
        ManualClock {
            now: AtomicU64::new(start_ns),
        }
    }

    /// Advance time by `ns`.
    pub fn advance(&self, ns: u64) {
        self.now.fetch_add(ns, Ordering::SeqCst);
    }

    /// Jump to an absolute time (must not go backwards in tests that
    /// rely on monotonicity; the clock does not enforce it).
    pub fn set(&self, ns: u64) {
        self.now.store(ns, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }
}

/// Shorthand for the production clock as a shareable trait object.
pub fn system_clock() -> Arc<dyn Clock> {
    Arc::new(SystemClock::new())
}

/// One-shot interval measurement through the sanctioned clock.
///
/// `pem-lint` L1 keeps `Instant::now()` out of everything but this
/// module, so ad-hoc "how long did that take" measurements (fault
/// latency, calibration loops, CLI elapsed time) go through a
/// `Stopwatch`: construct at the start of the interval, read
/// [`Stopwatch::elapsed_ns`] at the end.  A [`SystemClock`]'s origin
/// is its construction time, which makes the stopwatch free to build
/// on top of it.
#[derive(Debug)]
pub struct Stopwatch {
    clock: SystemClock,
}

impl Stopwatch {
    /// Start measuring now.
    pub fn start() -> Stopwatch {
        Stopwatch {
            clock: SystemClock::new(),
        }
    }

    /// Nanoseconds since [`Stopwatch::start`].
    pub fn elapsed_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Elapsed time as a [`std::time::Duration`].
    pub fn elapsed(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.elapsed_ns())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotonic() {
        let c = SystemClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_advances_only_on_demand() {
        let c = ManualClock::new(100);
        assert_eq!(c.now_ns(), 100);
        assert_eq!(c.now_ns(), 100);
        c.advance(50);
        assert_eq!(c.now_ns(), 150);
        c.set(1_000);
        assert_eq!(c.now_ns(), 1_000);
    }

    #[test]
    fn clocks_are_object_safe_and_shareable() {
        let c: Arc<dyn Clock> = Arc::new(ManualClock::new(7));
        let c2 = Arc::clone(&c);
        assert_eq!(c.now_ns(), c2.now_ns());
    }
}
