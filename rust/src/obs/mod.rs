//! Cluster-wide observability: metrics registry, lifecycle tracing,
//! and the shared monotonic clock.
//!
//! Three pieces (see `docs/OBSERVABILITY.md` for the operator view):
//!
//! * [`clock`] — the injectable monotonic [`Clock`] every accounting
//!   timestamp in the crate goes through;
//! * [`registry`] — lock-cheap counters/gauges/histograms per server,
//!   snapshottable into a serializable [`MetricsSnapshot`] that the
//!   protocol-v6 `StatsRequest`/`StatsReport` frames carry to `pem
//!   stats`;
//! * [`trace`] — per-task lifecycle events in a bounded ring,
//!   dumpable as JSONL (`pem match --trace`) and replayable by
//!   [`verify_exactly_once`].

pub mod clock;
pub mod registry;
pub mod trace;

pub use clock::{
    system_clock, Clock, ManualClock, Stopwatch, SystemClock,
};
pub use registry::{
    bucket_index, bucket_lower, metric_name, tenant_gauge, Counter,
    Gauge, Histogram, HistogramSnapshot, MetricsSnapshot, Registry,
    HISTOGRAM_BUCKETS,
};
pub use trace::{
    verify_exactly_once, ReplaySummary, TraceEvent, TraceEventKind,
    Tracer, DEFAULT_TRACE_CAPACITY,
};
