//! Deterministic discrete-event simulator in virtual time.
//!
//! Models the paper's computing environment exactly: `nodes` match
//! services, each with `cores` cores, `threads` match threads, `max_mem`
//! shared memory, an LRU partition cache of capacity `c`, and RMI-style
//! communication costs to the central data and workflow services.  The
//! scheduler under simulation is the *real* [`Scheduler`] — the same code
//! the thread engine runs.
//!
//! Task lifecycle (one virtual thread):
//!
//! ```text
//! assign ──control──▶ fetch partitions (cache miss ⇒ transfer time;
//!         latency      no core needed — I/O overlaps compute)
//!        ──▶ wait for a free core ──▶ compute (service time from
//!            CostParams) ──▶ report complete (piggybacked cache status)
//!        ──▶ assign next …
//! ```
//!
//! Everything is integer nanoseconds; ties break on event sequence
//! numbers, so runs are bit-for-bit reproducible.

use super::CostParams;
use crate::cluster::{ComputingEnv, HeterogeneousEnv, NodeSpec};
use crate::coordinator::scheduler::{Policy, Scheduler, ServiceId};
use crate::matching::StrategyKind;
use crate::metrics::RunMetrics;
use crate::model::Correspondence;
use crate::net::CostModel;
use crate::partition::{task_memory_bytes, MatchTask, PartitionId, PartitionSet};
use crate::store::DataService;
use crate::util::LruCache;
use crate::worker::{task_comparisons, TaskExecutor};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Simulator configuration.
pub struct SimConfig {
    /// Per-pair compute cost model (calibrated or defaults).
    pub cost: CostParams,
    /// Control-plane messages (assignment / completion RMI to the
    /// workflow service).
    pub net: CostModel,
    /// Data-plane partition fetches from the data service (DBMS path —
    /// see [`CostModel::dbms`]).
    pub data_net: CostModel,
    /// Match strategy whose cost profile is simulated.
    pub strategy: StrategyKind,
    /// Partition-cache capacity per match service (paper's `c`).
    pub cache_capacity: usize,
    /// Task-assignment policy (FIFO or affinity).
    pub policy: Policy,
    /// Inject node failures at (virtual time, node index).
    pub failures: Vec<(u64, usize)>,
    /// Actually execute the match tasks (real compute, small runs only)
    /// to produce correspondences alongside the virtual-time metrics.
    pub execute: Option<Box<dyn TaskExecutor>>,
}

impl SimConfig {
    /// Defaults: LAN control plane, DBMS data plane, affinity policy,
    /// no cache, no failures, metrics-only (no real matching).
    pub fn new(strategy: StrategyKind, cost: CostParams) -> SimConfig {
        SimConfig {
            cost,
            net: CostModel::lan(),
            data_net: CostModel::dbms(),
            strategy,
            cache_capacity: 0,
            policy: Policy::Affinity,
            failures: Vec::new(),
            execute: None,
        }
    }
}

/// Simulation outcome: metrics on the virtual clock (+ correspondences
/// when `execute` was set).
pub struct SimOutcome {
    /// Virtual-clock run metrics.
    pub metrics: RunMetrics,
    /// Real match output (empty unless `execute` was set).
    pub correspondences: Vec<Correspondence>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EventKind {
    FetchDone { thread: usize, task: MatchTask },
    ComputeDone { thread: usize, task: MatchTask },
    FailNode { node: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Event {
    time: u64,
    seq: u64,
    kind: EventKind,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct Node {
    spec: NodeSpec,
    cache: LruCache<PartitionId, u64>,
    busy_cores: usize,
    compute_queue: VecDeque<(usize, MatchTask, u64)>, // (thread, task, service_ns)
    alive: bool,
}

/// Run the simulation.
pub fn run(
    ce: &ComputingEnv,
    parts: &PartitionSet,
    tasks: Vec<MatchTask>,
    store: &DataService,
    mut cfg: SimConfig,
) -> SimOutcome {
    run_heterogeneous(
        &HeterogeneousEnv::uniform(ce),
        parts,
        tasks,
        store,
        &mut cfg,
    )
}

/// Run on an explicitly heterogeneous environment.
pub fn run_heterogeneous(
    env: &HeterogeneousEnv,
    parts: &PartitionSet,
    tasks: Vec<MatchTask>,
    store: &DataService,
    cfg: &mut SimConfig,
) -> SimOutcome {
    let n_tasks = tasks.len();
    let mut sched = Scheduler::new(tasks, cfg.policy);
    let mut nodes: Vec<Node> = env
        .nodes
        .iter()
        .map(|&spec| Node {
            spec,
            cache: LruCache::new(cfg.cache_capacity),
            busy_cores: 0,
            compute_queue: VecDeque::new(),
            alive: true,
        })
        .collect();
    for i in 0..nodes.len() {
        sched.add_service(ServiceId(i));
    }

    // global thread table: thread id → node
    let mut thread_node: Vec<usize> = Vec::new();
    for (ni, node) in nodes.iter().enumerate() {
        for _ in 0..node.spec.threads {
            thread_node.push(ni);
        }
    }
    let n_threads = thread_node.len();

    let mut metrics = RunMetrics {
        thread_busy_ns: vec![0; n_threads],
        ..Default::default()
    };
    let mut correspondences = Vec::new();

    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    let mut seq = 0u64;
    let push = |heap: &mut BinaryHeap<Reverse<Event>>,
                    seq: &mut u64,
                    time: u64,
                    kind: EventKind| {
        heap.push(Reverse(Event {
            time,
            seq: *seq,
            kind,
        }));
        *seq += 1;
    };

    for &(time, node) in &cfg.failures {
        push(&mut heap, &mut seq, time, EventKind::FailNode { node });
    }

    let mut idle_threads: Vec<usize> = Vec::new();
    let mut makespan = 0u64;

    // Assign a task to `thread` at `now`: charge control + fetch, push
    // FetchDone.  Returns false if no task was available.
    macro_rules! try_assign {
        ($thread:expr, $now:expr) => {{
            let thread = $thread;
            let now: u64 = $now;
            let ni = thread_node[thread];
            if !nodes[ni].alive {
                false
            } else if let Some(task) = sched.next_task(ServiceId(ni)) {
                metrics.control_messages += 1;
                let mut t = now + cfg.net.control_message_ns();
                for pid in task.needed_partitions() {
                    let node = &mut nodes[ni];
                    if node.cache.get(&pid).is_some() {
                        metrics.cache_hits += 1;
                    } else {
                        metrics.cache_misses += 1;
                        let bytes = store
                            .payload_bytes(pid)
                            .expect("partition named by the plan");
                        t += cfg.data_net.transfer_time_ns(bytes);
                        metrics.bytes_fetched += bytes;
                        node.cache.put(pid, bytes);
                    }
                }
                metrics.thread_busy_ns[thread] += t - now;
                push(
                    &mut heap,
                    &mut seq,
                    t,
                    EventKind::FetchDone { thread, task },
                );
                true
            } else {
                idle_threads.push(thread);
                false
            }
        }};
    }

    // service time of a task on node `ni`
    let service_time = |nodes: &Vec<Node>, ni: usize, task: &MatchTask| -> u64 {
        let spec = &nodes[ni].spec;
        let l = parts.get(task.left).len();
        let r = parts.get(task.right).len();
        let pairs = task_comparisons(task, l, r);
        let active = spec.threads.min(spec.cores);
        let budget = spec.max_mem / spec.threads as u64;
        let demand = task_memory_bytes(l, r, cfg.strategy);
        let pair_cost = cfg.cost.pair_cost_contended(active)
            * cfg.cost.paging_penalty(demand, budget);
        let work = cfg.cost.task_overhead_ns as f64
            + pairs as f64 * pair_cost;
        (work / spec.speed.max(1e-9)) as u64
    };

    // Kick-off: threads ask for work as the run starts.  The workflow
    // service hands out assignments one RMI call at a time, so the
    // initial wave is staggered by one control latency per thread —
    // without this, homogeneous tasks march in lockstep (all threads
    // fetch at the same instants, all cores idle at the same instants),
    // a convoy no real deployment exhibits.
    for thread in 0..n_threads {
        try_assign!(
            thread,
            thread as u64 * cfg.net.control_message_ns().max(1)
        );
    }

    while let Some(Reverse(ev)) = heap.pop() {
        match ev.kind {
            EventKind::FailNode { node } => {
                if !nodes[node].alive {
                    continue;
                }
                nodes[node].alive = false;
                nodes[node].compute_queue.clear();
                nodes[node].busy_cores = 0;
                let reopened = sched.fail_service(ServiceId(node));
                if reopened > 0 {
                    // wake idle threads on surviving nodes
                    let waiting: Vec<usize> = std::mem::take(&mut idle_threads);
                    for thread in waiting {
                        try_assign!(thread, ev.time);
                    }
                }
            }
            EventKind::FetchDone { thread, task } => {
                let ni = thread_node[thread];
                if !nodes[ni].alive {
                    continue;
                }
                let svc = service_time(&nodes, ni, &task);
                let node = &mut nodes[ni];
                if node.busy_cores < node.spec.cores {
                    node.busy_cores += 1;
                    metrics.thread_busy_ns[thread] += svc;
                    push(
                        &mut heap,
                        &mut seq,
                        ev.time + svc,
                        EventKind::ComputeDone { thread, task },
                    );
                } else {
                    node.compute_queue.push_back((thread, task, svc));
                }
            }
            EventKind::ComputeDone { thread, task } => {
                let ni = thread_node[thread];
                if !nodes[ni].alive {
                    continue;
                }
                makespan = makespan.max(ev.time);

                // real execution (small runs): produce correspondences
                let l = parts.get(task.left).len();
                let r = parts.get(task.right).len();
                metrics.tasks += 1;
                metrics.comparisons += task_comparisons(&task, l, r);
                if let Some(exec) = &cfg.execute {
                    let left = store
                        .fetch(task.left)
                        .expect("partition named by the plan");
                    let intra = task.left == task.right;
                    let right = if intra {
                        left.clone()
                    } else {
                        store
                            .fetch(task.right)
                            .expect("partition named by the plan")
                    };
                    correspondences
                        .extend(exec.execute(&left, &right, intra));
                }

                // completion report with piggybacked cache status
                metrics.control_messages += 1;
                sched.report_complete(
                    ServiceId(ni),
                    task.id,
                    nodes[ni].cache.keys(),
                );

                // free the core; start a queued compute phase if any
                let node = &mut nodes[ni];
                node.busy_cores -= 1;
                if let Some((qt, qtask, qsvc)) = node.compute_queue.pop_front()
                {
                    node.busy_cores += 1;
                    metrics.thread_busy_ns[qt] += qsvc;
                    push(
                        &mut heap,
                        &mut seq,
                        ev.time + qsvc,
                        EventKind::ComputeDone {
                            thread: qt,
                            task: qtask,
                        },
                    );
                }

                // pull the next task for this thread
                try_assign!(thread, ev.time + cfg.net.control_message_ns());
            }
        }
    }

    assert!(
        sched.is_done(),
        "simulation ended with {} of {} tasks incomplete",
        sched.remaining(),
        n_tasks,
    );
    metrics.makespan_ns = makespan;
    metrics.matches = correspondences.len();
    metrics.affinity_hits = sched.affinity_assignments;
    SimOutcome {
        metrics,
        correspondences,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::matching::MatchStrategy;
    use crate::model::EntityId;
    use crate::partition::{generate_tasks, partition_size_based};
    use crate::worker::RustExecutor;

    fn setup(
        n: usize,
        m: usize,
    ) -> (
        crate::datagen::GeneratedData,
        PartitionSet,
        Vec<MatchTask>,
        DataService,
    ) {
        let data = GeneratorConfig::tiny().with_entities(n).generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, m);
        let tasks = generate_tasks(&parts);
        let store = DataService::build(&data.dataset, &parts);
        (data, parts, tasks, store)
    }

    fn sim_cfg(strategy: StrategyKind) -> SimConfig {
        SimConfig::new(strategy, CostParams::default_for(strategy))
    }

    #[test]
    fn completes_all_tasks_deterministically() {
        let (_, parts, tasks, store) = setup(400, 80);
        let ce = ComputingEnv::paper_testbed(2);
        let n_tasks = tasks.len();
        let a = run(&ce, &parts, tasks.clone(), &store, sim_cfg(StrategyKind::Wam));
        let b = run(&ce, &parts, tasks, &store, sim_cfg(StrategyKind::Wam));
        assert_eq!(a.metrics.tasks, n_tasks);
        assert_eq!(a.metrics.makespan_ns, b.metrics.makespan_ns);
        assert_eq!(a.metrics.cache_hits, b.metrics.cache_hits);
        assert!(a.metrics.makespan_ns > 0);
    }

    #[test]
    fn more_cores_scale_down_makespan() {
        let (_, parts, tasks, store) = setup(600, 60);
        let mut times = Vec::new();
        for nodes in [1, 2, 4] {
            let ce = ComputingEnv::paper_testbed(nodes);
            let out = run(
                &ce,
                &parts,
                tasks.clone(),
                &store,
                sim_cfg(StrategyKind::Wam),
            );
            times.push(out.metrics.makespan_ns);
        }
        assert!(times[1] < times[0]);
        assert!(times[2] < times[1]);
        // speedup from 4 to 16 cores should be substantial (> 2.5x)
        assert!(
            times[0] as f64 / times[2] as f64 > 2.5,
            "speedup {}",
            times[0] as f64 / times[2] as f64
        );
    }

    #[test]
    fn caching_reduces_fetches_and_time() {
        let (_, parts, tasks, store) = setup(600, 60);
        let ce = ComputingEnv::paper_testbed(1);
        let nc = run(
            &ce,
            &parts,
            tasks.clone(),
            &store,
            sim_cfg(StrategyKind::Wam),
        );
        let mut cached = sim_cfg(StrategyKind::Wam);
        cached.cache_capacity = 16;
        let c = run(&ce, &parts, tasks, &store, cached);
        assert_eq!(nc.metrics.cache_hits, 0);
        assert!(c.metrics.cache_hits > 0);
        assert!(c.metrics.bytes_fetched < nc.metrics.bytes_fetched);
        assert!(c.metrics.makespan_ns <= nc.metrics.makespan_ns);
        assert!(c.metrics.hit_ratio() > 0.3, "hr {}", c.metrics.hit_ratio());
    }

    #[test]
    fn execute_mode_matches_direct_execution() {
        let (data, parts, tasks, store) = setup(200, 50);
        let ce = ComputingEnv::paper_testbed(1);
        let strategy = MatchStrategy::new(StrategyKind::Wam);
        let mut cfg = sim_cfg(StrategyKind::Wam);
        cfg.execute = Some(Box::new(RustExecutor::new(strategy)));
        let out = run(&ce, &parts, tasks, &store, cfg);
        assert_eq!(out.metrics.matches, out.correspondences.len());
        // sanity: finds a healthy share of injected duplicates
        let found: std::collections::HashSet<_> =
            out.correspondences.iter().map(|c| c.pair()).collect();
        let hits = data
            .truth
            .iter()
            .filter(|&&(a, b)| found.contains(&(a, b)))
            .count();
        assert!(hits * 10 >= data.truth.len() * 8, "{hits}/{}", data.truth.len());
    }

    #[test]
    fn node_failure_reassigns_and_completes() {
        let (_, parts, tasks, store) = setup(600, 60);
        let n_tasks = tasks.len();
        let ce = ComputingEnv::paper_testbed(2);
        let healthy = run(
            &ce,
            &parts,
            tasks.clone(),
            &store,
            sim_cfg(StrategyKind::Wam),
        );
        let mut cfg = sim_cfg(StrategyKind::Wam);
        // kill node 1 early in the run
        cfg.failures = vec![(healthy.metrics.makespan_ns / 10, 1)];
        let out = run(&ce, &parts, tasks, &store, cfg);
        assert_eq!(out.metrics.tasks, n_tasks, "all tasks still complete");
        assert!(
            out.metrics.makespan_ns > healthy.metrics.makespan_ns,
            "losing a node costs time"
        );
    }

    #[test]
    fn threads_beyond_cores_give_little() {
        let (_, parts, tasks, store) = setup(800, 60);
        // LAN data path: at these tiny test partitions the default DBMS
        // fetch cost would dominate compute and extra threads would win
        // by I/O overlap alone — not what this test isolates.
        let mut cfg4 = sim_cfg(StrategyKind::Lrm);
        cfg4.data_net = CostModel::lan();
        let mut cfg8 = sim_cfg(StrategyKind::Lrm);
        cfg8.data_net = CostModel::lan();
        let t4 = run(
            &ComputingEnv::paper_testbed(1).with_threads(4),
            &parts,
            tasks.clone(),
            &store,
            cfg4,
        );
        let t8 = run(
            &ComputingEnv::paper_testbed(1).with_threads(8),
            &parts,
            tasks,
            &store,
            cfg8,
        );
        // LRM: 8 threads on 4 cores must not be much better than 4
        // (memory pressure + core sharing), within 20%
        let ratio = t4.metrics.makespan_ns as f64 / t8.metrics.makespan_ns as f64;
        assert!(ratio < 1.20, "8-thread speedup over 4 = {ratio}");
    }

    #[test]
    fn affinity_beats_fifo_on_cache_hits() {
        let (_, parts, tasks, store) = setup(900, 50);
        let ce = ComputingEnv::paper_testbed(2);
        let mut aff = sim_cfg(StrategyKind::Wam);
        aff.cache_capacity = 8;
        aff.policy = Policy::Affinity;
        let mut fifo = sim_cfg(StrategyKind::Wam);
        fifo.cache_capacity = 8;
        fifo.policy = Policy::Fifo;
        let a = run(&ce, &parts, tasks.clone(), &store, aff);
        let f = run(&ce, &parts, tasks, &store, fifo);
        assert!(
            a.metrics.hit_ratio() >= f.metrics.hit_ratio(),
            "affinity hr {} < fifo hr {}",
            a.metrics.hit_ratio(),
            f.metrics.hit_ratio()
        );
    }
}
