//! Execution backends: the execute half of the plan/execute split.
//!
//! An [`ExecutionBackend`] consumes a finished
//! [`crate::coordinator::MatchPlan`] and runs its tasks, returning the
//! engine-level output (metrics + raw correspondences) that the
//! workflow layer merges into a
//! [`crate::coordinator::RunOutcome`].  The three engines are impls —
//! [`Threads`], [`Sim`], [`Dist`] — and each owns its *own* typed
//! option struct ([`SimOptions`], [`DistOptions`]) instead of leaking
//! engine-specific knobs into a shared flat config.  The trait is
//! object-safe, so the [`crate::coordinator::Workflow`] builder holds a
//! `Box<dyn ExecutionBackend>` and new backends (a remote cluster, a
//! recorded trace, …) plug in without touching the workflow layer.

use crate::cluster::ComputingEnv;
use crate::coordinator::plan::MatchPlan;
use crate::coordinator::scheduler::Policy;
use crate::engine::{calibrate, dist, sim, threads, CostParams};
use crate::matching::MatchStrategy;
use crate::metrics::RunMetrics;
use crate::model::{Correspondence, Dataset};
use crate::net::CostModel;
use crate::obs::Tracer;
use crate::store::{DataService, StoreKind};
use crate::worker::{RustExecutor, TaskExecutor};
use anyhow::{Context, Result};
use std::fmt;
use std::sync::Arc;

/// Shared execution inputs every backend receives alongside the plan:
/// the dataset the plan was built from, the environment, the match
/// strategy, and the cross-backend service knobs (cache capacity,
/// scheduling policy).
pub struct ExecContext<'a> {
    /// The dataset the plan partitions (must be the one the plan was
    /// built from — the workflow layer checks the fingerprint).
    pub dataset: &'a Dataset,
    /// The computing environment to execute on.
    pub ce: &'a ComputingEnv,
    /// Match strategy (decides similarity + threshold).
    pub strategy: MatchStrategy,
    /// Partition-cache capacity per match service (0 = disabled).
    pub cache_capacity: usize,
    /// Task-assignment policy (FIFO or affinity).
    pub policy: Policy,
    /// Optional lifecycle tracer threaded through to the engine's
    /// scheduler and workers ([`Workflow::trace`] sets it; the sim
    /// backend ignores it — virtual-time stamps would not be
    /// comparable).
    ///
    /// [`Workflow::trace`]: crate::coordinator::Workflow::trace
    pub tracer: Option<Arc<Tracer>>,
}

/// Raw engine output, before the workflow layer merges per-task match
/// results.
pub struct EngineRun {
    /// Engine metrics (wall clock or virtual time, see engine docs).
    pub metrics: RunMetrics,
    /// Per-task match output, merged across services.
    pub correspondences: Vec<Correspondence>,
    /// Cost params used by the simulator (after calibration), when the
    /// backend simulates.
    pub cost: Option<CostParams>,
}

/// An execution backend: consumes a plan, returns an [`EngineRun`].
pub trait ExecutionBackend: fmt::Debug + Send + Sync {
    /// Short stable identifier (`"threads"`, `"sim"`, `"dist"`).
    fn name(&self) -> &'static str;

    /// Execute every task of `plan` under `ctx`.
    fn execute(
        &self,
        plan: &MatchPlan,
        ctx: &ExecContext<'_>,
    ) -> Result<EngineRun>;
}

/// Real OS threads inside this process; real matching; wall-clock
/// metrics ([`crate::engine::threads`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct Threads;

impl ExecutionBackend for Threads {
    fn name(&self) -> &'static str {
        "threads"
    }

    fn execute(
        &self,
        plan: &MatchPlan,
        ctx: &ExecContext<'_>,
    ) -> Result<EngineRun> {
        let store = DataService::build(ctx.dataset, &plan.partitions);
        let exec = RustExecutor::new(ctx.strategy);
        let out = threads::run(
            ctx.ce,
            &plan.partitions,
            plan.tasks.clone(),
            &store,
            &exec,
            threads::ThreadConfig {
                cache_capacity: ctx.cache_capacity,
                policy: ctx.policy,
                tracer: ctx.tracer.clone(),
            },
        );
        Ok(EngineRun {
            metrics: out.metrics,
            correspondences: out.correspondences,
            cost: None,
        })
    }
}

/// Options of the [`Sim`] backend (virtual-time simulator).
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// Control-plane cost model (workflow-service RMI).
    pub net: CostModel,
    /// Data-plane cost model (data-service partition fetches).
    pub data_net: CostModel,
    /// Also execute the tasks to produce real correspondences (small
    /// workloads only).
    pub execute: bool,
    /// Calibrate per-pair cost by really matching a sample (otherwise
    /// use the strategy's default constants).
    pub calibrate: bool,
    /// Use these cost params verbatim (skips calibration).  Sweeps
    /// MUST pin the cost once and reuse it — re-calibrating per
    /// configuration injects real-timer noise into virtual-time
    /// ratios.
    pub cost_override: Option<CostParams>,
    /// Simulated node failures (virtual ns, node index).
    pub failures: Vec<(u64, usize)>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            net: CostModel::lan(),
            data_net: CostModel::dbms(),
            execute: false,
            calibrate: true,
            cost_override: None,
            failures: Vec::new(),
        }
    }
}

/// Deterministic virtual-time simulation with calibrated costs
/// ([`crate::engine::sim`]); no matching performed (metrics only)
/// unless [`SimOptions::execute`] is set.
#[derive(Clone, Debug, Default)]
pub struct Sim(pub SimOptions);

impl ExecutionBackend for Sim {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn execute(
        &self,
        plan: &MatchPlan,
        ctx: &ExecContext<'_>,
    ) -> Result<EngineRun> {
        let opts = &self.0;
        let store = DataService::build(ctx.dataset, &plan.partitions);
        let cost = if let Some(cost) = opts.cost_override {
            cost
        } else if opts.calibrate {
            calibrate::calibrated_params(
                ctx.dataset,
                ctx.strategy.kind,
                120,
                0xCA11B,
            )
        } else {
            CostParams::default_for(ctx.strategy.kind)
        };
        let mut sim_cfg = sim::SimConfig::new(ctx.strategy.kind, cost);
        sim_cfg.net = opts.net;
        sim_cfg.data_net = opts.data_net;
        sim_cfg.cache_capacity = ctx.cache_capacity;
        sim_cfg.policy = ctx.policy;
        sim_cfg.failures = opts.failures.clone();
        if opts.execute {
            sim_cfg.execute =
                Some(Box::new(RustExecutor::new(ctx.strategy)));
        }
        let out = sim::run(
            ctx.ce,
            &plan.partitions,
            plan.tasks.clone(),
            &store,
            sim_cfg,
        );
        Ok(EngineRun {
            metrics: out.metrics,
            correspondences: out.correspondences,
            cost: Some(cost),
        })
    }
}

/// Options of the [`Dist`] backend (real TCP services).
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// Total data-plane servers (1 = just the primary; N > 1 adds N−1
    /// synced replicas and fetch failover).
    pub replicas: usize,
    /// Tasks pulled per control round trip (protocol batched
    /// assignment; 1 = classic per-task pull).
    pub batch: usize,
    /// Host the services bind (default loopback).
    pub bind: String,
    /// §3.1 memory-model enforcement: when set, every match node
    /// rejects assigned tasks whose plan footprint exceeds this budget
    /// with a typed `TaskRejected`, and the scheduler re-queues them
    /// marked oversize.  A task exceeding *every* node's budget is
    /// split by the scheduler into sub-tasks that fit (runtime
    /// BlockSplit, protocol v5); one that cannot be split — a single
    /// pair already over budget — fails the run fast with the typed
    /// [`crate::coordinator::PlanMisfit`] instead of burning the
    /// timeout.
    pub memory_budget: Option<u64>,
    /// Which [`PartitionStore`] backs the data-plane primary:
    /// [`StoreKind::Resident`] (everything in RAM) or
    /// [`StoreKind::Spill`] (byte-budgeted hot set over checksummed
    /// spill files — catalogs larger than RAM).
    ///
    /// [`PartitionStore`]: crate::store::PartitionStore
    pub store: StoreKind,
    /// Hot-set byte budget per data replica (partial replication);
    /// `None` = full replicas.  See
    /// [`crate::engine::dist::DistConfig::replica_hot_budget`].
    pub replica_hot_budget: Option<u64>,
}

impl Default for DistOptions {
    fn default() -> Self {
        DistOptions {
            replicas: 1,
            batch: 1,
            bind: "127.0.0.1".to_string(),
            memory_budget: None,
            store: StoreKind::Resident,
            replica_hot_budget: None,
        }
    }
}

/// Real services over real TCP ([`crate::engine::dist`]): workflow +
/// data services, `ce.nodes` match-service nodes, the [`crate::rpc`]
/// wire protocol in between; wall-clock metrics and actual socket-byte
/// traffic accounting.
#[derive(Clone, Debug, Default)]
pub struct Dist(pub DistOptions);

impl ExecutionBackend for Dist {
    fn name(&self) -> &'static str {
        "dist"
    }

    fn execute(
        &self,
        plan: &MatchPlan,
        ctx: &ExecContext<'_>,
    ) -> Result<EngineRun> {
        let opts = &self.0;
        let store = Arc::new(
            DataService::build_with(
                ctx.dataset,
                &plan.partitions,
                opts.store
                    .open()
                    .context("opening the partition store")?,
            )
            .context("loading partitions into the store")?,
        );
        let exec: Arc<dyn TaskExecutor> =
            Arc::new(RustExecutor::new(ctx.strategy));
        let out = dist::run(
            ctx.ce,
            &plan.partitions,
            plan.tasks.clone(),
            store,
            exec,
            dist::DistConfig {
                cache_capacity: ctx.cache_capacity,
                policy: ctx.policy,
                data_replicas: opts.replicas.max(1),
                batch: opts.batch.max(1),
                bind: opts.bind.clone(),
                task_mem: plan.task_mem.clone(),
                memory_budget: opts.memory_budget,
                replica_hot_budget: opts.replica_hot_budget,
                tracer: ctx.tracer.clone(),
                ..dist::DistConfig::default()
            },
        )?;
        Ok(EngineRun {
            metrics: out.metrics,
            correspondences: out.correspondences,
            cost: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan::MatchPlan;
    use crate::datagen::GeneratorConfig;
    use crate::matching::StrategyKind;
    use crate::partition::SizeBased;
    use crate::util::GIB;

    fn ctx<'a>(
        dataset: &'a Dataset,
        ce: &'a ComputingEnv,
    ) -> ExecContext<'a> {
        ExecContext {
            dataset,
            ce,
            strategy: MatchStrategy::new(StrategyKind::Wam),
            cache_capacity: 4,
            policy: Policy::Affinity,
            tracer: None,
        }
    }

    #[test]
    fn threads_backend_executes_a_plan() {
        let data = GeneratorConfig::tiny().with_entities(200).generate();
        let ce = ComputingEnv::new(1, 2, GIB);
        let plan = MatchPlan::build(
            &data.dataset,
            &SizeBased::with_max_size(50),
            StrategyKind::Wam,
            &ce,
        )
        .unwrap();
        let run = Threads.execute(&plan, &ctx(&data.dataset, &ce)).unwrap();
        assert_eq!(run.metrics.tasks, plan.n_tasks());
        assert_eq!(run.metrics.comparisons, 200 * 199 / 2);
        assert!(run.cost.is_none());
    }

    #[test]
    fn sim_backend_reports_cost_and_metrics_only() {
        let data = GeneratorConfig::tiny().with_entities(200).generate();
        let ce = ComputingEnv::paper_testbed(2);
        let plan = MatchPlan::build(
            &data.dataset,
            &SizeBased::with_max_size(50),
            StrategyKind::Wam,
            &ce,
        )
        .unwrap();
        let backend = Sim(SimOptions {
            calibrate: false,
            ..SimOptions::default()
        });
        let run = backend.execute(&plan, &ctx(&data.dataset, &ce)).unwrap();
        assert!(run.metrics.makespan_ns > 0);
        assert!(run.correspondences.is_empty(), "metrics only");
        assert!(run.cost.is_some());
    }
}
