//! Distributed execution engine: real services over real localhost TCP.
//!
//! The third engine.  Where [`super::threads`] shares one process's
//! memory and [`super::sim`] charges modeled costs on a virtual clock,
//! this engine launches the paper's §4 infrastructure for real:
//!
//! * a [`DataServiceServer`] serving partitions over TCP — plus, with
//!   `data_replicas > 1`, additional replica servers push-synced from
//!   it and announced into the coordinator's replica directory, so
//!   match nodes spread fetches and fail over when a replica dies,
//! * a [`WorkflowServiceServer`] running the pull-based scheduler with
//!   heartbeat-driven failure handling,
//! * `ce.nodes` match-service nodes — threads in this process, but
//!   every partition fetch, task assignment, completion report and
//!   heartbeat crosses a real socket through the [`crate::rpc`] wire
//!   protocol.
//!
//! The same services also run as separate OS processes (or hosts) via
//! `pem serve` / `pem distmatch`; this engine is the single-command
//! form that the workflow API and the tests drive.
//!
//! Metrics note: `bytes_fetched` reports **actual socket bytes** from
//! all data servers (frames included, and — in replicated runs — the
//! one-time replication push), not the modeled `approx_bytes` of the
//! other engines: the number a network monitor would see.
//! [`DistOutcome::replica_wire_bytes`] breaks it down per server.

use crate::cluster::ComputingEnv;
use crate::coordinator::scheduler::Policy;
use crate::metrics::RunMetrics;
use crate::model::{Correspondence, Dataset};
use crate::net::reactor::Reactor;
use crate::obs::Tracer;
use crate::partition::{MatchTask, PartitionSet};
use crate::service::{
    announce_replica, run_match_node, DataServiceServer, MatchNodeConfig,
    NodeReport, TenantHostConfig, WaitStatus, WorkflowReport,
    WorkflowServerConfig, WorkflowServiceServer,
};
use crate::store::DataService;
use crate::worker::TaskExecutor;
use anyhow::{bail, Context, Result};
use std::sync::Arc;
use std::time::Duration;

/// Distributed-engine configuration.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Partition-cache capacity per match service (0 = disabled).
    pub cache_capacity: usize,
    /// Task-assignment policy (FIFO or affinity).
    pub policy: Policy,
    /// Tasks pulled per control round trip (protocol v3 batched
    /// assignment; 1 = the classic per-task pull).  Batches amortize
    /// the request/assign round trip and enable the node-side
    /// prefetcher that overlaps execution with partition fetches.
    pub batch: usize,
    /// Host every service binds (the ROADMAP fix: servers used to bind
    /// `0.0.0.0` unconditionally).  The default keeps single-machine
    /// runs off external interfaces.
    pub bind: String,
    /// Total data-plane servers: 1 = just the primary (the pre-replica
    /// behavior); N > 1 additionally starts N−1 replicas, waits for
    /// their push-sync, and announces all N into the coordinator's
    /// replica directory.
    pub data_replicas: usize,
    /// Hot-set byte budget for each data replica (**partial
    /// replication**): instead of mirroring the whole catalog, a
    /// budgeted replica keeps only the most-demanded frames, redirects
    /// cold misses to the primary, and re-admits a shed frame once
    /// demand for it recurs.  `None` = full replicas (the pre-PR 9
    /// behavior).
    pub replica_hot_budget: Option<u64>,
    /// §3.1 memory footprint per task, aligned with the `tasks`
    /// argument of [`run`] (from the match plan).  Empty = no
    /// footprints: every assignment travels with footprint 0 and is
    /// never rejected.
    pub task_mem: Vec<u64>,
    /// §3.1 memory budget applied to every match node: a node rejects
    /// assigned tasks whose footprint exceeds it (`TaskRejected`,
    /// re-queued marked oversize).  `None` disables enforcement.  A
    /// task exceeding *every* node's budget is **split** by the
    /// scheduler into sub-tasks that fit the smallest budget (runtime
    /// BlockSplit, protocol v5) — and when even a single pair cannot
    /// fit, the run fails fast with the typed
    /// [`crate::coordinator::PlanMisfit`] instead of burning
    /// `run_timeout`.
    pub memory_budget: Option<u64>,
    /// Test hook: per-node budget overrides `(node_index, budget)`
    /// for heterogeneous-memory runs; overrides `memory_budget`.
    pub node_memory_budgets: Vec<(usize, u64)>,
    /// Failure detector: a silent service is failed after this long.
    pub heartbeat_timeout: Duration,
    /// Node-side liveness signal period.
    pub heartbeat_interval: Duration,
    /// Node back-off while the open list is momentarily empty.
    pub poll_interval: Duration,
    /// Give up if the workflow has not completed in this long.
    pub run_timeout: Duration,
    /// Test hook: `(node_index, tasks)` — that node crashes after
    /// completing `tasks` tasks (see [`MatchNodeConfig`]).
    pub fail_node_after: Vec<(usize, usize)>,
    /// Optional lifecycle tracer shared by the coordinator's scheduler
    /// **and** every in-process match node: one replayable stream of
    /// `Planned → … → Completed` events for the whole wire run
    /// (`pem match --trace`, chaos replay verification).
    pub tracer: Option<Arc<Tracer>>,
    /// Per-tenant in-flight cap for a *resident* cluster
    /// ([`serve_resident`]): at most this many of one tenant's tasks
    /// assigned at once, so a huge submitted plan cannot starve a
    /// small one.  Ignored by [`run`].  `None` = uncapped.
    pub per_tenant_inflight: Option<usize>,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            cache_capacity: 0,
            policy: Policy::Affinity,
            batch: 1,
            bind: "127.0.0.1".to_string(),
            data_replicas: 1,
            replica_hot_budget: None,
            task_mem: Vec::new(),
            memory_budget: None,
            node_memory_budgets: Vec::new(),
            heartbeat_timeout: Duration::from_secs(2),
            heartbeat_interval: Duration::from_millis(50),
            poll_interval: Duration::from_millis(2),
            run_timeout: Duration::from_secs(600),
            fail_node_after: Vec::new(),
            tracer: None,
            per_tenant_inflight: None,
        }
    }
}

/// A running resident multi-tenant cluster (protocol v7): the data
/// primary, a workflow server that accepts `PlanSubmit` frames, and
/// `ce.nodes` in-process match nodes that stay attached between
/// plans.  Built by [`serve_resident`]; lives until
/// [`ResidentCluster::shutdown`].
pub struct ResidentCluster {
    workflow: WorkflowServiceServer,
    data: DataServiceServer,
    nodes: Vec<std::thread::JoinHandle<Result<NodeReport>>>,
}

impl ResidentCluster {
    /// Control-plane address clients submit plans to (`pem submit
    /// --to`).
    pub fn workflow_addr(&self) -> std::net::SocketAddr {
        self.workflow.addr()
    }

    /// Data-plane primary address.
    pub fn data_addr(&self) -> std::net::SocketAddr {
        self.data.addr()
    }

    /// Tear the cluster down: abort both servers (their dropped
    /// connections unblock every node poll), join the node threads,
    /// and extract the final coordinator report.  Nodes exiting with
    /// a lost-coordinator error is the *expected* resident teardown,
    /// not a failure.
    pub fn shutdown(self) -> WorkflowReport {
        self.workflow.abort();
        self.data.shutdown();
        for h in self.nodes {
            let _ = h.join();
        }
        self.workflow.finish()
    }
}

/// Start a resident multi-tenant cluster serving `dataset`: the
/// workflow server is seeded with **no tasks** and a
/// [`TenantHostConfig`], so all work arrives as `PlanSubmit` frames
/// from clients; admitted plans' partitions are loaded into `store`
/// at run time, and the match nodes — which never see `done` — pull
/// whatever the fair scheduler interleaves.
pub fn serve_resident(
    ce: &ComputingEnv,
    dataset: Arc<Dataset>,
    store: Arc<DataService>,
    executor: Arc<dyn TaskExecutor>,
    cfg: DistConfig,
) -> Result<ResidentCluster> {
    let bind_ep = format!("{}:0", cfg.bind);
    let connect_host = if cfg.bind == "0.0.0.0" {
        "127.0.0.1"
    } else {
        cfg.bind.as_str()
    };
    // one reactor thread hosts both resident services (PR 8): the
    // control and data planes park in the same kernel wait instead of
    // two spinning loops, which is what makes leaving the cluster
    // resident essentially free when idle
    let mut reactor = Reactor::build()
        .context("building the shared service reactor")?;
    let data_srv =
        DataServiceServer::start_on(&mut reactor, store.clone(), &bind_ep)
            .context("starting data service")?;
    let data_addr =
        format!("{connect_host}:{}", data_srv.addr().port());
    let wf_srv = WorkflowServiceServer::start_on(
        &mut reactor,
        Vec::new(),
        WorkflowServerConfig {
            policy: cfg.policy,
            heartbeat_timeout: cfg.heartbeat_timeout,
            task_mem: std::collections::HashMap::new(),
            task_sizes: std::collections::HashMap::new(),
            expected_services: ce.nodes,
            tracer: cfg.tracer.clone(),
            tenancy: Some(TenantHostConfig {
                dataset,
                store,
                per_tenant_inflight: cfg.per_tenant_inflight,
            }),
        },
        &bind_ep,
    )
    .context("starting resident workflow service")?;
    reactor
        .spawn("pem-services")
        .context("spawning the shared service reactor")?;
    let wf_addr = format!("{connect_host}:{}", wf_srv.addr().port());
    announce_replica(
        &wf_addr,
        &data_addr,
        &data_srv.partition_ids(),
        Duration::from_secs(10),
    )
    .context("announcing the data primary")?;

    let nodes: Vec<_> = (0..ce.nodes)
        .map(|i| {
            let mut node_cfg =
                MatchNodeConfig::new(wf_addr.clone(), data_addr.clone());
            node_cfg.name = format!("resident-node-{i}");
            node_cfg.threads = ce.threads_per_node;
            node_cfg.cache_capacity = cfg.cache_capacity;
            node_cfg.batch = cfg.batch;
            node_cfg.task_memory_budget = cfg
                .node_memory_budgets
                .iter()
                .find(|(node, _)| *node == i)
                .map(|&(_, budget)| budget)
                .or(cfg.memory_budget);
            node_cfg.heartbeat_interval = cfg.heartbeat_interval;
            node_cfg.poll_interval = cfg.poll_interval;
            node_cfg.tracer = cfg.tracer.clone();
            let exec = executor.clone();
            std::thread::Builder::new()
                .name(format!("pem-resident-node-{i}"))
                .spawn(move || run_match_node(&node_cfg, exec))
                .expect("spawn match node")
        })
        .collect();
    Ok(ResidentCluster {
        workflow: wf_srv,
        data: data_srv,
        nodes,
    })
}

/// Outcome of a distributed run.
pub struct DistOutcome {
    /// Wall-clock run metrics (`bytes_fetched` = real socket bytes).
    pub metrics: RunMetrics,
    /// Merged match output across all nodes.
    pub correspondences: Vec<Correspondence>,
    /// Per-node execution reports.
    pub node_reports: Vec<NodeReport>,
    /// Coordinator-side statistics (requeues, stale completions, …).
    /// Its `correspondences` have been drained into
    /// [`DistOutcome::correspondences`].
    pub workflow: WorkflowReport,
    /// Actual data-plane socket bytes, all servers (also in
    /// `metrics.bytes_fetched`).
    pub data_wire_bytes: u64,
    /// Data-plane socket bytes per server — primary first, then the
    /// replicas in start order.  The per-replica accounting a network
    /// monitor would report.
    pub replica_wire_bytes: Vec<u64>,
}

/// Execute all tasks on `ce.nodes` match-service nodes ×
/// `ce.threads_per_node` workers each, over localhost TCP.
pub fn run(
    ce: &ComputingEnv,
    parts: &PartitionSet,
    tasks: Vec<MatchTask>,
    store: Arc<DataService>,
    executor: Arc<dyn TaskExecutor>,
    cfg: DistConfig,
) -> Result<DistOutcome> {
    let n_tasks = tasks.len();
    // every server binds the configured host (default loopback — the
    // ROADMAP fix for the unconditional 0.0.0.0 binds); the wildcard
    // is not a *connectable* address, so in-process clients dial
    // loopback when it is used
    let bind_ep = format!("{}:0", cfg.bind);
    let connect_host = if cfg.bind == "0.0.0.0" {
        "127.0.0.1"
    } else {
        cfg.bind.as_str()
    };
    // §3.1 footprints from the plan, keyed by task id for assignment,
    // plus the partition sizes the scheduler needs to *split* a task
    // no node's budget fits (runtime BlockSplit, protocol v5)
    let task_mem: std::collections::HashMap<u32, u64> = tasks
        .iter()
        .zip(cfg.task_mem.iter())
        .map(|(t, &m)| (t.id, m))
        .collect();
    let task_sizes: std::collections::HashMap<u32, (u32, u32)> = tasks
        .iter()
        .map(|t| {
            (
                t.id,
                (
                    parts.get(t.left).len() as u32,
                    parts.get(t.right).len() as u32,
                ),
            )
        })
        .collect();
    // the primary data server and the workflow server share one
    // reactor thread (PR 8); replicas still run their own so a
    // wedged replica cannot stall the primary's event loop
    let mut reactor = Reactor::build()
        .context("building the shared service reactor")?;
    let data_srv =
        DataServiceServer::start_on(&mut reactor, store, &bind_ep)
            .context("starting data service")?;
    let primary_addr =
        format!("{connect_host}:{}", data_srv.addr().port());
    let wf_srv = WorkflowServiceServer::start_on(
        &mut reactor,
        tasks,
        WorkflowServerConfig {
            policy: cfg.policy,
            heartbeat_timeout: cfg.heartbeat_timeout,
            task_mem,
            task_sizes,
            // splitting verdicts wait until the whole cluster joined
            expected_services: ce.nodes,
            tracer: cfg.tracer.clone(),
            tenancy: None,
        },
        &bind_ep,
    )
    .context("starting workflow service")?;
    reactor
        .spawn("pem-services")
        .context("spawning the shared service reactor")?;
    // replicated data plane: N−1 replicas push-synced from the primary
    let mut replica_srvs: Vec<DataServiceServer> = Vec::new();
    for r in 1..cfg.data_replicas.max(1) {
        let srv = match cfg.replica_hot_budget {
            Some(budget) => DataServiceServer::start_replica_partial(
                &bind_ep,
                &primary_addr,
                Duration::from_secs(30),
                budget,
            ),
            None => DataServiceServer::start_replica(
                &bind_ep,
                &primary_addr,
                Duration::from_secs(30),
            ),
        }
        .with_context(|| format!("starting data replica {r}"))?;
        replica_srvs.push(srv);
    }
    for (r, srv) in replica_srvs.iter().enumerate() {
        if !srv.wait_synced(Duration::from_secs(60)) {
            wf_srv.abort();
            data_srv.shutdown();
            for s in &replica_srvs {
                s.shutdown();
            }
            bail!("data replica {} did not sync in time", r + 1);
        }
    }

    let wf_addr =
        format!("{connect_host}:{}", wf_srv.addr().port());
    let data_addrs: Vec<String> = std::iter::once(&data_srv)
        .chain(replica_srvs.iter())
        .map(|s| format!("{connect_host}:{}", s.addr().port()))
        .collect();
    // announce every data server into the directory so the scheduler
    // sees replica coverage and late joiners learn all addresses
    for (addr, srv) in
        data_addrs.iter().zip(
            std::iter::once(&data_srv).chain(replica_srvs.iter()),
        )
    {
        announce_replica(
            &wf_addr,
            addr,
            &srv.partition_ids(),
            Duration::from_secs(10),
        )
        .with_context(|| format!("announcing data server {addr}"))?;
    }
    let start = crate::obs::Stopwatch::start();

    let node_handles: Vec<_> = (0..ce.nodes)
        .map(|i| {
            let mut node_cfg = MatchNodeConfig::new(
                wf_addr.clone(),
                data_addrs[0].clone(),
            );
            node_cfg.data_addrs = data_addrs.clone();
            node_cfg.name = format!("node-{i}");
            node_cfg.threads = ce.threads_per_node;
            node_cfg.cache_capacity = cfg.cache_capacity;
            node_cfg.batch = cfg.batch;
            node_cfg.task_memory_budget = cfg
                .node_memory_budgets
                .iter()
                .find(|(node, _)| *node == i)
                .map(|&(_, budget)| budget)
                .or(cfg.memory_budget);
            node_cfg.heartbeat_interval = cfg.heartbeat_interval;
            node_cfg.poll_interval = cfg.poll_interval;
            node_cfg.fail_after_tasks = cfg
                .fail_node_after
                .iter()
                .find(|(node, _)| *node == i)
                .map(|&(_, after)| after);
            node_cfg.tracer = cfg.tracer.clone();
            let exec = executor.clone();
            std::thread::Builder::new()
                .name(format!("pem-match-node-{i}"))
                .spawn(move || run_match_node(&node_cfg, exec))
                .expect("spawn match node")
        })
        .collect();

    let status = wf_srv.wait_outcome(cfg.run_timeout);
    let elapsed = start.elapsed_ns();
    let done = matches!(status, WaitStatus::Done);
    if !done {
        // timeout or §3.1 misfit — tear the wire down *before* joining
        // the node threads: with the servers aborted, every blocked
        // worker/heartbeat request errors out promptly, so the joins
        // below cannot hang on nodes still polling an un-finishable
        // workflow
        wf_srv.abort();
        data_srv.shutdown();
        for srv in &replica_srvs {
            srv.shutdown();
        }
    }

    let mut node_reports = Vec::new();
    let mut node_errors = Vec::new();
    for h in node_handles {
        match h.join().expect("match node panicked") {
            Ok(report) => node_reports.push(report),
            Err(e) => node_errors.push(e),
        }
    }
    data_srv.shutdown();
    for srv in &replica_srvs {
        srv.shutdown();
    }
    let replica_wire_bytes: Vec<u64> = std::iter::once(&data_srv)
        .chain(replica_srvs.iter())
        .map(|s| s.wire_bytes())
        .collect();
    let data_wire_bytes: u64 = replica_wire_bytes.iter().sum();
    let mut workflow = wf_srv.finish();

    if let WaitStatus::Misfit(misfit) = status {
        // the typed §3.1 fail-fast: callers can downcast to
        // `PlanMisfit` to distinguish "plan does not fit this
        // cluster" from infrastructure failures
        return Err(anyhow::Error::new(misfit).context(format!(
            "distributed run failed fast: {}/{} tasks complete",
            workflow.completed_tasks, workflow.total_tasks
        )));
    }
    if !done {
        bail!(
            "distributed run timed out: {}/{} tasks complete, \
             node errors: {:?}",
            workflow.completed_tasks,
            workflow.total_tasks,
            node_errors
        );
    }
    // the workflow completed: a node that errored out mid-run was
    // handled exactly like a crash (its tasks were re-queued and done
    // elsewhere), so the run as a whole still succeeded — report it
    for e in &node_errors {
        eprintln!(
            "dist engine: a match node failed mid-run \
             (workflow completed without it): {e:#}"
        );
    }

    let metrics = RunMetrics {
        makespan_ns: elapsed,
        tasks: workflow.completed_tasks,
        comparisons: workflow.comparisons,
        matches: workflow.correspondences.len(),
        cache_hits: node_reports.iter().map(|r| r.cache_hits).sum(),
        cache_misses: node_reports.iter().map(|r| r.cache_misses).sum(),
        bytes_fetched: data_wire_bytes,
        control_messages: workflow.control_messages,
        thread_busy_ns: node_reports
            .iter()
            .flat_map(|r| r.busy_ns.iter().copied())
            .collect(),
        affinity_hits: workflow.affinity_assignments,
    };
    debug_assert_eq!(workflow.completed_tasks, n_tasks);
    // drain rather than clone: the merged result can be large
    let correspondences = std::mem::take(&mut workflow.correspondences);
    Ok(DistOutcome {
        correspondences,
        metrics,
        node_reports,
        workflow,
        data_wire_bytes,
        replica_wire_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::matching::{MatchStrategy, StrategyKind};
    use crate::model::EntityId;
    use crate::partition::{generate_tasks, partition_size_based};
    use crate::worker::RustExecutor;

    fn setup(
        n: usize,
        m: usize,
    ) -> (PartitionSet, Vec<MatchTask>, Arc<DataService>) {
        let data = GeneratorConfig::tiny().with_entities(n).generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, m);
        let tasks = generate_tasks(&parts);
        let store = Arc::new(DataService::build(&data.dataset, &parts));
        (parts, tasks, store)
    }

    fn wam_exec() -> Arc<dyn TaskExecutor> {
        Arc::new(RustExecutor::new(MatchStrategy::new(StrategyKind::Wam)))
    }

    #[test]
    fn two_nodes_complete_all_tasks_over_sockets() {
        let (parts, tasks, store) = setup(400, 40);
        let n_tasks = tasks.len();
        let ce = ComputingEnv::new(2, 2, crate::util::GIB);
        let out = run(
            &ce,
            &parts,
            tasks,
            store,
            wam_exec(),
            DistConfig {
                cache_capacity: 8,
                ..DistConfig::default()
            },
        )
        .unwrap();
        assert_eq!(out.metrics.tasks, n_tasks);
        assert_eq!(out.metrics.comparisons, 400 * 399 / 2);
        assert!(out.metrics.bytes_fetched > 0, "real socket bytes");
        assert!(out.metrics.control_messages > n_tasks as u64);
        assert_eq!(out.node_reports.len(), 2);
        assert_eq!(out.workflow.services_joined, 2);
        assert_eq!(out.workflow.requeued_tasks, 0);
        // both nodes participated (pull balancing)
        for r in &out.node_reports {
            assert!(r.tasks_completed > 0, "idle node {:?}", r.service);
        }
    }

    /// With a replicated data plane, every data server carries real
    /// traffic (the selector spreads first-time fetches) and the
    /// per-replica accounting adds up to the total.
    #[test]
    fn replicated_data_plane_spreads_fetches_across_servers() {
        let (parts, tasks, store) = setup(400, 40);
        let n_tasks = tasks.len();
        let ce = ComputingEnv::new(2, 2, crate::util::GIB);
        let out = run(
            &ce,
            &parts,
            tasks,
            store,
            wam_exec(),
            DistConfig {
                cache_capacity: 4,
                data_replicas: 2,
                ..DistConfig::default()
            },
        )
        .unwrap();
        assert_eq!(out.metrics.tasks, n_tasks);
        assert_eq!(out.replica_wire_bytes.len(), 2);
        assert_eq!(
            out.replica_wire_bytes.iter().sum::<u64>(),
            out.data_wire_bytes
        );
        for (i, b) in out.replica_wire_bytes.iter().enumerate() {
            assert!(*b > 0, "data server {i} served no bytes");
        }
        // the directory reached the scheduler and the nodes
        assert_eq!(out.workflow.data_replicas.len(), 2);
        for r in &out.node_reports {
            assert_eq!(r.fetches_per_replica.len(), 2);
            assert_eq!(r.replica_failovers, 0);
        }
    }

    /// Batched assignment (protocol v3): the run completes with the
    /// same totals as the classic per-task pull while the control
    /// plane sees strictly fewer pulls than tasks, and every task
    /// flowed through a batch.
    #[test]
    fn batched_assignment_cuts_control_round_trips() {
        let (parts, tasks, store) = setup(400, 40);
        let n_tasks = tasks.len();
        let ce = ComputingEnv::new(2, 2, crate::util::GIB);
        let out = run(
            &ce,
            &parts,
            tasks,
            store,
            wam_exec(),
            DistConfig {
                cache_capacity: 8,
                batch: 4,
                // slow drain polls keep the pull count stable
                poll_interval: Duration::from_millis(20),
                ..DistConfig::default()
            },
        )
        .unwrap();
        assert_eq!(out.metrics.tasks, n_tasks);
        assert_eq!(out.metrics.comparisons, 400 * 399 / 2);
        assert!(out.workflow.batch_requests > 0, "v3 path exercised");
        assert!(
            out.workflow.batch_requests < n_tasks as u64,
            "{} pulls for {} tasks — batching must amortize them",
            out.workflow.batch_requests,
            n_tasks
        );
        assert_eq!(out.workflow.requeued_tasks, 0);
        assert_eq!(out.workflow.stale_completions, 0);
        for r in &out.node_reports {
            assert!(r.tasks_completed > 0, "idle node {:?}", r.service);
        }
    }

    /// §3.1 memory-model parity in the engine: with plan footprints
    /// attached and one node's budget below every task, that node
    /// rejects its assignments (`TaskRejected`), the scheduler
    /// re-routes them, and the roomier node completes the workflow —
    /// nothing lost, nothing double-completed.
    #[test]
    fn heterogeneous_memory_budgets_reroute_oversize_tasks() {
        let (parts, tasks, store) = setup(300, 60);
        let n_tasks = tasks.len();
        // the same footprints a MatchPlan would carry
        let task_mem: Vec<u64> = tasks
            .iter()
            .map(|t| {
                crate::partition::task_memory_bytes(
                    parts.get(t.left).len(),
                    parts.get(t.right).len(),
                    StrategyKind::Wam,
                )
            })
            .collect();
        let min_footprint =
            *task_mem.iter().min().expect("tasks exist");
        assert!(min_footprint > 100, "test premise");
        let ce = ComputingEnv::new(2, 1, crate::util::GIB);
        let out = run(
            &ce,
            &parts,
            tasks,
            store,
            wam_exec(),
            DistConfig {
                cache_capacity: 4,
                task_mem,
                // node 0 fits nothing; node 1 is unrestricted
                node_memory_budgets: vec![(0, 100)],
                ..DistConfig::default()
            },
        )
        .unwrap();
        assert_eq!(out.metrics.tasks, n_tasks);
        assert_eq!(out.metrics.comparisons, 300 * 299 / 2);
        assert!(
            out.workflow.oversize_rejections >= 1,
            "the capped node never rejected anything"
        );
        assert_eq!(out.workflow.requeued_tasks, 0, "no failures");
        let rejected: u64 =
            out.node_reports.iter().map(|r| r.tasks_rejected).sum();
        assert_eq!(rejected, out.workflow.oversize_rejections);
        // every completion ran on the unrestricted node
        for r in &out.node_reports {
            if r.tasks_rejected > 0 {
                assert_eq!(
                    r.tasks_completed, 0,
                    "capped node must not execute oversize work"
                );
            }
        }
        assert_eq!(
            out.node_reports
                .iter()
                .map(|r| r.tasks_completed)
                .sum::<u64>() as usize,
            n_tasks
        );
    }

    #[test]
    fn affinity_scheduling_works_through_the_wire() {
        let (parts, tasks, store) = setup(240, 40);
        let ce = ComputingEnv::new(2, 1, crate::util::GIB);
        let out = run(
            &ce,
            &parts,
            tasks,
            store,
            wam_exec(),
            DistConfig {
                cache_capacity: 16,
                policy: Policy::Affinity,
                ..DistConfig::default()
            },
        )
        .unwrap();
        // Cartesian tasks share partitions heavily: with caches on and
        // affinity policy, both cache hits and affinity assignments
        // must show up
        assert!(out.metrics.cache_hits > 0);
        assert!(out.metrics.affinity_hits > 0);
        assert!(out.metrics.hit_ratio() > 0.2);
    }
}
