//! Real-thread execution engine.
//!
//! Runs the same [`Scheduler`] + [`PartitionCache`] + [`TaskExecutor`]
//! stack as the simulator, but on actual OS threads with real matching
//! work and wall-clock timing.  One match service (cache + thread pool)
//! per configured node; all services share this process.
//!
//! On the single-core benchmark host this engine provides the 1-thread
//! baselines and correctness cross-checks against the simulator
//! (identical correspondence sets); the scale-out numbers come from
//! [`super::sim`].

use crate::cluster::ComputingEnv;
use crate::coordinator::scheduler::{Policy, Scheduler, ServiceId};
use crate::metrics::RunMetrics;
use crate::model::Correspondence;
use crate::obs::{Stopwatch, TraceEventKind, Tracer};
use crate::partition::{MatchTask, PartitionSet};
use crate::store::DataService;
use crate::util::lock_poisonless;
use crate::worker::{task_comparisons, PartitionCache, TaskExecutor};
use std::sync::{Arc, Mutex};

/// Thread-engine configuration.
pub struct ThreadConfig {
    /// Partition-cache capacity per match service (0 = disabled).
    pub cache_capacity: usize,
    /// Task-assignment policy (FIFO or affinity).
    pub policy: Policy,
    /// Optional lifecycle tracer: the scheduler records its decisions
    /// and the workers add `PartitionsFetched`/`Executed`, so a run's
    /// full task history can be dumped (`pem match --trace`) and
    /// replay-verified.
    pub tracer: Option<Arc<Tracer>>,
}

impl Default for ThreadConfig {
    fn default() -> Self {
        ThreadConfig {
            cache_capacity: 0,
            policy: Policy::Affinity,
            tracer: None,
        }
    }
}

/// Outcome of a thread-engine run.
pub struct ThreadOutcome {
    /// Wall-clock run metrics.
    pub metrics: RunMetrics,
    /// Per-task match output, merged.
    pub correspondences: Vec<Correspondence>,
}

/// Execute all tasks on real threads (`ce.nodes` services ×
/// `ce.threads_per_node` threads each).
pub fn run(
    ce: &ComputingEnv,
    _parts: &PartitionSet,
    tasks: Vec<MatchTask>,
    store: &DataService,
    executor: &dyn TaskExecutor,
    cfg: ThreadConfig,
) -> ThreadOutcome {
    let n_tasks = tasks.len();
    let mut sched = Scheduler::new(tasks, cfg.policy);
    if let Some(tracer) = cfg.tracer.clone() {
        sched.set_tracer(tracer);
    }
    let scheduler = Arc::new(Mutex::new(sched));
    let caches: Vec<Arc<PartitionCache>> = (0..ce.nodes)
        .map(|_| Arc::new(PartitionCache::new(cfg.cache_capacity)))
        .collect();
    for i in 0..ce.nodes {
        lock_poisonless(&scheduler).add_service(ServiceId(i));
    }

    let n_threads = ce.total_threads();
    let start = Stopwatch::start();
    let results: Mutex<Vec<Correspondence>> = Mutex::new(Vec::new());
    let comparisons = std::sync::atomic::AtomicU64::new(0);
    let done_tasks = std::sync::atomic::AtomicU64::new(0);
    let busy: Vec<std::sync::atomic::AtomicU64> =
        (0..n_threads).map(|_| Default::default()).collect();

    std::thread::scope(|scope| {
        for thread in 0..n_threads {
            let node = thread / ce.threads_per_node;
            let scheduler = scheduler.clone();
            let cache = caches[node].clone();
            let results = &results;
            let comparisons = &comparisons;
            let done_tasks = &done_tasks;
            let busy = &busy;
            let tracer = cfg.tracer.clone();
            scope.spawn(move || {
                loop {
                    let task = {
                        let mut s = lock_poisonless(&scheduler);
                        s.next_task(ServiceId(node))
                    };
                    let Some(task) = task else {
                        // open list empty: if everything completed, stop;
                        // otherwise wait for potential requeues
                        let done = lock_poisonless(&scheduler).is_done();
                        if done {
                            break;
                        }
                        std::thread::yield_now();
                        // re-check: remaining-but-in-flight tasks may
                        // finish without reopening; exit when done
                        let s = lock_poisonless(&scheduler);
                        if s.is_done() || s.remaining() == 0 {
                            break;
                        }
                        drop(s);
                        std::thread::sleep(
                            std::time::Duration::from_micros(50),
                        );
                        continue;
                    };

                    let t0 = Stopwatch::start();
                    // fetch through the service cache
                    let fetch = |pid| match cache.get(pid) {
                        Some(d) => d,
                        None => {
                            let d = store
                                .fetch(pid)
                                .expect("partition named by the plan");
                            cache.put(pid, d.clone());
                            d
                        }
                    };
                    let intra = task.left == task.right;
                    let left = fetch(task.left);
                    let right = if intra {
                        left.clone()
                    } else {
                        fetch(task.right)
                    };
                    if let Some(t) = &tracer {
                        t.record(
                            task.id,
                            TraceEventKind::PartitionsFetched,
                            Some(node as u64),
                            None,
                        );
                    }
                    let found = executor.execute(&left, &right, intra);
                    if let Some(t) = &tracer {
                        t.record(
                            task.id,
                            TraceEventKind::Executed,
                            Some(node as u64),
                            None,
                        );
                    }
                    comparisons.fetch_add(
                        task_comparisons(&task, left.len(), right.len()),
                        std::sync::atomic::Ordering::Relaxed,
                    );
                    done_tasks
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    busy[thread].fetch_add(
                        t0.elapsed_ns(),
                        std::sync::atomic::Ordering::Relaxed,
                    );
                    lock_poisonless(results).extend(found);
                    lock_poisonless(&scheduler).report_complete(
                        ServiceId(node),
                        task.id,
                        cache.status(),
                    );
                }
            });
        }
    });

    let elapsed = start.elapsed_ns();
    let sched = lock_poisonless(&scheduler);
    assert!(sched.is_done(), "thread engine finished incomplete");
    let correspondences = results.into_inner().unwrap();
    let metrics = RunMetrics {
        makespan_ns: elapsed,
        tasks: n_tasks,
        comparisons: comparisons.into_inner(),
        matches: correspondences.len(),
        cache_hits: caches.iter().map(|c| c.hits()).sum(),
        cache_misses: caches.iter().map(|c| c.misses()).sum(),
        bytes_fetched: store.traffic.total_bytes(),
        control_messages: 2 * n_tasks as u64,
        thread_busy_ns: busy.into_iter().map(|b| b.into_inner()).collect(),
        affinity_hits: sched.affinity_assignments,
    };
    ThreadOutcome {
        metrics,
        correspondences,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::matching::{MatchStrategy, StrategyKind};
    use crate::model::EntityId;
    use crate::partition::{generate_tasks, partition_size_based};
    use crate::worker::RustExecutor;

    fn setup(
        n: usize,
        m: usize,
    ) -> (
        crate::datagen::GeneratedData,
        PartitionSet,
        Vec<MatchTask>,
        DataService,
    ) {
        let data = GeneratorConfig::tiny().with_entities(n).generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, m);
        let tasks = generate_tasks(&parts);
        let store = DataService::build(&data.dataset, &parts);
        (data, parts, tasks, store)
    }

    #[test]
    fn completes_and_counts() {
        let (_, parts, tasks, store) = setup(300, 60);
        let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
        let n_tasks = tasks.len();
        let out = run(
            &ComputingEnv::new(1, 2, crate::util::GIB),
            &parts,
            tasks,
            &store,
            &exec,
            ThreadConfig::default(),
        );
        assert_eq!(out.metrics.tasks, n_tasks);
        // Cartesian over p partitions covers all n(n-1)/2 pairs
        assert_eq!(out.metrics.comparisons, 300 * 299 / 2);
        assert!(out.metrics.makespan_ns > 0);
    }

    #[test]
    fn result_invariant_across_parallelism_and_caching() {
        let (_, parts, tasks, store) = setup(250, 50);
        let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
        let sort_key =
            |c: &Correspondence| (c.e1, c.e2);
        let mut base: Option<Vec<(EntityId, EntityId)>> = None;
        for (nodes, threads, cache) in
            [(1, 1, 0), (1, 4, 0), (2, 2, 8), (4, 1, 16)]
        {
            let ce = ComputingEnv::new(nodes, threads, crate::util::GIB);
            let out = run(
                &ce,
                &parts,
                tasks.clone(),
                &store,
                &exec,
                ThreadConfig {
                    cache_capacity: cache,
                    policy: Policy::Affinity,
                    tracer: None,
                },
            );
            let mut pairs: Vec<(EntityId, EntityId)> = out
                .correspondences
                .iter()
                .map(|c| sort_key(c))
                .collect();
            pairs.sort_unstable();
            match &base {
                None => base = Some(pairs),
                Some(b) => assert_eq!(
                    &pairs, b,
                    "results differ at ({nodes},{threads},{cache})"
                ),
            }
        }
    }

    #[test]
    fn caching_reduces_store_fetches() {
        let (_, parts, tasks, store_nc) = setup(400, 50);
        let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
        let ce = ComputingEnv::new(1, 2, crate::util::GIB);
        let out_nc = run(
            &ce,
            &parts,
            tasks.clone(),
            &store_nc,
            &exec,
            ThreadConfig {
                cache_capacity: 0,
                policy: Policy::Affinity,
                tracer: None,
            },
        );
        let (_, parts2, tasks2, store_c) = setup(400, 50);
        let out_c = run(
            &ce,
            &parts2,
            tasks2,
            &store_c,
            &exec,
            ThreadConfig {
                cache_capacity: 16,
                policy: Policy::Affinity,
                tracer: None,
            },
        );
        assert_eq!(out_nc.metrics.cache_hits, 0);
        assert!(out_c.metrics.cache_hits > 0);
        assert!(store_c.fetches() < store_nc.fetches());
        assert!(out_c.metrics.hit_ratio() > 0.5);
    }

    /// A traced run records a replayable lifecycle: every plan task
    /// completes exactly once, every execution was preceded by an
    /// assignment, and each `Executed` is bracketed by a
    /// `PartitionsFetched` from the same node.
    #[test]
    fn traced_run_replays_exactly_once() {
        let (_, parts, tasks, store) = setup(200, 40);
        let exec = RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
        let plan_ids: Vec<u32> = tasks.iter().map(|t| t.id).collect();
        let tracer = crate::obs::Tracer::new(1 << 16);
        let out = run(
            &ComputingEnv::new(2, 2, crate::util::GIB),
            &parts,
            tasks,
            &store,
            &exec,
            ThreadConfig {
                cache_capacity: 8,
                policy: Policy::Affinity,
                tracer: Some(tracer.clone()),
            },
        );
        assert_eq!(out.metrics.tasks, plan_ids.len());
        let summary = tracer.verify_plan(&plan_ids).unwrap();
        assert_eq!(summary.plan_tasks, plan_ids.len());
        assert_eq!(summary.splits, 0);
        assert_eq!(summary.requeues, 0);
        assert_eq!(summary.assignments, plan_ids.len());
        let events = tracer.events();
        let executed = events
            .iter()
            .filter(|e| e.kind == crate::obs::TraceEventKind::Executed)
            .count();
        assert_eq!(executed, plan_ids.len());
    }

    #[test]
    fn matches_sim_execute_mode_results() {
        let (_, parts, tasks, store) = setup(200, 40);
        let strategy = MatchStrategy::new(StrategyKind::Lrm);
        let exec = RustExecutor::new(strategy);
        let ce = ComputingEnv::new(2, 2, crate::util::GIB);
        let thread_out = run(
            &ce,
            &parts,
            tasks.clone(),
            &store,
            &exec,
            ThreadConfig::default(),
        );
        let mut sim_cfg = crate::engine::sim::SimConfig::new(
            StrategyKind::Lrm,
            crate::engine::CostParams::default_for(StrategyKind::Lrm),
        );
        sim_cfg.execute = Some(Box::new(RustExecutor::new(strategy)));
        let sim_out =
            crate::engine::sim::run(&ce, &parts, tasks, &store, sim_cfg);
        let norm = |cs: &[Correspondence]| {
            let mut v: Vec<(EntityId, EntityId)> =
                cs.iter().map(|c| (c.e1, c.e2)).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(
            norm(&thread_out.correspondences),
            norm(&sim_out.correspondences)
        );
    }

    /// Wedge regression (PR 8 bug class, now lint-enforced as L2): a
    /// worker that panics while holding the scheduler lock poisons the
    /// mutex, and every `.lock().unwrap()` after that would wedge the
    /// whole engine.  The scheduler path goes through
    /// `lock_poisonless`, so a poisoned scheduler keeps dispatching.
    #[test]
    fn poisoned_scheduler_mutex_keeps_dispatching() {
        let (_, _parts, tasks, _store) = setup(100, 20);
        let n_tasks = tasks.len();
        let scheduler =
            Arc::new(Mutex::new(Scheduler::new(tasks, Policy::Affinity)));
        // poison the mutex: panic while holding the guard
        let poisoner = Arc::clone(&scheduler);
        std::thread::spawn(move || {
            let _guard = poisoner.lock().unwrap();
            panic!("deliberate poison");
        })
        .join()
        .unwrap_err();
        assert!(scheduler.is_poisoned());
        // the engine's scheduler path still works end to end
        lock_poisonless(&scheduler).add_service(ServiceId(0));
        let mut completed = 0usize;
        while let Some(task) =
            lock_poisonless(&scheduler).next_task(ServiceId(0))
        {
            lock_poisonless(&scheduler).report_complete(
                ServiceId(0),
                task.id,
                Vec::new(),
            );
            completed += 1;
        }
        assert_eq!(completed, n_tasks);
        assert!(lock_poisonless(&scheduler).is_done());
    }
}
