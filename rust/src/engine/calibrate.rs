//! Calibration: anchor the simulator's virtual clock with real measured
//! per-pair match costs on this host.
//!
//! Runs the actual Rust matchers over a sample of entity pairs from the
//! real dataset and returns the measured mean cost of one comparison.
//! The result feeds [`super::CostParams::pair_ns`], so simulated
//! makespans are “this workload on the modeled cluster at this host's
//! single-core speed”.

use super::CostParams;
use crate::features::EntityFeatures;
use crate::matching::{MatchStrategy, StrategyKind};
use crate::model::Dataset;
use crate::obs::Stopwatch;
use crate::util::Rng;

/// Measured calibration result.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// The strategy that was measured.
    pub strategy: StrategyKind,
    /// Measured mean cost of one pair comparison, nanoseconds.
    pub pair_ns: f64,
    /// How many comparisons the measurement averaged over.
    pub pairs_measured: u64,
}

/// Measure the mean per-pair cost of `strategy` on a sample of up to
/// `sample_entities` entities from `dataset` (all pairs of the sample,
/// at least `min_pairs` comparisons).
pub fn calibrate(
    dataset: &Dataset,
    strategy: StrategyKind,
    sample_entities: usize,
    seed: u64,
) -> Calibration {
    let mut rng = Rng::new(seed);
    let n = dataset.len().min(sample_entities).max(2);
    // sample without replacement via shuffle prefix
    let mut idx: Vec<usize> = (0..dataset.len()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(n);
    let feats: Vec<EntityFeatures> = idx
        .iter()
        .map(|&i| EntityFeatures::of(&dataset.entities[i], dataset))
        .collect();

    let ms = MatchStrategy::new(strategy);
    // warmup: one pass over a small prefix
    let warm = feats.len().min(20);
    for i in 0..warm {
        for j in (i + 1)..warm {
            std::hint::black_box(ms.similarity(&feats[i], &feats[j]));
        }
    }

    let start = Stopwatch::start();
    let mut pairs = 0u64;
    for i in 0..feats.len() {
        for j in (i + 1)..feats.len() {
            std::hint::black_box(ms.similarity(&feats[i], &feats[j]));
            pairs += 1;
        }
    }
    let elapsed = start.elapsed_ns() as f64;
    Calibration {
        strategy,
        pair_ns: elapsed / pairs.max(1) as f64,
        pairs_measured: pairs,
    }
}

/// Convenience: calibrated cost params for a strategy.
pub fn calibrated_params(
    dataset: &Dataset,
    strategy: StrategyKind,
    sample_entities: usize,
    seed: u64,
) -> CostParams {
    let c = calibrate(dataset, strategy, sample_entities, seed);
    CostParams::default_for(strategy).with_pair_ns(c.pair_ns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;

    #[test]
    fn calibration_measures_positive_cost() {
        let data = GeneratorConfig::tiny().with_seed(1).generate();
        let c = calibrate(&data.dataset, StrategyKind::Wam, 60, 7);
        assert!(c.pair_ns > 0.0 && c.pair_ns.is_finite());
        assert_eq!(c.pairs_measured, 60 * 59 / 2);
    }

    #[test]
    fn lrm_costs_more_than_wam() {
        if cfg!(debug_assertions) {
            // the relation holds for the optimized production build the
            // simulator calibrates against; unoptimized debug code skews
            // the banded DP vs sorted-merge balance the other way
            return;
        }
        let data = GeneratorConfig::tiny().with_seed(2).generate();
        let w = calibrate(&data.dataset, StrategyKind::Wam, 50, 3);
        let l = calibrate(&data.dataset, StrategyKind::Lrm, 50, 3);
        // LRM evaluates 3 matchers incl. a 4096-dim cosine; WAM discards
        // early.  Allow slack for timer noise but LRM must be dearer.
        assert!(
            l.pair_ns > w.pair_ns,
            "lrm {} <= wam {}",
            l.pair_ns,
            w.pair_ns
        );
    }

    #[test]
    fn calibrated_params_plumbs_measurement() {
        let data = GeneratorConfig::tiny().with_seed(3).generate();
        let p = calibrated_params(&data.dataset, StrategyKind::Wam, 40, 5);
        assert!(p.pair_ns > 0.0);
        // other fields keep their strategy defaults
        let d = CostParams::default_for(StrategyKind::Wam);
        assert_eq!(p.mem_fraction, d.mem_fraction);
        assert_eq!(p.task_overhead_ns, d.task_overhead_ns);
    }
}
