//! Execution engines for parallel match workflows.
//!
//! * [`threads`] — real OS threads inside this process.  Exercises the
//!   exact scheduler/cache/executor code under true concurrency; on this
//!   single-core host it is used for correctness tests and the 1-thread
//!   baseline.
//! * [`sim`] — a **deterministic discrete-event simulator** in virtual
//!   time.  Models the full computing environment `CE = (nodes, cores,
//!   mem)` of the paper's testbed, charging calibrated compute costs and
//!   modeled network / memory costs (DESIGN.md §Substitutions).  All
//!   scale-out experiments (Figs 5–9, Tables 1–2) run here.
//! * [`dist`] — the **distributed engine**: the paper's §4 services as
//!   real TCP endpoints ([`crate::service`]) with match-service nodes
//!   pulling tasks and fetching partitions over actual sockets.  Same
//!   scheduler, same executors, real wire.
//! * [`calibrate`] — measures real per-pair match cost on this host to
//!   anchor the simulator's virtual clock.
//! * [`backend`] — the [`backend::ExecutionBackend`] trait that wraps
//!   each engine behind the plan/execute split, with per-backend typed
//!   option structs.

#![warn(missing_docs)]

pub mod backend;
pub mod calibrate;
pub mod dist;
pub mod sim;
pub mod threads;

use crate::matching::StrategyKind;

/// Cost parameters of one match strategy on the reference node.
///
/// The virtual service time of a match task with `n` pair comparisons on
/// a node running `t` active threads over `c` cores is
///
/// ```text
/// time = overhead + n · pair_ns · (cpu + mem·(1 + γ·(min(t,c)−1))) · paging
/// ```
///
/// where `cpu + mem = 1` splits the per-pair cost into a compute-bound
/// part (scales perfectly with cores) and a memory-bandwidth-bound part
/// (contends with the other active threads of the node, factor `γ` per
/// extra thread), and `paging ≥ 1` penalizes tasks whose estimated
/// footprint exceeds the per-thread budget (soft: quadratic approach to
/// the budget, reproducing GC pressure; hard: linear beyond it).  This is
/// what makes LRM degrade for large partitions (paper Fig 6) and stop
/// scaling past the core count (Fig 5) while WAM keeps scaling.
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    /// Calibrated mean cost of one pair comparison, nanoseconds.
    pub pair_ns: f64,
    /// Fraction of the pair cost bound by memory bandwidth (0..1).
    pub mem_fraction: f64,
    /// Memory-contention factor per additional active thread.
    pub gamma: f64,
    /// Fixed per-task overhead (start/terminate a match task), ns.
    pub task_overhead_ns: u64,
    /// Soft (GC-pressure) paging coefficient.
    pub soft_paging: f64,
    /// Hard paging coefficient once the footprint exceeds the budget.
    pub hard_paging: f64,
}

impl CostParams {
    /// Uncalibrated defaults per strategy; `pair_ns` is replaced by
    /// [`calibrate::calibrate`] in real runs.  WAM's discard optimization
    /// keeps it compute-bound and cheap; LRM evaluates three matchers and
    /// builds model features, making it dearer and more memory-bound.
    pub fn default_for(strategy: StrategyKind) -> CostParams {
        match strategy {
            StrategyKind::Wam => CostParams {
                pair_ns: 900.0,
                mem_fraction: 0.12,
                gamma: 0.18,
                task_overhead_ns: 8_000_000, // 8 ms start/stop + result ship
                soft_paging: 0.5,
                hard_paging: 2.0,
            },
            StrategyKind::Lrm => CostParams {
                pair_ns: 2600.0,
                mem_fraction: 0.42,
                gamma: 0.30,
                task_overhead_ns: 12_000_000,
                soft_paging: 0.9,
                hard_paging: 2.5,
            },
        }
    }

    /// Replace the per-pair cost (builder style).
    pub fn with_pair_ns(mut self, pair_ns: f64) -> Self {
        self.pair_ns = pair_ns;
        self
    }

    /// Effective per-pair cost with `active` threads sharing a node's
    /// memory system (`active` already clamped to the core count).
    pub fn pair_cost_contended(&self, active: usize) -> f64 {
        let cpu = 1.0 - self.mem_fraction;
        let mem = self.mem_fraction
            * (1.0 + self.gamma * active.saturating_sub(1) as f64);
        self.pair_ns * (cpu + mem)
    }

    /// Paging penalty for a task of `demand` bytes against a per-thread
    /// `budget`.
    pub fn paging_penalty(&self, demand: u64, budget: u64) -> f64 {
        if budget == 0 {
            return 1.0 + self.hard_paging;
        }
        let ratio = demand as f64 / budget as f64;
        let soft = self.soft_paging * ratio * ratio;
        let hard = if ratio > 1.0 {
            self.hard_paging * (ratio - 1.0)
        } else {
            0.0
        };
        1.0 + soft + hard
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contention_grows_with_threads() {
        let p = CostParams::default_for(StrategyKind::Lrm);
        let c1 = p.pair_cost_contended(1);
        let c4 = p.pair_cost_contended(4);
        assert!((c1 - p.pair_ns).abs() < 1e-9, "1 thread = base cost");
        assert!(c4 > c1);
        // WAM is less memory-bound → contends less
        let w = CostParams::default_for(StrategyKind::Wam);
        assert!(
            c4 / c1 > w.pair_cost_contended(4) / w.pair_cost_contended(1)
        );
    }

    #[test]
    fn paging_penalty_shape() {
        let p = CostParams::default_for(StrategyKind::Lrm);
        let budget = 750 * crate::util::MIB;
        let none = p.paging_penalty(0, budget);
        let half = p.paging_penalty(budget / 2, budget);
        let full = p.paging_penalty(budget, budget);
        let double = p.paging_penalty(2 * budget, budget);
        assert!((none - 1.0).abs() < 1e-12);
        assert!(none < half && half < full && full < double);
        assert!(double > 2.0, "hard paging dominates: {double}");
    }

    #[test]
    fn lrm_dearer_than_wam() {
        let w = CostParams::default_for(StrategyKind::Wam);
        let l = CostParams::default_for(StrategyKind::Lrm);
        assert!(l.pair_ns > w.pair_ns);
        assert!(l.mem_fraction > w.mem_fraction);
        assert!(l.task_overhead_ns > w.task_overhead_ns);
    }
}
