//! Per-connection session state machines for the readiness-driven
//! servers: incremental frame decoding and buffered outbound frames.
//!
//! The blocking [`super::Transport`] can simply `read_exact` a whole
//! frame; a nonblocking server cannot — the kernel hands it whatever
//! bytes happen to have arrived, which may be half a length prefix,
//! three coalesced frames, or one byte of a megabyte payload.  This
//! module contains the two state machines the
//! [`crate::net::reactor`] drives per connection:
//!
//! * [`SessionDecoder`] — absorbs arbitrary read chunks and yields
//!   complete frame payloads, enforcing [`MAX_FRAME_BYTES`] on the
//!   announced length *before* buffering the body;
//! * [`SessionEncoder`] — queues outbound frames and writes as much
//!   as the socket accepts, carrying partial writes across readiness
//!   events.  Since PR 8 the length prefix and body go down in one
//!   vectored write, owned encode buffers are recycled through a
//!   bounded spare pool, and shared payloads
//!   ([`SessionEncoder::queue_shared`]) are written straight from
//!   their `Arc` allocation — the partition-fetch path frames
//!   `PartitionData` bytes with zero intermediate copies.
//!
//! Both are pure byte-level machines with no socket inside, so the
//! property tests below can fuzz every chunk boundary: the decoder is
//! held byte-identical to the blocking codec under 1-byte reads, split
//! length prefixes and coalesced frames, and the encoder under short
//! writes and spurious `WouldBlock`s.
//!
//! **Buffering bounds** (normative, `docs/WIRE_PROTOCOL.md` § Framing):
//! inbound, a session buffers at most one partial frame — 4 prefix
//! bytes plus [`MAX_FRAME_BYTES`] — and a length header above the limit
//! is a framing violation answered by hanging up; outbound, a peer that
//! stops draining its socket may have at most
//! [`MAX_SESSION_SEND_BYTES`] queued against it before the server hangs
//! up on it.

use super::{Message, WireError, MAX_FRAME_BYTES};
use std::collections::VecDeque;
use std::io::{self, IoSlice, Write};
use std::sync::Arc;

/// Upper bound on bytes queued toward one peer that is not draining
/// its socket.  Generous enough for a full replication stream of an
/// extreme store; anything beyond it means the peer is gone or wedged
/// and the server hangs up instead of buffering without bound.
pub const MAX_SESSION_SEND_BYTES: usize = 1 << 30;

/// Incremental frame decoder: feed arbitrary byte chunks, pull
/// complete frame payloads.
///
/// The consumed prefix of the internal buffer is reclaimed lazily, so
/// feeding and draining are amortized O(bytes).
#[derive(Debug, Default)]
pub struct SessionDecoder {
    buf: Vec<u8>,
    start: usize,
}

impl SessionDecoder {
    /// Fresh decoder with no buffered bytes.
    pub fn new() -> SessionDecoder {
        SessionDecoder::default()
    }

    /// Absorb one read chunk (any size, including empty).
    pub fn feed(&mut self, chunk: &[u8]) {
        self.buf.extend_from_slice(chunk);
    }

    /// Extract the next complete frame payload, if one is buffered.
    ///
    /// `Ok(None)` means more bytes are needed;
    /// [`WireError::FrameTooLarge`] means the stream is corrupt (or
    /// hostile) and the connection must be dropped — the oversized
    /// body was never buffered.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>, WireError> {
        if self.buffered() < 4 {
            self.compact();
            return Ok(None);
        }
        let prefix: [u8; 4] =
            self.buf[self.start..self.start + 4].try_into().unwrap();
        let len = u32::from_le_bytes(prefix) as u64;
        if len > MAX_FRAME_BYTES {
            return Err(WireError::FrameTooLarge(len));
        }
        let len = len as usize;
        if self.buffered() < 4 + len {
            self.compact();
            return Ok(None);
        }
        let body_start = self.start + 4;
        let payload = self.buf[body_start..body_start + len].to_vec();
        self.start += 4 + len;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        }
        Ok(Some(payload))
    }

    /// Bytes buffered but not yet consumed as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Reclaim the consumed prefix (called when the caller is about to
    /// wait for more bytes, so the buffer never grows past one frame).
    fn compact(&mut self) {
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }
}

/// Recycled encode buffers above this capacity are dropped instead of
/// pooled, so one giant control frame cannot pin its allocation.
const SPARE_BUF_CAP: usize = 64 * 1024;

/// At most this many recycled encode buffers are pooled per session.
const SPARE_BUFS: usize = 8;

/// The bytes of one queued outbound frame body.
#[derive(Debug)]
enum OutBody {
    /// Encoder-owned bytes (control replies); the buffer returns to
    /// the spare pool once written.
    Owned(Vec<u8>),
    /// Shared, already-encoded bytes written straight from their
    /// owner's allocation — the zero-copy partition-fetch path.  The
    /// session never copies them and never pools them.
    Shared(Arc<Vec<u8>>),
}

impl OutBody {
    fn as_slice(&self) -> &[u8] {
        match self {
            OutBody::Owned(v) => v,
            OutBody::Shared(v) => v,
        }
    }
}

/// One queued frame: 4-byte little-endian length prefix + body.  The
/// prefix lives beside the body instead of being copied in front of
/// it; [`SessionEncoder::flush_into`] stitches the two together with
/// a vectored write.
#[derive(Debug)]
struct OutFrame {
    header: [u8; 4],
    body: OutBody,
}

impl OutFrame {
    fn wire_len(&self) -> usize {
        4 + self.body.as_slice().len()
    }
}

/// Outbound frame queue with partial-write tracking.
///
/// Frames are queued with their length prefix held separately and
/// drained by [`SessionEncoder::flush_into`], which writes as much as
/// the sink accepts (header + body in one vectored call where the
/// sink supports it) and resumes mid-frame on the next readiness
/// event.  Two paths feed it:
///
/// * **owned** ([`SessionEncoder::queue_message`] /
///   [`SessionEncoder::queue_payload`]): the body is encoded into a
///   session-recycled buffer (bounded spare pool, no per-frame
///   allocation in steady state);
/// * **shared** ([`SessionEncoder::queue_shared`]): the body is an
///   `Arc<Vec<u8>>` written in place — partition fetches are framed
///   without any intermediate copy.
#[derive(Debug, Default)]
pub struct SessionEncoder {
    /// Complete frames; the front one may be partially written.
    queue: VecDeque<OutFrame>,
    /// Bytes of the front frame already written (prefix included).
    offset: usize,
    /// Total unwritten bytes across the queue.
    pending: usize,
    /// Recycled owned encode buffers (bounded by [`SPARE_BUFS`] ×
    /// [`SPARE_BUF_CAP`]).
    spare: Vec<Vec<u8>>,
}

impl SessionEncoder {
    /// Fresh encoder with nothing queued.
    pub fn new() -> SessionEncoder {
        SessionEncoder::default()
    }

    /// Queue one message as a frame; returns the frame's full wire
    /// footprint (payload + length prefix) for traffic accounting.
    /// The encoding lands directly in a recycled session buffer.
    pub fn queue_message(&mut self, msg: &Message) -> u64 {
        let mut body = self.take_buf();
        msg.encode_into(&mut body);
        self.queue_body(OutBody::Owned(body))
    }

    /// Queue one pre-encoded payload as a frame (the length prefix is
    /// added here); returns the frame's full wire footprint.  Payloads
    /// above [`MAX_FRAME_BYTES`] are a caller bug — servers only queue
    /// payloads they themselves encoded under the limit.
    pub fn queue_payload(&mut self, payload: &[u8]) -> u64 {
        let mut body = self.take_buf();
        body.extend_from_slice(payload);
        self.queue_body(OutBody::Owned(body))
    }

    /// Queue shared pre-encoded bytes as a frame, written straight
    /// from the shared allocation (no copy into session buffers).
    /// This is how the data service serves its cached per-partition
    /// encodings to any number of fetchers at once.
    pub fn queue_shared(&mut self, payload: Arc<Vec<u8>>) -> u64 {
        self.queue_body(OutBody::Shared(payload))
    }

    fn queue_body(&mut self, body: OutBody) -> u64 {
        let len = body.as_slice().len();
        debug_assert!(len as u64 <= MAX_FRAME_BYTES);
        let frame = OutFrame { header: (len as u32).to_le_bytes(), body };
        self.pending += frame.wire_len();
        self.queue.push_back(frame);
        (len + 4) as u64
    }

    /// A cleared buffer from the spare pool, or a fresh one.
    fn take_buf(&mut self) -> Vec<u8> {
        match self.spare.pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => Vec::new(),
        }
    }

    /// Return a fully-written frame's buffer to the spare pool.
    fn recycle(&mut self, frame: OutFrame) {
        if let OutBody::Owned(buf) = frame.body {
            if buf.capacity() <= SPARE_BUF_CAP && self.spare.len() < SPARE_BUFS {
                self.spare.push(buf);
            }
        }
    }

    /// `true` when every queued byte has been written.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Bytes queued but not yet accepted by the sink.
    pub fn pending_bytes(&self) -> usize {
        self.pending
    }

    /// Total capacity held by the recycled-buffer pool.  Test hook:
    /// the shared (zero-copy) path must never grow it.
    pub fn spare_capacity_bytes(&self) -> usize {
        self.spare.iter().map(|b| b.capacity()).sum()
    }

    /// Write as much as `w` accepts right now; a `WouldBlock` stops
    /// the drain without error (the remainder is retried on the next
    /// readiness event).  Returns the bytes written by this call.
    ///
    /// While the 4-byte prefix of the front frame is unwritten, the
    /// prefix remainder and the whole body go down in **one vectored
    /// write**, so a partition fetch reaches the socket as
    /// `writev(header, shared_payload)` with zero staging copies.
    pub fn flush_into<W: Write>(&mut self, w: &mut W) -> io::Result<usize> {
        let mut total = 0;
        loop {
            let (frame_len, wrote) = {
                let Some(front) = self.queue.front() else { break };
                let body = front.body.as_slice();
                let wrote = if self.offset < 4 {
                    let slices =
                        [IoSlice::new(&front.header[self.offset..]), IoSlice::new(body)];
                    w.write_vectored(&slices)
                } else {
                    w.write(&body[self.offset - 4..])
                };
                (4 + body.len(), wrote)
            };
            match wrote {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "sink accepted no bytes",
                    ));
                }
                Ok(n) => {
                    total += n;
                    self.offset += n;
                    self.pending -= n;
                    if self.offset == frame_len {
                        let done = self.queue.pop_front().expect("front frame exists");
                        self.offset = 0;
                        self.recycle(done);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::testutil::arbitrary_messages;
    use crate::rpc::write_frame;
    use crate::util::proptest::forall;
    use crate::util::Rng;

    /// Encode `msgs` with the blocking codec into one contiguous byte
    /// stream (the exact bytes a `Transport` would put on the wire).
    fn blocking_stream(msgs: &[Message]) -> Vec<u8> {
        let mut stream = Vec::new();
        for m in msgs {
            write_frame(&mut stream, m).unwrap();
        }
        stream
    }

    /// Split `stream` into random chunks: mostly tiny (down to one
    /// byte, so length prefixes get split), sometimes large (so frames
    /// get coalesced).
    fn random_chunks(rng: &mut Rng, stream: &[u8]) -> Vec<Vec<u8>> {
        let mut chunks = Vec::new();
        let mut pos = 0;
        while pos < stream.len() {
            let remaining = stream.len() - pos;
            let n = if rng.gen_bool(0.4) {
                1
            } else {
                1 + rng.gen_range(remaining.min(96))
            };
            chunks.push(stream[pos..pos + n].to_vec());
            pos += n;
        }
        chunks
    }

    /// Property (the tentpole's decoder guarantee): feeding the
    /// blocking codec's byte stream through [`SessionDecoder`] under
    /// arbitrary chunk splits yields exactly the blocking codec's
    /// payloads, byte for byte, for every v2/v3 frame type.
    #[test]
    fn prop_decoder_matches_blocking_codec_under_any_chunking() {
        forall("session-decode-chunked", 48, |rng| {
            let msgs = arbitrary_messages(rng);
            let expected: Vec<Vec<u8>> =
                msgs.iter().map(Message::encode).collect();
            let stream = blocking_stream(&msgs);
            let mut dec = SessionDecoder::new();
            let mut got: Vec<Vec<u8>> = Vec::new();
            for chunk in random_chunks(rng, &stream) {
                dec.feed(&chunk);
                while let Some(payload) = dec.next_frame().unwrap() {
                    got.push(payload);
                }
            }
            assert_eq!(got, expected, "payload mismatch after chunking");
            assert_eq!(dec.buffered(), 0, "bytes left over");
            // and every recovered payload still decodes canonically
            for payload in &got {
                let msg = Message::decode(payload).unwrap();
                assert_eq!(&msg.encode(), payload);
            }
        });
    }

    /// Property: draining [`SessionEncoder`] through a sink that
    /// accepts only a few bytes at a time (and interleaves spurious
    /// `WouldBlock`s) reproduces the blocking codec's byte stream
    /// exactly.
    #[test]
    fn prop_encoder_matches_blocking_codec_under_short_writes() {
        struct ShortWriter {
            out: Vec<u8>,
            rng: Rng,
        }
        impl std::io::Write for ShortWriter {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                if self.rng.gen_bool(0.25) {
                    return Err(io::Error::new(
                        io::ErrorKind::WouldBlock,
                        "not ready",
                    ));
                }
                let cap = buf.len().min(7);
                let n = 1 + self.rng.gen_range(cap);
                self.out.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        forall("session-encode-short-writes", 32, |rng| {
            let msgs = arbitrary_messages(rng);
            let expected = blocking_stream(&msgs);
            let mut enc = SessionEncoder::new();
            let mut queued = 0u64;
            for m in &msgs {
                queued += enc.queue_message(m);
            }
            assert_eq!(queued as usize, enc.pending_bytes());
            let mut w = ShortWriter {
                out: Vec::new(),
                rng: rng.fork(),
            };
            while !enc.is_empty() {
                enc.flush_into(&mut w).unwrap();
            }
            assert_eq!(enc.pending_bytes(), 0);
            assert_eq!(w.out, expected, "wire bytes differ");
        });
    }

    /// A length prefix split across feeds decodes once completed.
    #[test]
    fn split_length_prefix_is_reassembled() {
        let msg = Message::HeartbeatAck;
        let mut stream = Vec::new();
        write_frame(&mut stream, &msg).unwrap();
        let mut dec = SessionDecoder::new();
        dec.feed(&stream[..2]); // half the prefix
        assert!(dec.next_frame().unwrap().is_none());
        dec.feed(&stream[2..4]); // prefix complete, no body yet
        assert!(dec.next_frame().unwrap().is_none());
        dec.feed(&stream[4..]);
        let payload = dec.next_frame().unwrap().unwrap();
        assert_eq!(payload, msg.encode());
        assert!(dec.next_frame().unwrap().is_none());
    }

    /// Two frames arriving in one chunk are both extracted.
    #[test]
    fn coalesced_frames_split_correctly() {
        let a = Message::LeaveAck;
        let b = Message::NoTask { done: true };
        let mut stream = Vec::new();
        write_frame(&mut stream, &a).unwrap();
        write_frame(&mut stream, &b).unwrap();
        let mut dec = SessionDecoder::new();
        dec.feed(&stream);
        assert_eq!(dec.next_frame().unwrap().unwrap(), a.encode());
        assert_eq!(dec.next_frame().unwrap().unwrap(), b.encode());
        assert!(dec.next_frame().unwrap().is_none());
        assert_eq!(dec.buffered(), 0);
    }

    /// An oversized length header is rejected before any body bytes
    /// are buffered — the reactor hangs up on such a peer.
    #[test]
    fn oversized_header_rejected_without_buffering() {
        let mut dec = SessionDecoder::new();
        dec.feed(&[0xFF, 0xFF, 0xFF, 0xFF]);
        assert!(matches!(
            dec.next_frame(),
            Err(WireError::FrameTooLarge(_))
        ));
    }

    /// Property: a random mix of owned (`queue_message`) and shared
    /// (`queue_shared`) frames drains to exactly the blocking codec's
    /// byte stream, under short writes, and the shared path leaves
    /// the spare pool untouched.
    #[test]
    fn prop_mixed_owned_and_shared_frames_match_blocking_codec() {
        struct ShortWriter {
            out: Vec<u8>,
            rng: Rng,
        }
        impl std::io::Write for ShortWriter {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                if self.rng.gen_bool(0.25) {
                    return Err(io::Error::new(
                        io::ErrorKind::WouldBlock,
                        "not ready",
                    ));
                }
                let cap = buf.len().min(7);
                let n = 1 + self.rng.gen_range(cap);
                self.out.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        forall("session-encode-mixed-shared", 32, |rng| {
            let msgs = arbitrary_messages(rng);
            let expected = blocking_stream(&msgs);
            let mut enc = SessionEncoder::new();
            let mut queued = 0u64;
            for m in &msgs {
                if rng.gen_bool(0.5) {
                    queued += enc.queue_shared(Arc::new(m.encode()));
                } else {
                    queued += enc.queue_message(m);
                }
            }
            assert_eq!(queued as usize, enc.pending_bytes());
            let mut w = ShortWriter {
                out: Vec::new(),
                rng: rng.fork(),
            };
            while !enc.is_empty() {
                enc.flush_into(&mut w).unwrap();
            }
            assert_eq!(enc.pending_bytes(), 0);
            assert_eq!(w.out, expected, "wire bytes differ");
        });
    }

    /// The zero-copy guarantee at the syscall boundary: with the
    /// front frame's prefix unwritten, header and body reach the sink
    /// in a *single* vectored write — no staging buffer in between.
    #[test]
    fn header_and_body_go_down_in_one_vectored_write() {
        struct VectoredCapture {
            out: Vec<u8>,
            /// Non-empty slice count of each vectored call.
            calls: Vec<usize>,
        }
        impl std::io::Write for VectoredCapture {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                self.calls.push(1);
                self.out.extend_from_slice(buf);
                Ok(buf.len())
            }
            fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> io::Result<usize> {
                self.calls.push(bufs.iter().filter(|b| !b.is_empty()).count());
                let mut n = 0;
                for b in bufs {
                    self.out.extend_from_slice(b);
                    n += b.len();
                }
                Ok(n)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let payload = Arc::new(vec![0xAB; 4096]);
        let mut enc = SessionEncoder::new();
        let n = enc.queue_shared(payload.clone());
        assert_eq!(n, 4096 + 4);
        let mut w = VectoredCapture { out: Vec::new(), calls: Vec::new() };
        while !enc.is_empty() {
            enc.flush_into(&mut w).unwrap();
        }
        assert_eq!(w.calls, vec![2], "expected exactly one two-slice writev");
        let mut expected = (4096u32).to_le_bytes().to_vec();
        expected.extend_from_slice(&payload[..]);
        assert_eq!(w.out, expected);
    }

    /// The no-growth guarantee for the fetch path (PR 8 satellite
    /// test): streaming many large *shared* frames through a session
    /// never grows the spare-buffer pool, and recycled owned buffers
    /// stay within the bounded pool cap.
    #[test]
    fn shared_frames_do_not_grow_spare_buffers() {
        let mut enc = SessionEncoder::new();
        let mut sink = Vec::new();
        let big = Arc::new(vec![7u8; 1 << 20]); // 1 MiB, like a partition
        for _ in 0..32 {
            enc.queue_shared(big.clone());
            while !enc.is_empty() {
                enc.flush_into(&mut sink).unwrap();
            }
            assert_eq!(
                enc.spare_capacity_bytes(),
                0,
                "zero-copy frames must not leave buffers behind"
            );
            sink.clear();
        }
        // owned control frames recycle through a *bounded* pool …
        for _ in 0..64 {
            enc.queue_message(&Message::HeartbeatAck);
            while !enc.is_empty() {
                enc.flush_into(&mut sink).unwrap();
            }
        }
        assert!(enc.spare_capacity_bytes() <= SPARE_BUFS * SPARE_BUF_CAP);
        // … and an oversized owned frame is dropped, not pooled
        let before = enc.spare_capacity_bytes();
        let oversized = vec![1u8; SPARE_BUF_CAP * 2];
        enc.queue_payload(&oversized);
        while !enc.is_empty() {
            enc.flush_into(&mut sink).unwrap();
        }
        assert!(
            enc.spare_capacity_bytes() <= before.max(SPARE_BUFS * SPARE_BUF_CAP),
            "oversized encode buffer was retained"
        );
        assert!(enc.spare_capacity_bytes() < SPARE_BUF_CAP * 2);
    }

    /// Partial writes resume exactly where they stopped.
    #[test]
    fn partial_write_resumes_mid_frame() {
        struct OneByte(Vec<u8>);
        impl std::io::Write for OneByte {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                self.0.push(buf[0]);
                Ok(1)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let msg = Message::NoTask { done: false };
        let mut enc = SessionEncoder::new();
        let n = enc.queue_message(&msg);
        assert_eq!(n as usize, enc.pending_bytes());
        let mut w = OneByte(Vec::new());
        while !enc.is_empty() {
            enc.flush_into(&mut w).unwrap();
        }
        let mut expected = Vec::new();
        write_frame(&mut expected, &msg).unwrap();
        assert_eq!(w.0, expected);
    }
}
