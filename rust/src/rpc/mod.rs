//! Wire protocol of the service-based distributed runtime (paper §4).
//!
//! The paper's workflow / data / match services talk over Java RMI on a
//! LAN.  This module is the reproduction's RMI substitute: a
//! **length-prefixed binary protocol** over `std::net::TcpStream` — no
//! external crates, no async runtime.  Every frame is
//!
//! ```text
//! ┌────────────┬───────────────────────────────┐
//! │ u32 LE len │ payload (len bytes)           │
//! └────────────┴───────────────────────────────┘
//!   payload[0] = message tag, rest = fields in LE byte order
//! ```
//!
//! [`Message`] enumerates the paper's control- and data-plane calls:
//! task request/assignment, completion report **with piggybacked cache
//! status**, partition fetch, heartbeat, and join/leave membership.
//! Integers are little-endian; floats travel as IEEE-754 bit patterns so
//! match similarities round-trip exactly; strings are UTF-8 with a u32
//! length; collections carry a u32 element count that is validated
//! against the remaining buffer before any allocation.
//!
//! Decoding is strict: a frame must parse completely and exactly —
//! truncated buffers yield [`WireError::Truncated`], extra bytes yield
//! [`WireError::TrailingBytes`] — so corrupt or hostile frames are
//! rejected instead of being half-read (see the property tests at the
//! bottom and [`frame`] for the stream framing).
//!
//! **Versioning (protocol v2).**  The handshake frames — [`Message::Join`],
//! [`Message::JoinAck`], [`Message::ReplicaAnnounce`] — carry a protocol
//! version byte ([`PROTOCOL_VERSION`]) immediately after the tag; a
//! service receiving a mismatched version rejects the peer with a clear
//! [`Message::Error`] instead of mis-parsing later frames.  v2 also adds
//! the **replicated data plane**: [`Message::JoinAck`] delivers the
//! replica directory, data servers announce themselves with
//! [`Message::ReplicaAnnounce`], replicate partition frames with
//! [`Message::SyncRequest`]/[`Message::SyncDone`], and answer fetches
//! for partitions they do not hold with [`Message::Redirect`].
//!
//! **Batched assignment (protocol v3).**  One
//! [`Message::TaskRequestBatch`] reports every task a worker finished
//! since its last pull ([`CompletedTask`] records, cache status
//! attached once) *and* requests up to `max` new tasks; the reply is
//! [`Message::TaskAssignBatch`].  This folds the per-task
//! request/assign round trip — the dominant coordination cost when
//! tasks are small — into one round trip per batch.  v3 also adds the
//! incremental session layer ([`session`]) that lets servers decode
//! these frames from arbitrary read-chunk boundaries.
//!
//! **Memory-aware assignment (protocol v4).**  Every assignment —
//! [`Message::TaskAssign`] and each [`AssignedTask`] inside
//! [`Message::TaskAssignBatch`] — carries the task's §3.1 memory
//! footprint (`c_ms · m₁ · m₂` from the match plan), and a match node
//! whose budget the footprint exceeds answers with
//! [`Message::TaskRejected`] instead of executing; the workflow
//! service re-queues the task marked oversize for that node.
//!
//! **Runtime task splitting (protocol v5).**  [`Message::Join`] now
//! reports the joining node's §3.1 budget, and every assignment may
//! carry an optional [`TaskSpan`]: when a task has been rejected by
//! *every* live node, the scheduler splits its pair space into
//! sub-tasks that fit the smallest live budget (Kolb et al.'s
//! BlockSplit, applied at run time), and the span tells the node which
//! entity-index rectangle of the fetched partitions to compare.
//!
//! **Live observability (protocol v6).**  Any server answers
//! [`Message::StatsRequest`] with a [`Message::StatsReport`] carrying
//! its serialized [`crate::obs::MetricsSnapshot`] — scheduler queue
//! depth, per-node busy ns, cache hit ratios, fetch-latency histograms
//! — so `pem stats` can scrape a *running* cluster.
//! [`Message::Heartbeat`] is enriched with the node's busy-ns and
//! cache counters, giving the coordinator live per-node load without
//! extra round trips.
//!
//! **Multi-tenant plan submission (protocol v7).**  A *client* (not a
//! match node) submits a whole workflow over the wire:
//! [`Message::PlanSubmit`] carries the canonical
//! [`crate::coordinator::MatchPlan`] bytes (`pem plan --save`); the
//! resident workflow service admits it against the aggregate of the
//! v5 join-time node budgets and answers [`Message::PlanAccepted`]
//! (with the tenant's plan id) or [`Message::PlanRejected`] (typed
//! admission denial: required vs. available bytes).  The client polls
//! with [`Message::PlanStatus`]; the reply is
//! [`Message::PlanStatusReport`] while the plan runs and
//! [`Message::PlanResult`] — the tenant's isolated match output —
//! once it reaches a terminal state.  The authoritative byte-level
//! layout of every frame is specified in `docs/WIRE_PROTOCOL.md`,
//! kept in lockstep with this module.

#![warn(missing_docs)]

pub mod frame;
pub mod session;

pub use frame::{read_frame, read_frame_raw, write_frame, Transport, MAX_FRAME_BYTES};

/// Version of the wire protocol this build speaks.
///
/// Carried in the handshake frames ([`Message::Join`],
/// [`Message::JoinAck`], [`Message::ReplicaAnnounce`]); peers with a
/// different version are rejected at join time with a clear error
/// (`docs/WIRE_PROTOCOL.md` § Version negotiation).  History:
/// v1 — PR 1's unversioned frames; v2 — version byte + replicated data
/// plane (directory, redirect, sync); v3 — batched task assignment
/// ([`Message::TaskRequestBatch`] / [`Message::TaskAssignBatch`]);
/// v4 — §3.1 memory-aware assignment (footprints on every assignment,
/// [`Message::TaskRejected`]); v5 — runtime task splitting (node
/// budget on [`Message::Join`], optional [`TaskSpan`] on every
/// assignment); v6 — live observability ([`Message::StatsRequest`] /
/// [`Message::StatsReport`] management frames, enriched
/// [`Message::Heartbeat`] carrying busy-ns and cache counters);
/// v7 — multi-tenant plan submission ([`Message::PlanSubmit`] /
/// [`Message::PlanAccepted`] / [`Message::PlanRejected`] /
/// [`Message::PlanStatus`] / [`Message::PlanStatusReport`] /
/// [`Message::PlanResult`]) to a resident workflow service.
pub const PROTOCOL_VERSION: u8 = 7;

use crate::coordinator::scheduler::ServiceId;
use crate::features::{EntityFeatures, QGramSet, TokenSet};
use crate::model::{Correspondence, EntityId};
use crate::partition::{MatchTask, PartitionId, TaskSpan};
use crate::store::PartitionData;
use std::fmt;

/// Decode failure: the frame is not a valid message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the message was complete.
    Truncated,
    /// The first payload byte is not a known message tag.
    UnknownTag(u8),
    /// The message decoded but left unconsumed bytes.
    TrailingBytes(usize),
    /// A string field is not valid UTF-8.
    BadUtf8,
    /// A frame header announced more than [`MAX_FRAME_BYTES`].
    FrameTooLarge(u64),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::UnknownTag(t) => write!(f, "unknown message tag {t}"),
            WireError::TrailingBytes(n) => {
                write!(f, "{n} trailing bytes after message")
            }
            WireError::BadUtf8 => write!(f, "string field is not UTF-8"),
            WireError::FrameTooLarge(n) => {
                write!(f, "frame of {n} bytes exceeds limit")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// One finished task inside a [`Message::TaskRequestBatch`] report:
/// the v3 batched equivalent of a [`Message::Complete`] body (the
/// cache status travels once per batch, not per task).
#[derive(Debug, Clone, PartialEq)]
pub struct CompletedTask {
    /// The completed task.
    pub task_id: u32,
    /// Pair comparisons the task evaluated.
    pub comparisons: u64,
    /// Correspondences the task found.
    pub matches: Vec<Correspondence>,
}

/// One assignment inside a [`Message::TaskAssignBatch`] (protocol v4):
/// the task plus its §3.1 memory footprint, so a node can reject work
/// that would not fit its budget *before* fetching anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AssignedTask {
    /// The assigned match task.
    pub task: MatchTask,
    /// Estimated §3.1 memory footprint of the task (`c_ms · m₁ · m₂`
    /// from the match plan; 0 when the coordinator has no plan
    /// footprints).
    pub mem_bytes: u64,
    /// Runtime-split sub-task span (v5): the pair-space rectangle to
    /// compare instead of the full partitions.  `None` for plan tasks.
    pub span: Option<TaskSpan>,
}

/// One protocol message (control plane to the workflow service, data
/// plane to the data service).
#[derive(Debug)]
pub enum Message {
    /// match service → workflow service: join the cluster.  `version`
    /// is the sender's [`PROTOCOL_VERSION`]; a mismatch is answered
    /// with [`Message::Error`], never with a `JoinAck`.
    Join {
        /// Human-readable node name (coordinator logs).
        name: String,
        /// Sender's [`PROTOCOL_VERSION`].
        version: u8,
        /// The node's §3.1 per-task memory budget, bytes (v5); `0` =
        /// unlimited.  Feeds scheduler-level task splitting: a task
        /// rejected by every live node is split into sub-tasks sized
        /// to the smallest live budget.
        mem_budget: u64,
    },
    /// workflow service → match service: membership granted.  Carries
    /// the coordinator's protocol version (echo for symmetric checking)
    /// and the current **replica directory** — the `host:port`
    /// addresses of every announced data-service replica, so a joining
    /// node can spread partition fetches without extra configuration.
    JoinAck {
        /// The [`ServiceId`] granted to the joining match service.
        service: ServiceId,
        /// Coordinator's [`PROTOCOL_VERSION`].
        version: u8,
        /// Data-plane replica directory (`host:port` per replica, in
        /// announcement order; may be empty).
        replicas: Vec<String>,
    },
    /// match service → workflow service: graceful departure.
    Leave {
        /// The departing service.
        service: ServiceId,
    },
    /// workflow service → match service: departure acknowledged.
    LeaveAck,
    /// match service → workflow service: pull a task (initial request;
    /// subsequent pulls piggyback on [`Message::Complete`]).
    TaskRequest {
        /// The pulling service.
        service: ServiceId,
    },
    /// workflow service → match service: task assignment.
    TaskAssign {
        /// The assigned match task (id + partition pair).
        task: MatchTask,
        /// Estimated §3.1 memory footprint of the task (v4; 0 when
        /// the coordinator has no plan footprints).
        mem_bytes: u64,
        /// Runtime-split sub-task span (v5): the pair-space rectangle
        /// to compare instead of the full partitions.  `None` for
        /// plan tasks.
        span: Option<TaskSpan>,
    },
    /// workflow service → match service: nothing to assign right now.
    NoTask {
        /// `true`: the whole workflow has completed and the match
        /// service may shut down; `false`: tasks are in flight
        /// elsewhere and may yet be re-queued (poll again).
        done: bool,
    },
    /// match service → workflow service: completion report with the
    /// piggybacked cache status (paper §4) and the task's match output.
    /// The reply is the next assignment ([`Message::TaskAssign`] or
    /// [`Message::NoTask`]) — the paper's pull scheduling in one round
    /// trip.
    Complete {
        /// The reporting service.
        service: ServiceId,
        /// The completed task.
        task_id: u32,
        /// Pair comparisons the task evaluated.
        comparisons: u64,
        /// Partition ids currently in the service's cache.
        cached: Vec<PartitionId>,
        /// Correspondences the task found.
        matches: Vec<Correspondence>,
    },
    /// match service → workflow service: liveness signal.  Since v6
    /// the heartbeat doubles as a cheap stats push: the cumulative
    /// busy-ns and cache counters ride along, so the coordinator has
    /// live per-node load for `pem stats` without extra round trips.
    Heartbeat {
        /// The live service.
        service: ServiceId,
        /// Cumulative ns this node's workers spent executing tasks.
        busy_ns: u64,
        /// Cumulative partition-cache hits on this node.
        cache_hits: u64,
        /// Cumulative partition-cache misses on this node.
        cache_misses: u64,
        /// Tasks this node has completed so far.
        tasks_done: u64,
    },
    /// workflow service → match service: liveness acknowledged.
    HeartbeatAck,
    /// match service → workflow service (v3): report every task
    /// finished since the last pull and request up to `max` new tasks
    /// — the batched form of the [`Message::Complete`] +
    /// [`Message::TaskRequest`] round trip.  The reply is
    /// [`Message::TaskAssignBatch`].
    TaskRequestBatch {
        /// The pulling service.
        service: ServiceId,
        /// Maximum number of tasks the worker wants assigned.
        max: u32,
        /// Partition ids currently in the service's cache (piggybacked
        /// once per batch, paper §4).
        cached: Vec<PartitionId>,
        /// Tasks completed since the previous batch request.
        completed: Vec<CompletedTask>,
    },
    /// workflow service → match service (v3): up to `max` assignments
    /// for a [`Message::TaskRequestBatch`].  An empty `tasks` with
    /// `done = false` means poll again (tasks are in flight elsewhere
    /// and may be re-queued); `done = true` means the whole workflow
    /// has completed.
    TaskAssignBatch {
        /// `true` once every task of the workflow has completed.
        done: bool,
        /// The assigned tasks with their memory footprints, in
        /// scheduler preference order.
        tasks: Vec<AssignedTask>,
    },
    /// match service → workflow service (v4): the assigned task's
    /// §3.1 memory footprint exceeds this node's budget — it was not
    /// executed.  The workflow service re-queues the task marked
    /// oversize for this node and replies with the next assignment
    /// ([`Message::TaskAssign`] or [`Message::NoTask`]), exactly like
    /// a [`Message::TaskRequest`].
    TaskRejected {
        /// The rejecting service.
        service: ServiceId,
        /// The task that did not fit.
        task_id: u32,
    },
    /// match service → data service: fetch one partition.
    FetchPartition {
        /// The wanted partition.
        id: PartitionId,
    },
    /// data service → match service: the partition payload (entity ids +
    /// precomputed match features).
    Partition {
        /// The partition payload.
        data: PartitionData,
    },
    /// data service → workflow service: announce a data-plane replica
    /// into the directory, listing the partitions it holds (feeds
    /// replica-aware affinity scheduling).  Answered with
    /// [`Message::ReplicaDirectory`], or [`Message::Error`] on a
    /// version mismatch.
    ReplicaAnnounce {
        /// `host:port` match nodes should use to reach this replica.
        addr: String,
        /// Sender's [`PROTOCOL_VERSION`].
        version: u8,
        /// Partitions this replica currently holds.
        partitions: Vec<PartitionId>,
    },
    /// workflow service → data service: the directory after an
    /// announcement (every replica announced so far, in order).
    ReplicaDirectory {
        /// `host:port` per announced replica.
        replicas: Vec<String>,
    },
    /// data service → match service: this replica does not hold the
    /// requested partition — retry at `addr` (normally the primary).
    /// Clients follow at most one redirect hop per fetch attempt.
    Redirect {
        /// `host:port` of the data server that does hold the partition.
        addr: String,
    },
    /// replica data service → upstream data service: push me every
    /// partition frame I do not already hold (`have`).  The upstream
    /// answers with a stream of [`Message::Partition`] frames
    /// terminated by [`Message::SyncDone`].
    SyncRequest {
        /// Partitions the requesting replica already holds.
        have: Vec<PartitionId>,
    },
    /// upstream data service → replica: replication stream complete.
    SyncDone {
        /// Number of partition frames pushed in this stream.
        count: u32,
    },
    /// any client → any server (v6): scrape the server's live
    /// metrics.  Every server — workflow, data, replica — answers
    /// with a [`Message::StatsReport`]; the frame carries no fields
    /// so it can be sent by an operator tool (`pem stats`) that knows
    /// nothing about the server's role.
    StatsRequest,
    /// server → client (v6): the server's current
    /// [`crate::obs::MetricsSnapshot`], in its canonical byte format
    /// (`PEMSTAT` magic; decoded with
    /// [`crate::obs::MetricsSnapshot::from_bytes`]).  The snapshot
    /// travels as opaque bytes so the wire layer needs no knowledge
    /// of metric names.
    StatsReport {
        /// Serialized `MetricsSnapshot`.
        stats: Vec<u8>,
    },
    /// client → workflow service (v7): submit a whole match workflow
    /// to a *resident* cluster.  `plan` is the canonical
    /// [`crate::coordinator::MatchPlan`] byte format (`PEMPLAN` magic,
    /// `pem plan --save`) — the same bytes the CLI writes to disk.
    /// Answered with [`Message::PlanAccepted`] or
    /// [`Message::PlanRejected`].
    PlanSubmit {
        /// Human-readable tenant name (status reports, `pem stats`).
        name: String,
        /// Serialized `MatchPlan` (`MatchPlan::to_bytes`).
        plan: Vec<u8>,
    },
    /// workflow service → client (v7): the submitted plan was admitted.
    PlanAccepted {
        /// Tenant plan id — the handle for [`Message::PlanStatus`]
        /// polls.  Unique for the lifetime of the resident service.
        plan: u32,
    },
    /// workflow service → client (v7): the submitted plan was refused.
    /// When `required > 0` this is a typed **admission denial**: the
    /// plan's aggregate §3.1 footprint (`required` bytes) exceeds the
    /// cluster's aggregate join-time budget (`available` bytes) — the
    /// client gets the denial in one round trip instead of a
    /// queue-and-hang run timeout.  `required == 0` means the plan was
    /// malformed or the service is not accepting submissions; see
    /// `reason`.
    PlanRejected {
        /// Aggregate §3.1 footprint of the plan, bytes (0 = not an
        /// admission denial).
        required: u64,
        /// Aggregate budget of the live cluster, bytes, at denial time.
        available: u64,
        /// Human-readable refusal description.
        reason: String,
    },
    /// client → workflow service (v7): poll a submitted plan.
    PlanStatus {
        /// The plan id from [`Message::PlanAccepted`].
        plan: u32,
    },
    /// workflow service → client (v7): progress of a *running* plan.
    /// Terminal plans answer with [`Message::PlanResult`] instead.
    PlanStatusReport {
        /// The polled plan.
        plan: u32,
        /// Tenant lifecycle state (`1` running — terminal states
        /// arrive as [`Message::PlanResult`]).
        state: u8,
        /// Tasks of this plan completed so far.
        completed: u32,
        /// Total tasks of this plan.
        total: u32,
        /// Human-readable detail (empty while healthy).
        detail: String,
    },
    /// workflow service → client (v7): terminal outcome of a submitted
    /// plan — the tenant's isolated result channel.  `state` is `2`
    /// done, `3` aborted (submitting client vanished), `4` failed
    /// (e.g. an unsplittable task raised a plan misfit).  Re-polling a
    /// terminal plan is idempotent: the same result is served again.
    PlanResult {
        /// The polled plan.
        plan: u32,
        /// Terminal tenant state (2 done / 3 aborted / 4 failed).
        state: u8,
        /// Pair comparisons the plan's tasks evaluated.
        comparisons: u64,
        /// Correspondences the plan found (empty unless done).
        matches: Vec<Correspondence>,
        /// Failure/abort detail (empty when done).
        detail: String,
    },
    /// Either direction: request failed.
    Error {
        /// Human-readable failure description.
        message: String,
    },
}

// ---------------------------------------------------------------- tags

const TAG_JOIN: u8 = 1;
const TAG_JOIN_ACK: u8 = 2;
const TAG_LEAVE: u8 = 3;
const TAG_LEAVE_ACK: u8 = 4;
const TAG_TASK_REQUEST: u8 = 5;
const TAG_TASK_ASSIGN: u8 = 6;
const TAG_NO_TASK: u8 = 7;
const TAG_COMPLETE: u8 = 8;
const TAG_HEARTBEAT: u8 = 9;
const TAG_HEARTBEAT_ACK: u8 = 10;
const TAG_FETCH_PARTITION: u8 = 11;
const TAG_PARTITION: u8 = 12;
const TAG_ERROR: u8 = 13;
const TAG_REPLICA_ANNOUNCE: u8 = 14;
const TAG_REPLICA_DIRECTORY: u8 = 15;
const TAG_REDIRECT: u8 = 16;
const TAG_SYNC_REQUEST: u8 = 17;
const TAG_SYNC_DONE: u8 = 18;
const TAG_TASK_REQUEST_BATCH: u8 = 19;
const TAG_TASK_ASSIGN_BATCH: u8 = 20;
const TAG_TASK_REJECTED: u8 = 21;
const TAG_STATS_REQUEST: u8 = 22;
const TAG_STATS_REPORT: u8 = 23;
const TAG_PLAN_SUBMIT: u8 = 24;
const TAG_PLAN_ACCEPTED: u8 = 25;
const TAG_PLAN_REJECTED: u8 = 26;
const TAG_PLAN_STATUS: u8 = 27;
const TAG_PLAN_STATUS_REPORT: u8 = 28;
const TAG_PLAN_RESULT: u8 = 29;

/// Minimum wire footprint of one [`EntityFeatures`]: a 4-byte title
/// length plus three 4-byte list counts (all possibly zero).
const MIN_FEATURE_BYTES: usize = 16;

/// Salvage the version check from a handshake frame that failed to
/// decode.  The handshake frames put the version byte *immediately
/// after the tag* precisely so compatibility can be checked before
/// parsing anything else — and since v5 changed the `Join` body
/// layout (the budget field), an older node's `Join` no longer
/// decodes at all; strict decoding would otherwise mask the version
/// mismatch behind a generic "undecodable frame" error.  Returns
/// `Some(peer_version)` when `payload` starts like a handshake frame
/// whose version differs from [`PROTOCOL_VERSION`].
pub fn foreign_handshake_version(payload: &[u8]) -> Option<u8> {
    match payload {
        [TAG_JOIN | TAG_JOIN_ACK | TAG_REPLICA_ANNOUNCE, version, ..]
            if *version != PROTOCOL_VERSION =>
        {
            Some(*version)
        }
        _ => None,
    }
}

// ------------------------------------------------------------- encoder

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

// `put_u32`/`put_u64`/`put_str` are shared with the plan serializer
// (`crate::coordinator::plan`), so the two canonical binary formats
// keep one set of primitive encoders.
pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    put_u32(buf, v.to_bits());
}

fn put_bool(buf: &mut Vec<u8>, v: bool) {
    put_u8(buf, v as u8);
}

pub(crate) fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_u64_list(buf: &mut Vec<u8>, vs: &[u64]) {
    put_u32(buf, vs.len() as u32);
    for &v in vs {
        put_u64(buf, v);
    }
}

fn put_service(buf: &mut Vec<u8>, s: ServiceId) {
    put_u32(buf, s.0 as u32);
}

fn put_str_list(buf: &mut Vec<u8>, ss: &[String]) {
    put_u32(buf, ss.len() as u32);
    for s in ss {
        put_str(buf, s);
    }
}

fn put_partition_list(buf: &mut Vec<u8>, ps: &[PartitionId]) {
    put_u32(buf, ps.len() as u32);
    for p in ps {
        put_u32(buf, p.0);
    }
}

fn put_span(buf: &mut Vec<u8>, span: &Option<TaskSpan>) {
    match span {
        None => put_bool(buf, false),
        Some(s) => {
            put_bool(buf, true);
            put_u32(buf, s.left.0);
            put_u32(buf, s.left.1);
            put_u32(buf, s.right.0);
            put_u32(buf, s.right.1);
        }
    }
}

fn put_features(buf: &mut Vec<u8>, f: &EntityFeatures) {
    // Only the canonical representations travel; `title_chars` and the
    // sparse count vectors are derived again on the receiving side.
    put_str(buf, &f.title_norm);
    put_u64_list(buf, f.title_grams.hashes());
    put_u64_list(buf, f.title_tokens.hashes());
    put_u64_list(buf, f.desc_grams.hashes());
}

/// Encode the payload of a [`Message::Partition`] reply directly from a
/// borrowed [`PartitionData`] — the data service serves `Arc`ed
/// partitions and must not deep-clone them per fetch.
pub fn encode_partition_message(data: &PartitionData) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + data.approx_bytes as usize / 2);
    encode_partition_message_into(data, &mut buf);
    buf
}

/// [`encode_partition_message`] into a caller-provided buffer, which
/// is cleared first.  The session encoder calls this with a recycled
/// buffer so steady-state replies allocate nothing per frame.
pub fn encode_partition_message_into(data: &PartitionData, buf: &mut Vec<u8>) {
    buf.clear();
    put_u8(buf, TAG_PARTITION);
    put_u32(buf, data.id.0);
    put_u64(buf, data.approx_bytes);
    put_u32(buf, data.entities.len() as u32);
    for e in &data.entities {
        put_u32(buf, e.0);
    }
    debug_assert_eq!(data.features.len(), data.entities.len());
    for f in &data.features {
        put_features(buf, f);
    }
}

impl Message {
    /// Encode to a payload (without the frame length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(16);
        self.encode_into(&mut b);
        b
    }

    /// Encode into a caller-provided buffer, replacing its contents.
    /// This is the allocation-free path the session encoder drives
    /// with its recycled buffers (PR 8).
    pub fn encode_into(&self, b: &mut Vec<u8>) {
        b.clear();
        match self {
            Message::Join {
                name,
                version,
                mem_budget,
            } => {
                put_u8(b, TAG_JOIN);
                put_u8(b, *version);
                put_str(b, name);
                put_u64(b, *mem_budget);
            }
            Message::JoinAck {
                service,
                version,
                replicas,
            } => {
                put_u8(b, TAG_JOIN_ACK);
                put_u8(b, *version);
                put_service(b, *service);
                put_str_list(b, replicas);
            }
            Message::Leave { service } => {
                put_u8(b, TAG_LEAVE);
                put_service(b, *service);
            }
            Message::LeaveAck => put_u8(b, TAG_LEAVE_ACK),
            Message::TaskRequest { service } => {
                put_u8(b, TAG_TASK_REQUEST);
                put_service(b, *service);
            }
            Message::TaskAssign {
                task,
                mem_bytes,
                span,
            } => {
                put_u8(b, TAG_TASK_ASSIGN);
                put_u32(b, task.id);
                put_u32(b, task.left.0);
                put_u32(b, task.right.0);
                put_u64(b, *mem_bytes);
                put_span(b, span);
            }
            Message::NoTask { done } => {
                put_u8(b, TAG_NO_TASK);
                put_bool(b, *done);
            }
            Message::Complete {
                service,
                task_id,
                comparisons,
                cached,
                matches,
            } => {
                put_u8(b, TAG_COMPLETE);
                put_service(b, *service);
                put_u32(b, *task_id);
                put_u64(b, *comparisons);
                put_u32(b, cached.len() as u32);
                for p in cached {
                    put_u32(b, p.0);
                }
                put_u32(b, matches.len() as u32);
                for c in matches {
                    put_u32(b, c.e1.0);
                    put_u32(b, c.e2.0);
                    put_f32(b, c.sim);
                }
            }
            Message::Heartbeat {
                service,
                busy_ns,
                cache_hits,
                cache_misses,
                tasks_done,
            } => {
                put_u8(b, TAG_HEARTBEAT);
                put_service(b, *service);
                put_u64(b, *busy_ns);
                put_u64(b, *cache_hits);
                put_u64(b, *cache_misses);
                put_u64(b, *tasks_done);
            }
            Message::HeartbeatAck => put_u8(b, TAG_HEARTBEAT_ACK),
            Message::TaskRequestBatch {
                service,
                max,
                cached,
                completed,
            } => {
                put_u8(b, TAG_TASK_REQUEST_BATCH);
                put_service(b, *service);
                put_u32(b, *max);
                put_partition_list(b, cached);
                put_u32(b, completed.len() as u32);
                for c in completed {
                    put_u32(b, c.task_id);
                    put_u64(b, c.comparisons);
                    put_u32(b, c.matches.len() as u32);
                    for m in &c.matches {
                        put_u32(b, m.e1.0);
                        put_u32(b, m.e2.0);
                        put_f32(b, m.sim);
                    }
                }
            }
            Message::TaskAssignBatch { done, tasks } => {
                put_u8(b, TAG_TASK_ASSIGN_BATCH);
                put_bool(b, *done);
                put_u32(b, tasks.len() as u32);
                for a in tasks {
                    put_u32(b, a.task.id);
                    put_u32(b, a.task.left.0);
                    put_u32(b, a.task.right.0);
                    put_u64(b, a.mem_bytes);
                    put_span(b, &a.span);
                }
            }
            Message::TaskRejected { service, task_id } => {
                put_u8(b, TAG_TASK_REJECTED);
                put_service(b, *service);
                put_u32(b, *task_id);
            }
            Message::FetchPartition { id } => {
                put_u8(b, TAG_FETCH_PARTITION);
                put_u32(b, id.0);
            }
            Message::Partition { data } => {
                encode_partition_message_into(data, b);
            }
            Message::ReplicaAnnounce {
                addr,
                version,
                partitions,
            } => {
                put_u8(b, TAG_REPLICA_ANNOUNCE);
                put_u8(b, *version);
                put_str(b, addr);
                put_partition_list(b, partitions);
            }
            Message::ReplicaDirectory { replicas } => {
                put_u8(b, TAG_REPLICA_DIRECTORY);
                put_str_list(b, replicas);
            }
            Message::Redirect { addr } => {
                put_u8(b, TAG_REDIRECT);
                put_str(b, addr);
            }
            Message::SyncRequest { have } => {
                put_u8(b, TAG_SYNC_REQUEST);
                put_partition_list(b, have);
            }
            Message::SyncDone { count } => {
                put_u8(b, TAG_SYNC_DONE);
                put_u32(b, *count);
            }
            Message::StatsRequest => put_u8(b, TAG_STATS_REQUEST),
            Message::StatsReport { stats } => {
                put_u8(b, TAG_STATS_REPORT);
                put_u32(b, stats.len() as u32);
                b.extend_from_slice(stats);
            }
            Message::PlanSubmit { name, plan } => {
                put_u8(b, TAG_PLAN_SUBMIT);
                put_str(b, name);
                put_u32(b, plan.len() as u32);
                b.extend_from_slice(plan);
            }
            Message::PlanAccepted { plan } => {
                put_u8(b, TAG_PLAN_ACCEPTED);
                put_u32(b, *plan);
            }
            Message::PlanRejected {
                required,
                available,
                reason,
            } => {
                put_u8(b, TAG_PLAN_REJECTED);
                put_u64(b, *required);
                put_u64(b, *available);
                put_str(b, reason);
            }
            Message::PlanStatus { plan } => {
                put_u8(b, TAG_PLAN_STATUS);
                put_u32(b, *plan);
            }
            Message::PlanStatusReport {
                plan,
                state,
                completed,
                total,
                detail,
            } => {
                put_u8(b, TAG_PLAN_STATUS_REPORT);
                put_u32(b, *plan);
                put_u8(b, *state);
                put_u32(b, *completed);
                put_u32(b, *total);
                put_str(b, detail);
            }
            Message::PlanResult {
                plan,
                state,
                comparisons,
                matches,
                detail,
            } => {
                put_u8(b, TAG_PLAN_RESULT);
                put_u32(b, *plan);
                put_u8(b, *state);
                put_u64(b, *comparisons);
                put_u32(b, matches.len() as u32);
                for c in matches {
                    put_u32(b, c.e1.0);
                    put_u32(b, c.e2.0);
                    put_f32(b, c.sim);
                }
                put_str(b, detail);
            }
            Message::Error { message } => {
                put_u8(b, TAG_ERROR);
                put_str(b, message);
            }
        }
    }

    /// Decode a full payload; strict — see module docs.
    pub fn decode(payload: &[u8]) -> Result<Message, WireError> {
        let mut d = Dec {
            buf: payload,
            pos: 0,
        };
        let tag = d.u8()?;
        let msg = match tag {
            TAG_JOIN => Message::Join {
                version: d.u8()?,
                name: d.string()?,
                mem_budget: d.u64()?,
            },
            TAG_JOIN_ACK => Message::JoinAck {
                version: d.u8()?,
                service: d.service()?,
                replicas: d.str_list()?,
            },
            TAG_LEAVE => Message::Leave {
                service: d.service()?,
            },
            TAG_LEAVE_ACK => Message::LeaveAck,
            TAG_TASK_REQUEST => Message::TaskRequest {
                service: d.service()?,
            },
            TAG_TASK_ASSIGN => Message::TaskAssign {
                task: MatchTask {
                    id: d.u32()?,
                    left: PartitionId(d.u32()?),
                    right: PartitionId(d.u32()?),
                },
                mem_bytes: d.u64()?,
                span: d.span()?,
            },
            TAG_NO_TASK => Message::NoTask { done: d.bool()? },
            TAG_COMPLETE => {
                let service = d.service()?;
                let task_id = d.u32()?;
                let comparisons = d.u64()?;
                let n_cached = d.list_len(4)?;
                let mut cached = Vec::with_capacity(n_cached);
                for _ in 0..n_cached {
                    cached.push(PartitionId(d.u32()?));
                }
                let n_matches = d.list_len(12)?;
                let mut matches = Vec::with_capacity(n_matches);
                for _ in 0..n_matches {
                    let e1 = EntityId(d.u32()?);
                    let e2 = EntityId(d.u32()?);
                    let sim = d.f32()?;
                    matches.push(Correspondence { e1, e2, sim });
                }
                Message::Complete {
                    service,
                    task_id,
                    comparisons,
                    cached,
                    matches,
                }
            }
            TAG_HEARTBEAT => Message::Heartbeat {
                service: d.service()?,
                busy_ns: d.u64()?,
                cache_hits: d.u64()?,
                cache_misses: d.u64()?,
                tasks_done: d.u64()?,
            },
            TAG_HEARTBEAT_ACK => Message::HeartbeatAck,
            TAG_TASK_REQUEST_BATCH => {
                let service = d.service()?;
                let max = d.u32()?;
                let cached = d.partition_list()?;
                // minimum wire footprint of one CompletedTask: task id,
                // comparisons, and an (empty) match count
                let n = d.list_len(16)?;
                let mut completed = Vec::with_capacity(n);
                for _ in 0..n {
                    let task_id = d.u32()?;
                    let comparisons = d.u64()?;
                    let n_matches = d.list_len(12)?;
                    let mut matches = Vec::with_capacity(n_matches);
                    for _ in 0..n_matches {
                        let e1 = EntityId(d.u32()?);
                        let e2 = EntityId(d.u32()?);
                        let sim = d.f32()?;
                        matches.push(Correspondence { e1, e2, sim });
                    }
                    completed.push(CompletedTask {
                        task_id,
                        comparisons,
                        matches,
                    });
                }
                Message::TaskRequestBatch {
                    service,
                    max,
                    cached,
                    completed,
                }
            }
            TAG_TASK_ASSIGN_BATCH => {
                let done = d.bool()?;
                // 12 task bytes + 8 footprint bytes + 1 span-presence
                // byte per element
                let n = d.list_len(21)?;
                let mut tasks = Vec::with_capacity(n);
                for _ in 0..n {
                    tasks.push(AssignedTask {
                        task: MatchTask {
                            id: d.u32()?,
                            left: PartitionId(d.u32()?),
                            right: PartitionId(d.u32()?),
                        },
                        mem_bytes: d.u64()?,
                        span: d.span()?,
                    });
                }
                Message::TaskAssignBatch { done, tasks }
            }
            TAG_TASK_REJECTED => Message::TaskRejected {
                service: d.service()?,
                task_id: d.u32()?,
            },
            TAG_FETCH_PARTITION => Message::FetchPartition {
                id: PartitionId(d.u32()?),
            },
            TAG_PARTITION => {
                let id = PartitionId(d.u32()?);
                let approx_bytes = d.u64()?;
                let n = d.list_len(4)?;
                let mut entities = Vec::with_capacity(n);
                for _ in 0..n {
                    entities.push(EntityId(d.u32()?));
                }
                // even an empty-string feature occupies MIN_FEATURE_BYTES
                // on the wire; re-validate against what is actually left
                // so a lying entity count cannot reserve gigabytes here
                d.ensure_remaining(n, MIN_FEATURE_BYTES)?;
                let mut features = Vec::with_capacity(n);
                for _ in 0..n {
                    features.push(d.features()?);
                }
                Message::Partition {
                    data: PartitionData {
                        id,
                        entities,
                        features,
                        approx_bytes,
                    },
                }
            }
            TAG_REPLICA_ANNOUNCE => Message::ReplicaAnnounce {
                version: d.u8()?,
                addr: d.string()?,
                partitions: d.partition_list()?,
            },
            TAG_REPLICA_DIRECTORY => Message::ReplicaDirectory {
                replicas: d.str_list()?,
            },
            TAG_REDIRECT => Message::Redirect { addr: d.string()? },
            TAG_SYNC_REQUEST => Message::SyncRequest {
                have: d.partition_list()?,
            },
            TAG_SYNC_DONE => Message::SyncDone { count: d.u32()? },
            TAG_STATS_REQUEST => Message::StatsRequest,
            TAG_STATS_REPORT => Message::StatsReport {
                stats: {
                    let n = d.list_len(1)?;
                    d.take(n)?.to_vec()
                },
            },
            TAG_PLAN_SUBMIT => Message::PlanSubmit {
                name: d.string()?,
                plan: {
                    let n = d.list_len(1)?;
                    d.take(n)?.to_vec()
                },
            },
            TAG_PLAN_ACCEPTED => Message::PlanAccepted { plan: d.u32()? },
            TAG_PLAN_REJECTED => Message::PlanRejected {
                required: d.u64()?,
                available: d.u64()?,
                reason: d.string()?,
            },
            TAG_PLAN_STATUS => Message::PlanStatus { plan: d.u32()? },
            TAG_PLAN_STATUS_REPORT => Message::PlanStatusReport {
                plan: d.u32()?,
                state: d.u8()?,
                completed: d.u32()?,
                total: d.u32()?,
                detail: d.string()?,
            },
            TAG_PLAN_RESULT => {
                let plan = d.u32()?;
                let state = d.u8()?;
                let comparisons = d.u64()?;
                let n_matches = d.list_len(12)?;
                let mut matches = Vec::with_capacity(n_matches);
                for _ in 0..n_matches {
                    let e1 = EntityId(d.u32()?);
                    let e2 = EntityId(d.u32()?);
                    let sim = d.f32()?;
                    matches.push(Correspondence { e1, e2, sim });
                }
                Message::PlanResult {
                    plan,
                    state,
                    comparisons,
                    matches,
                    detail: d.string()?,
                }
            }
            TAG_ERROR => Message::Error {
                message: d.string()?,
            },
            other => return Err(WireError::UnknownTag(other)),
        };
        d.finish()?;
        Ok(msg)
    }

    /// Short tag name for logs and error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Join { .. } => "Join",
            Message::JoinAck { .. } => "JoinAck",
            Message::Leave { .. } => "Leave",
            Message::LeaveAck => "LeaveAck",
            Message::TaskRequest { .. } => "TaskRequest",
            Message::TaskAssign { .. } => "TaskAssign",
            Message::NoTask { .. } => "NoTask",
            Message::Complete { .. } => "Complete",
            Message::Heartbeat { .. } => "Heartbeat",
            Message::HeartbeatAck => "HeartbeatAck",
            Message::TaskRequestBatch { .. } => "TaskRequestBatch",
            Message::TaskAssignBatch { .. } => "TaskAssignBatch",
            Message::TaskRejected { .. } => "TaskRejected",
            Message::FetchPartition { .. } => "FetchPartition",
            Message::Partition { .. } => "Partition",
            Message::ReplicaAnnounce { .. } => "ReplicaAnnounce",
            Message::ReplicaDirectory { .. } => "ReplicaDirectory",
            Message::Redirect { .. } => "Redirect",
            Message::SyncRequest { .. } => "SyncRequest",
            Message::SyncDone { .. } => "SyncDone",
            Message::StatsRequest => "StatsRequest",
            Message::StatsReport { .. } => "StatsReport",
            Message::PlanSubmit { .. } => "PlanSubmit",
            Message::PlanAccepted { .. } => "PlanAccepted",
            Message::PlanRejected { .. } => "PlanRejected",
            Message::PlanStatus { .. } => "PlanStatus",
            Message::PlanStatusReport { .. } => "PlanStatusReport",
            Message::PlanResult { .. } => "PlanResult",
            Message::Error { .. } => "Error",
        }
    }
}

// ------------------------------------------------------------- decoder

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool, WireError> {
        Ok(self.u8()? != 0)
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn service(&mut self) -> Result<ServiceId, WireError> {
        Ok(ServiceId(self.u32()? as usize))
    }

    /// Element count of a collection whose elements need at least
    /// `min_elem_bytes` each — validated against the remaining buffer so
    /// a corrupt count cannot trigger a huge allocation.
    fn list_len(&mut self, min_elem_bytes: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        self.ensure_remaining(n, min_elem_bytes)?;
        Ok(n)
    }

    /// Re-validate an already-read count against the bytes still in the
    /// buffer (used when one count sizes several consecutive arrays
    /// whose per-element wire footprints differ).
    fn ensure_remaining(
        &self,
        count: usize,
        min_elem_bytes: usize,
    ) -> Result<(), WireError> {
        if count.saturating_mul(min_elem_bytes) > self.buf.len() - self.pos {
            return Err(WireError::Truncated);
        }
        Ok(())
    }

    fn string(&mut self) -> Result<String, WireError> {
        let len = self.list_len(1)?;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    fn str_list(&mut self) -> Result<Vec<String>, WireError> {
        // each string needs at least its own 4-byte length prefix
        let n = self.list_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.string()?);
        }
        Ok(out)
    }

    fn partition_list(&mut self) -> Result<Vec<PartitionId>, WireError> {
        let n = self.list_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(PartitionId(self.u32()?));
        }
        Ok(out)
    }

    fn span(&mut self) -> Result<Option<TaskSpan>, WireError> {
        if !self.bool()? {
            return Ok(None);
        }
        Ok(Some(TaskSpan {
            left: (self.u32()?, self.u32()?),
            right: (self.u32()?, self.u32()?),
        }))
    }

    fn u64_list(&mut self) -> Result<Vec<u64>, WireError> {
        let n = self.list_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    fn features(&mut self) -> Result<EntityFeatures, WireError> {
        let title_norm = self.string()?;
        let title_grams = QGramSet::from_hashes(self.u64_list()?);
        let title_tokens = TokenSet::from_hashes(self.u64_list()?);
        let desc_grams = QGramSet::from_hashes(self.u64_list()?);
        Ok(EntityFeatures {
            title_chars: title_norm.chars().collect(),
            title_sparse: title_grams.to_sparse(),
            desc_sparse: desc_grams.to_sparse(),
            title_norm,
            title_grams,
            title_tokens,
            desc_grams,
        })
    }

    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::TrailingBytes(self.buf.len() - self.pos));
        }
        Ok(())
    }
}

/// Randomized message generators shared by this module's property
/// tests and the [`session`] chunk-fuzzing tests.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::Rng;

    pub(crate) fn rand_string(rng: &mut Rng, max_len: usize) -> String {
        let len = rng.gen_range(max_len + 1);
        (0..len)
            .map(|_| {
                // mixed ASCII + a multibyte char to exercise UTF-8 paths
                match rng.gen_range(20) {
                    0 => 'ü',
                    n => (b'a' + (n as u8 % 26)) as char,
                }
            })
            .collect()
    }

    pub(crate) fn rand_features(rng: &mut Rng) -> EntityFeatures {
        let title = rand_string(rng, 24);
        let desc = rand_string(rng, 60);
        let title_grams = QGramSet::new(&title, 3);
        let desc_grams = QGramSet::new(&desc, 3);
        EntityFeatures {
            title_chars: crate::features::normalize(&title).chars().collect(),
            title_norm: crate::features::normalize(&title),
            title_sparse: title_grams.to_sparse(),
            desc_sparse: desc_grams.to_sparse(),
            title_grams,
            title_tokens: TokenSet::new(&title),
            desc_grams,
        }
    }

    pub(crate) fn rand_span(rng: &mut Rng) -> Option<TaskSpan> {
        if rng.gen_bool(0.5) {
            return None;
        }
        let l0 = rng.gen_range(100) as u32;
        let r0 = rng.gen_range(100) as u32;
        Some(TaskSpan {
            left: (l0, l0 + 1 + rng.gen_range(50) as u32),
            right: (r0, r0 + 1 + rng.gen_range(50) as u32),
        })
    }

    pub(crate) fn rand_partition(rng: &mut Rng) -> PartitionData {
        let n = rng.gen_range(6);
        let entities: Vec<EntityId> =
            (0..n).map(|i| EntityId(i as u32 * 7)).collect();
        let features = (0..n).map(|_| rand_features(rng)).collect();
        PartitionData {
            id: PartitionId(rng.gen_range(1000) as u32),
            entities,
            features,
            approx_bytes: rng.gen_range(1 << 20) as u64,
        }
    }

    /// One of each message kind (all protocol versions) with
    /// randomized fields.
    pub(crate) fn arbitrary_messages(rng: &mut Rng) -> Vec<Message> {
        let svc = ServiceId(rng.gen_range(64));
        vec![
            Message::Join {
                name: rand_string(rng, 16),
                version: rng.gen_range(256) as u8,
                mem_budget: rng.gen_range(1 << 30) as u64,
            },
            Message::JoinAck {
                service: svc,
                version: rng.gen_range(256) as u8,
                replicas: (0..rng.gen_range(4))
                    .map(|i| format!("10.0.0.{i}:74{i:02}"))
                    .collect(),
            },
            Message::Leave { service: svc },
            Message::LeaveAck,
            Message::TaskRequest { service: svc },
            Message::TaskAssign {
                task: MatchTask {
                    id: rng.gen_range(10_000) as u32,
                    left: PartitionId(rng.gen_range(500) as u32),
                    right: PartitionId(rng.gen_range(500) as u32),
                },
                mem_bytes: rng.gen_range(1 << 30) as u64,
                span: rand_span(rng),
            },
            Message::TaskRejected {
                service: svc,
                task_id: rng.gen_range(10_000) as u32,
            },
            Message::NoTask {
                done: rng.gen_bool(0.5),
            },
            Message::Complete {
                service: svc,
                task_id: rng.gen_range(10_000) as u32,
                comparisons: rng.gen_range(1 << 30) as u64,
                cached: (0..rng.gen_range(5))
                    .map(|i| PartitionId(i as u32))
                    .collect(),
                matches: (0..rng.gen_range(5))
                    .map(|i| Correspondence {
                        e1: EntityId(2 * i as u32),
                        e2: EntityId(2 * i as u32 + 1),
                        sim: (rng.gen_range(1000) as f32) / 1000.0,
                    })
                    .collect(),
            },
            Message::Heartbeat {
                service: svc,
                busy_ns: rng.gen_range(1 << 40) as u64,
                cache_hits: rng.gen_range(1 << 20) as u64,
                cache_misses: rng.gen_range(1 << 20) as u64,
                tasks_done: rng.gen_range(1 << 16) as u64,
            },
            Message::HeartbeatAck,
            Message::StatsRequest,
            Message::StatsReport {
                stats: (0..rng.gen_range(64))
                    .map(|_| rng.gen_range(256) as u8)
                    .collect(),
            },
            Message::FetchPartition {
                id: PartitionId(rng.gen_range(500) as u32),
            },
            Message::Partition {
                data: rand_partition(rng),
            },
            Message::ReplicaAnnounce {
                addr: format!("127.0.0.1:{}", 1024 + rng.gen_range(60_000)),
                version: rng.gen_range(256) as u8,
                partitions: (0..rng.gen_range(6))
                    .map(|i| PartitionId(i as u32))
                    .collect(),
            },
            Message::ReplicaDirectory {
                replicas: (0..rng.gen_range(4))
                    .map(|i| format!("replica-{i}:7402"))
                    .collect(),
            },
            Message::Redirect {
                addr: rand_string(rng, 24),
            },
            Message::SyncRequest {
                have: (0..rng.gen_range(8))
                    .map(|i| PartitionId(i as u32 * 3))
                    .collect(),
            },
            Message::SyncDone {
                count: rng.gen_range(10_000) as u32,
            },
            Message::TaskRequestBatch {
                service: svc,
                max: 1 + rng.gen_range(16) as u32,
                cached: (0..rng.gen_range(5))
                    .map(|i| PartitionId(i as u32))
                    .collect(),
                completed: (0..rng.gen_range(4))
                    .map(|i| CompletedTask {
                        task_id: i as u32,
                        comparisons: rng.gen_range(1 << 20) as u64,
                        matches: (0..rng.gen_range(3))
                            .map(|j| Correspondence {
                                e1: EntityId(2 * j as u32),
                                e2: EntityId(2 * j as u32 + 1),
                                sim: (rng.gen_range(1000) as f32) / 1000.0,
                            })
                            .collect(),
                    })
                    .collect(),
            },
            Message::TaskAssignBatch {
                done: rng.gen_bool(0.5),
                tasks: (0..rng.gen_range(9))
                    .map(|i| AssignedTask {
                        task: MatchTask {
                            id: i as u32,
                            left: PartitionId(rng.gen_range(500) as u32),
                            right: PartitionId(rng.gen_range(500) as u32),
                        },
                        mem_bytes: rng.gen_range(1 << 40) as u64,
                        span: rand_span(rng),
                    })
                    .collect(),
            },
            Message::PlanSubmit {
                name: rand_string(rng, 16),
                plan: (0..rng.gen_range(128))
                    .map(|_| rng.gen_range(256) as u8)
                    .collect(),
            },
            Message::PlanAccepted {
                plan: rng.gen_range(10_000) as u32,
            },
            Message::PlanRejected {
                required: rng.gen_range(1 << 40) as u64,
                available: rng.gen_range(1 << 40) as u64,
                reason: rand_string(rng, 40),
            },
            Message::PlanStatus {
                plan: rng.gen_range(10_000) as u32,
            },
            Message::PlanStatusReport {
                plan: rng.gen_range(10_000) as u32,
                state: rng.gen_range(5) as u8,
                completed: rng.gen_range(1000) as u32,
                total: rng.gen_range(1000) as u32,
                detail: rand_string(rng, 24),
            },
            Message::PlanResult {
                plan: rng.gen_range(10_000) as u32,
                state: 2 + rng.gen_range(3) as u8,
                comparisons: rng.gen_range(1 << 40) as u64,
                matches: (0..rng.gen_range(6))
                    .map(|i| Correspondence {
                        e1: EntityId(2 * i as u32),
                        e2: EntityId(2 * i as u32 + 1),
                        sim: (rng.gen_range(1000) as f32) / 1000.0,
                    })
                    .collect(),
                detail: rand_string(rng, 24),
            },
            Message::Error {
                message: rand_string(rng, 40),
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;
    use crate::util::proptest::forall;

    /// Property: every message round-trips encode → decode → encode to
    /// identical bytes (the encoding is canonical, so byte equality is
    /// full structural equality).
    #[test]
    fn prop_roundtrip_every_message_type() {
        forall("wire-roundtrip", 48, |rng| {
            for msg in arbitrary_messages(rng) {
                let bytes = msg.encode();
                let decoded = Message::decode(&bytes).unwrap_or_else(|e| {
                    panic!("decode {}: {e}", msg.kind())
                });
                assert_eq!(
                    decoded.encode(),
                    bytes,
                    "canonical re-encode mismatch for {}",
                    msg.kind()
                );
                assert_eq!(decoded.kind(), msg.kind());
            }
        });
    }

    /// Property: every strict prefix of a valid payload is rejected —
    /// decode never half-reads a truncated frame.
    #[test]
    fn prop_truncated_frames_rejected() {
        forall("wire-truncated", 24, |rng| {
            for msg in arbitrary_messages(rng) {
                let bytes = msg.encode();
                // all prefixes for small messages, sampled for large ones
                let step = (bytes.len() / 64).max(1);
                for cut in (0..bytes.len()).step_by(step) {
                    assert!(
                        Message::decode(&bytes[..cut]).is_err(),
                        "{}: prefix {cut}/{} decoded",
                        msg.kind(),
                        bytes.len()
                    );
                }
            }
        });
    }

    /// Property: trailing junk after a valid message is rejected.
    #[test]
    fn prop_trailing_bytes_rejected() {
        forall("wire-trailing", 24, |rng| {
            for msg in arbitrary_messages(rng) {
                let mut bytes = msg.encode();
                bytes.push(rng.gen_range(256) as u8);
                match Message::decode(&bytes) {
                    Err(_) => {}
                    Ok(d) => panic!(
                        "{}: decoded with trailing byte as {}",
                        msg.kind(),
                        d.kind()
                    ),
                }
            }
        });
    }

    /// The handshake frames put the version byte immediately after the
    /// tag, so a version check needs no further parsing — the layout
    /// contract `docs/WIRE_PROTOCOL.md` § Version negotiation relies on.
    #[test]
    fn version_byte_is_first_after_tag_in_handshake_frames() {
        let join = Message::Join {
            name: "n".into(),
            version: 0xAB,
            mem_budget: 0,
        }
        .encode();
        assert_eq!(join[0], TAG_JOIN);
        assert_eq!(join[1], 0xAB);
        let ack = Message::JoinAck {
            service: ServiceId(1),
            version: 0xCD,
            replicas: vec![],
        }
        .encode();
        assert_eq!(ack[0], TAG_JOIN_ACK);
        assert_eq!(ack[1], 0xCD);
        let ann = Message::ReplicaAnnounce {
            addr: "h:1".into(),
            version: 0xEF,
            partitions: vec![],
        }
        .encode();
        assert_eq!(ann[0], TAG_REPLICA_ANNOUNCE);
        assert_eq!(ann[1], 0xEF);
    }

    /// The handshake-salvage helper: a foreign version byte is
    /// recoverable from handshake frames whose body no longer
    /// decodes, and only from handshake frames.
    #[test]
    fn foreign_handshake_version_reads_the_version_byte() {
        // a v4-era Join: tag, version byte, name — no budget field
        let mut legacy = vec![TAG_JOIN, PROTOCOL_VERSION - 1];
        put_str(&mut legacy, "old-node");
        assert!(Message::decode(&legacy).is_err(), "layout changed in v5");
        assert_eq!(
            foreign_handshake_version(&legacy),
            Some(PROTOCOL_VERSION - 1)
        );
        // current-version handshakes are not flagged…
        let current = Message::Join {
            name: "new-node".into(),
            version: PROTOCOL_VERSION,
            mem_budget: 7,
        }
        .encode();
        assert_eq!(foreign_handshake_version(&current), None);
        // …nor are non-handshake frames or runts
        assert_eq!(
            foreign_handshake_version(
                &Message::NoTask { done: true }.encode()
            ),
            None
        );
        assert_eq!(foreign_handshake_version(&[TAG_JOIN]), None);
        assert_eq!(foreign_handshake_version(&[]), None);
        // ReplicaAnnounce is a handshake frame too
        assert_eq!(
            foreign_handshake_version(&[TAG_REPLICA_ANNOUNCE, 0]),
            Some(0)
        );
    }

    /// The v5 join: the node's §3.1 budget rides the handshake (0 =
    /// unlimited) and round-trips exactly.
    #[test]
    fn v5_join_carries_memory_budget() {
        for budget in [0u64, 1, 3 * 1024 * 1024 * 1024] {
            let msg = Message::Join {
                name: "budgeted".into(),
                version: PROTOCOL_VERSION,
                mem_budget: budget,
            };
            let Ok(Message::Join {
                name, mem_budget, ..
            }) = Message::decode(&msg.encode())
            else {
                panic!("decode Join");
            };
            assert_eq!(name, "budgeted");
            assert_eq!(mem_budget, budget);
        }
    }

    #[test]
    fn replica_directory_roundtrips_addresses_in_order() {
        let dir = vec![
            "10.1.2.3:7402".to_string(),
            "10.1.2.4:7402".to_string(),
        ];
        let msg = Message::JoinAck {
            service: ServiceId(9),
            version: PROTOCOL_VERSION,
            replicas: dir.clone(),
        };
        let Ok(Message::JoinAck {
            service,
            version,
            replicas,
        }) = Message::decode(&msg.encode())
        else {
            panic!("decode JoinAck");
        };
        assert_eq!(service, ServiceId(9));
        assert_eq!(version, PROTOCOL_VERSION);
        assert_eq!(replicas, dir);
    }

    #[test]
    fn sync_request_with_lying_count_rejected_before_alloc() {
        let mut b = vec![TAG_SYNC_REQUEST];
        put_u32(&mut b, u32::MAX); // claims 4 billion held partitions
        assert!(matches!(
            Message::decode(&b),
            Err(WireError::Truncated)
        ));
    }

    #[test]
    fn unknown_tag_rejected() {
        assert!(matches!(
            Message::decode(&[0xEE]),
            Err(WireError::UnknownTag(0xEE))
        ));
        assert!(matches!(
            Message::decode(&[]),
            Err(WireError::Truncated)
        ));
    }

    #[test]
    fn corrupt_length_does_not_overallocate() {
        // a Complete frame claiming 4 billion cached partitions must be
        // rejected by the remaining-bytes check, not attempted
        let mut b = vec![TAG_COMPLETE];
        put_u32(&mut b, 1); // service
        put_u32(&mut b, 2); // task
        put_u64(&mut b, 3); // comparisons
        put_u32(&mut b, u32::MAX); // cached count — lies
        assert!(matches!(
            Message::decode(&b),
            Err(WireError::Truncated)
        ));
    }

    #[test]
    fn partition_frame_with_lying_entity_count_rejected_before_alloc() {
        // a frame whose entity count is covered by entity-id bytes but
        // whose feature section is absent must fail the second
        // remaining-bytes check, not reserve features capacity for it
        let n = 1000u32;
        let mut b = vec![TAG_PARTITION];
        put_u32(&mut b, 1); // id
        put_u64(&mut b, 0); // approx_bytes
        put_u32(&mut b, n);
        for i in 0..n {
            put_u32(&mut b, i); // entity ids — present and valid
        }
        // …and zero feature bytes follow
        assert!(matches!(
            Message::decode(&b),
            Err(WireError::Truncated)
        ));
    }

    #[test]
    fn partition_payload_reconstructs_derived_features() {
        let title = "LG GH22NS50 Super Multi";
        let desc = "22x dvd writer sata";
        let title_grams = QGramSet::new(title, 3);
        let desc_grams = QGramSet::new(desc, 3);
        let f = EntityFeatures {
            title_chars: crate::features::normalize(title).chars().collect(),
            title_norm: crate::features::normalize(title),
            title_sparse: title_grams.to_sparse(),
            desc_sparse: desc_grams.to_sparse(),
            title_grams,
            title_tokens: TokenSet::new(title),
            desc_grams,
        };
        let data = PartitionData {
            id: PartitionId(7),
            entities: vec![EntityId(1)],
            features: vec![f],
            approx_bytes: 1234,
        };
        let bytes = encode_partition_message(&data);
        let Ok(Message::Partition { data: back }) = Message::decode(&bytes)
        else {
            panic!("decode partition");
        };
        assert_eq!(back.id, data.id);
        assert_eq!(back.entities, data.entities);
        assert_eq!(back.approx_bytes, data.approx_bytes);
        let (a, b) = (&back.features[0], &data.features[0]);
        assert_eq!(a.title_norm, b.title_norm);
        assert_eq!(a.title_chars, b.title_chars);
        assert_eq!(a.title_grams, b.title_grams);
        assert_eq!(a.title_tokens, b.title_tokens);
        assert_eq!(a.desc_grams, b.desc_grams);
        assert_eq!(a.title_sparse, b.title_sparse);
        assert_eq!(a.desc_sparse, b.desc_sparse);
    }

    #[test]
    fn message_encoding_via_enum_matches_borrowed_encoder() {
        let data = PartitionData {
            id: PartitionId(3),
            entities: vec![],
            features: vec![],
            approx_bytes: 0,
        };
        let borrowed = encode_partition_message(&data);
        let owned = Message::Partition { data }.encode();
        assert_eq!(borrowed, owned);
    }

    /// The v3 batch frames round-trip with field order and content
    /// preserved (assignments must arrive in scheduler preference
    /// order).
    #[test]
    fn batch_frames_roundtrip_in_order() {
        let req = Message::TaskRequestBatch {
            service: ServiceId(4),
            max: 8,
            cached: vec![PartitionId(1), PartitionId(9)],
            completed: vec![
                CompletedTask {
                    task_id: 7,
                    comparisons: 1234,
                    matches: vec![Correspondence {
                        e1: EntityId(1),
                        e2: EntityId(2),
                        sim: 0.75,
                    }],
                },
                CompletedTask {
                    task_id: 8,
                    comparisons: 0,
                    matches: vec![],
                },
            ],
        };
        let Ok(Message::TaskRequestBatch {
            service,
            max,
            cached,
            completed,
        }) = Message::decode(&req.encode())
        else {
            panic!("decode TaskRequestBatch");
        };
        assert_eq!(service, ServiceId(4));
        assert_eq!(max, 8);
        assert_eq!(cached, vec![PartitionId(1), PartitionId(9)]);
        assert_eq!(completed.len(), 2);
        assert_eq!(completed[0].task_id, 7);
        assert_eq!(completed[0].matches[0].sim, 0.75);
        assert_eq!(completed[1].task_id, 8);

        let assign = Message::TaskAssignBatch {
            done: false,
            tasks: (0..3)
                .map(|i| AssignedTask {
                    task: MatchTask {
                        id: i,
                        left: PartitionId(i),
                        right: PartitionId(i + 1),
                    },
                    mem_bytes: 1000 * i as u64,
                    span: (i == 1).then_some(TaskSpan {
                        left: (0, 10),
                        right: (10, 20),
                    }),
                })
                .collect(),
        };
        let Ok(Message::TaskAssignBatch { done, tasks }) =
            Message::decode(&assign.encode())
        else {
            panic!("decode TaskAssignBatch");
        };
        assert!(!done);
        assert_eq!(
            tasks.iter().map(|a| a.task.id).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "assignment order preserved"
        );
        assert_eq!(
            tasks.iter().map(|a| a.mem_bytes).collect::<Vec<_>>(),
            vec![0, 1000, 2000],
            "footprints travel with the tasks"
        );
        assert_eq!(
            tasks.iter().map(|a| a.span).collect::<Vec<_>>(),
            vec![
                None,
                Some(TaskSpan {
                    left: (0, 10),
                    right: (10, 20),
                }),
                None
            ],
            "spans travel with the tasks"
        );
    }

    /// The v4/v5 frames: the single assignment carries its footprint
    /// (and, for a runtime-split sub-task, its span) and a rejection
    /// round-trips exactly.
    #[test]
    fn v4_assignment_and_rejection_roundtrip() {
        let assign = Message::TaskAssign {
            task: MatchTask {
                id: 7,
                left: PartitionId(1),
                right: PartitionId(2),
            },
            mem_bytes: 123_456_789,
            span: None,
        };
        let Ok(Message::TaskAssign {
            task,
            mem_bytes,
            span,
        }) = Message::decode(&assign.encode())
        else {
            panic!("decode TaskAssign");
        };
        assert_eq!(task.id, 7);
        assert_eq!(mem_bytes, 123_456_789);
        assert_eq!(span, None);

        // a runtime-split sub-task: the span survives the round trip
        let sub = Message::TaskAssign {
            task: MatchTask {
                id: 900,
                left: PartitionId(4),
                right: PartitionId(4),
            },
            mem_bytes: 4_000,
            span: Some(TaskSpan {
                left: (0, 15),
                right: (15, 31),
            }),
        };
        let Ok(Message::TaskAssign { span, .. }) =
            Message::decode(&sub.encode())
        else {
            panic!("decode split TaskAssign");
        };
        assert_eq!(
            span,
            Some(TaskSpan {
                left: (0, 15),
                right: (15, 31),
            })
        );

        let rej = Message::TaskRejected {
            service: ServiceId(3),
            task_id: 7,
        };
        let Ok(Message::TaskRejected { service, task_id }) =
            Message::decode(&rej.encode())
        else {
            panic!("decode TaskRejected");
        };
        assert_eq!(service, ServiceId(3));
        assert_eq!(task_id, 7);
    }

    /// The v6 observability frames: a `StatsRequest` is a bare tag, a
    /// `StatsReport` carries an opaque snapshot blob that round-trips
    /// bit-exactly (and decodes as a real `MetricsSnapshot`).
    #[test]
    fn v6_stats_frames_roundtrip() {
        let req = Message::StatsRequest;
        assert_eq!(req.encode(), vec![TAG_STATS_REQUEST]);
        assert!(matches!(
            Message::decode(&req.encode()),
            Ok(Message::StatsRequest)
        ));

        let reg = crate::obs::Registry::new();
        reg.counter("tasks_completed").add(17);
        reg.histogram("fetch_ns").observe(1_000_000);
        reg.set_label("role", "workflow");
        let snap = reg.snapshot();
        let msg = Message::StatsReport {
            stats: snap.to_bytes(),
        };
        let Ok(Message::StatsReport { stats }) =
            Message::decode(&msg.encode())
        else {
            panic!("decode StatsReport");
        };
        let back =
            crate::obs::MetricsSnapshot::from_bytes(&stats).unwrap();
        assert_eq!(back, snap);
        // lying blob length rejected before allocation
        let mut b = vec![TAG_STATS_REPORT];
        put_u32(&mut b, u32::MAX);
        assert!(matches!(Message::decode(&b), Err(WireError::Truncated)));
    }

    /// The v6 heartbeat: the liveness frame doubles as a stats push;
    /// the load counters round-trip exactly.
    #[test]
    fn v6_heartbeat_carries_load_counters() {
        let hb = Message::Heartbeat {
            service: ServiceId(2),
            busy_ns: 123_456_789_000,
            cache_hits: 40,
            cache_misses: 8,
            tasks_done: 31,
        };
        let Ok(Message::Heartbeat {
            service,
            busy_ns,
            cache_hits,
            cache_misses,
            tasks_done,
        }) = Message::decode(&hb.encode())
        else {
            panic!("decode Heartbeat");
        };
        assert_eq!(service, ServiceId(2));
        assert_eq!(busy_ns, 123_456_789_000);
        assert_eq!(cache_hits, 40);
        assert_eq!(cache_misses, 8);
        assert_eq!(tasks_done, 31);
    }

    /// Hostile batch counts are rejected before any allocation, like
    /// every other list in the protocol.
    #[test]
    fn batch_frames_with_lying_counts_rejected() {
        // a TaskRequestBatch claiming 4 billion completed tasks
        let mut b = vec![TAG_TASK_REQUEST_BATCH];
        put_u32(&mut b, 1); // service
        put_u32(&mut b, 4); // max
        put_u32(&mut b, 0); // cached: empty
        put_u32(&mut b, u32::MAX); // completed count — lies
        assert!(matches!(
            Message::decode(&b),
            Err(WireError::Truncated)
        ));
        // a TaskAssignBatch claiming 4 billion tasks
        let mut b = vec![TAG_TASK_ASSIGN_BATCH];
        b.push(0); // done = false
        put_u32(&mut b, u32::MAX); // task count — lies
        assert!(matches!(
            Message::decode(&b),
            Err(WireError::Truncated)
        ));
    }
}
