//! Stream framing and a small blocking transport over `TcpStream`.
//!
//! Frames are `u32 LE length` + payload (see [`super`] for the payload
//! format).  [`Transport`] wraps one TCP connection with buffered
//! reads/writes, per-connection byte accounting, and the one-round-trip
//! `request` helper the services are built on.  Everything is blocking
//! std I/O — one OS thread per connection, the same execution model as
//! the paper's RMI runtime.

use super::{encode_partition_message, Message, WireError};
use crate::store::PartitionData;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Upper bound on a single frame; larger headers are treated as stream
/// corruption.  Partitions of ~1000 entities serialize to a few MB, so
/// 256 MiB leaves room for extreme configurations while still rejecting
/// garbage lengths immediately.
pub const MAX_FRAME_BYTES: u64 = 256 * 1024 * 1024;

fn wire_err(e: WireError) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, e)
}

/// Write one frame (length prefix + payload); returns bytes written.
pub fn write_frame<W: Write>(w: &mut W, msg: &Message) -> io::Result<u64> {
    write_payload(w, &msg.encode())
}

fn write_payload<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<u64> {
    let len = payload.len() as u64;
    if len > MAX_FRAME_BYTES {
        return Err(wire_err(WireError::FrameTooLarge(len)));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(len + 4)
}

/// Read one frame; `Err(UnexpectedEof)` when the peer closed cleanly
/// between frames, `InvalidData` on corrupt payloads.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Message> {
    let payload = read_frame_raw(r)?;
    Message::decode(&payload).map_err(wire_err)
}

/// Read one frame's payload **without decoding it** (the length header
/// is still validated against [`MAX_FRAME_BYTES`]).  Replication uses
/// this to store the primary's encoded partition frames byte-for-byte,
/// so a replica re-serves exactly the bytes the primary would have sent.
pub fn read_frame_raw<R: Read>(r: &mut R) -> io::Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as u64;
    if len > MAX_FRAME_BYTES {
        return Err(wire_err(WireError::FrameTooLarge(len)));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// One framed, buffered, byte-counting TCP connection.
pub struct Transport {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Bytes written to the socket (frames incl. length prefixes).
    pub sent_bytes: u64,
    /// Frames written.
    pub sent_messages: u64,
}

impl Transport {
    /// Connect to `addr`, with `timeout` for connection establishment
    /// and subsequent reads (writes inherit OS defaults).  Like
    /// `TcpStream::connect`, every resolved address is tried in order —
    /// on dual-stack hosts `localhost` may resolve to `::1` first while
    /// the server listens on IPv4 only.
    pub fn connect<A: ToSocketAddrs>(
        addr: A,
        timeout: Duration,
    ) -> io::Result<Transport> {
        let mut last_err = None;
        for sock_addr in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&sock_addr, timeout) {
                Ok(stream) => {
                    stream.set_read_timeout(Some(timeout))?;
                    return Transport::from_stream(stream);
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            io::Error::new(
                io::ErrorKind::AddrNotAvailable,
                "address resolved to nothing",
            )
        }))
    }

    /// Wrap an accepted connection.
    pub fn from_stream(stream: TcpStream) -> io::Result<Transport> {
        stream.set_nodelay(true).ok(); // control messages are tiny
        let write_half = stream.try_clone()?;
        Ok(Transport {
            reader: BufReader::new(stream),
            writer: BufWriter::new(write_half),
            sent_bytes: 0,
            sent_messages: 0,
        })
    }

    /// Write one message as a frame; returns bytes written (payload +
    /// length prefix).
    pub fn send(&mut self, msg: &Message) -> io::Result<u64> {
        let n = write_frame(&mut self.writer, msg)?;
        self.sent_bytes += n;
        self.sent_messages += 1;
        Ok(n)
    }

    /// Send a partition payload encoded from a borrowed
    /// [`PartitionData`] (no deep clone); returns bytes written.
    pub fn send_partition(&mut self, data: &PartitionData) -> io::Result<u64> {
        self.send_raw_payload(&encode_partition_message(data))
    }

    /// Send a pre-encoded message payload (the frame length prefix is
    /// added here).  Lets servers cache serialized replies — the data
    /// service serves the same immutable partition bytes many times.
    pub fn send_raw_payload(&mut self, payload: &[u8]) -> io::Result<u64> {
        let n = write_payload(&mut self.writer, payload)?;
        self.sent_bytes += n;
        self.sent_messages += 1;
        Ok(n)
    }

    /// Block for the next frame and decode it.
    pub fn recv(&mut self) -> io::Result<Message> {
        read_frame(&mut self.reader)
    }

    /// Block for the next frame and return its raw payload bytes
    /// (see [`read_frame_raw`]).
    pub fn recv_raw(&mut self) -> io::Result<Vec<u8>> {
        read_frame_raw(&mut self.reader)
    }

    /// One RPC round trip: send `msg`, block for the reply.
    pub fn request(&mut self, msg: &Message) -> io::Result<Message> {
        self.send(msg)?;
        self.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ServiceId;
    use std::net::TcpListener;

    #[test]
    fn frame_roundtrip_over_real_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = Transport::from_stream(stream).unwrap();
            // echo until EOF
            while let Ok(msg) = t.recv() {
                t.send(&msg).unwrap();
            }
        });
        let mut c =
            Transport::connect(addr, Duration::from_secs(5)).unwrap();
        for msg in [
            Message::Join {
                name: "node0".into(),
                version: super::super::PROTOCOL_VERSION,
                mem_budget: 0,
            },
            Message::NoTask { done: true },
            Message::Heartbeat {
                service: ServiceId(3),
                busy_ns: 1,
                cache_hits: 2,
                cache_misses: 3,
                tasks_done: 4,
            },
        ] {
            let reply = c.request(&msg).unwrap();
            assert_eq!(reply.encode(), msg.encode());
        }
        assert_eq!(c.sent_messages, 3);
        assert!(c.sent_bytes > 0);
        drop(c);
        server.join().unwrap();
    }

    #[test]
    fn oversized_header_rejected() {
        let mut bad: &[u8] = &[0xFF, 0xFF, 0xFF, 0xFF, 0x00];
        let err = read_frame(&mut bad).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn short_stream_is_eof() {
        let mut short: &[u8] = &[4, 0, 0, 0, 1]; // promises 4, delivers 1
        let err = read_frame(&mut short).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }
}
