//! `pem` — the parallel entity matching CLI (the Layer-3 leader binary).
//!
//! Subcommands:
//!
//! * `generate` — produce a synthetic product-offer dataset and print its
//!   block-structure statistics;
//! * `plan`     — run ONLY the planning half (partitioning → task
//!   generation → memory footprints) and print the plan: partition
//!   stats, task skew, the heaviest tasks — without paying for
//!   execution.  `--save plan.bin` writes the serialized plan;
//! * `match`    — run a full match workflow (plan → execute) and report
//!   the result;
//! * `sweep`    — run a core-count sweep (the Figs 8/9 experiment
//!   shape); a failing cell reports its strategy/backend combination
//!   and the sweep continues;
//! * `serve`    — start the workflow + data services on TCP ports and
//!   wait for match-service nodes to complete the workflow; with
//!   `--role data --replica-of HOST:PORT` it instead runs a standalone
//!   data-plane replica that syncs from a running coordinator and
//!   serves fetches until the coordinator goes away;
//! * `distmatch`— run one match-service node process against a running
//!   `pem serve` coordinator (give `--data` a comma-separated replica
//!   list, or let the join-time directory supply it);
//! * `submit`   — send a saved match plan (`pem plan --save`) to a
//!   *resident* coordinator (`pem serve --resident`, protocol v7) and
//!   follow it to completion; admission is checked against the live
//!   cluster's aggregate §3.1 budget;
//! * `stats`    — scrape a RUNNING cluster's live metrics over the
//!   wire (protocol v6 `StatsRequest`): scheduler queue depth,
//!   per-node busy/idle, cache hit ratios, fetch-latency histograms;
//! * `artifacts`— inspect the AOT artifact manifest and smoke-run the
//!   PJRT path on a tiny workload;
//! * `info`     — print the computing-environment and memory-model
//!   numbers for a configuration.
//!
//! A full multi-process match on one machine:
//!
//! ```text
//! $ pem serve --entities 20000 --workflow-port 7401 --data-port 7402
//! $ pem distmatch --workflow 127.0.0.1:7401 --data 127.0.0.1:7402 \
//!       --threads 4 --cache 8   # repeat per node / machine
//! ```

use anyhow::{bail, Context, Result};
use pem::blocking::BlockingMethod;
use pem::cluster::ComputingEnv;
use pem::coordinator::workflow::{default_max_size, default_min_size};
use pem::coordinator::{Policy, Workflow};
use pem::datagen::GeneratorConfig;
use pem::engine::backend::{
    Dist, DistOptions, ExecutionBackend, Sim, SimOptions, Threads,
};
use pem::matching::{MatchStrategy, StrategyKind};
use pem::metrics::speedups;
use pem::model::Dataset;
use pem::partition::{
    max_partition_size, BlockSplit, BlockingBased, PartitionStrategy,
    SizeBased, SortedNeighborhood,
};
use pem::util::cli::Args;
use pem::util::{fmt_bytes, fmt_nanos, GIB};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: pem <generate|export|plan|match|sweep|serve|distmatch|submit|stats|artifacts|info> [options]
  common options:
    --entities N          dataset size (default 20000)
    --seed S              generator seed (default 2010)
    --strategy wam|lrm    match strategy (default wam)
  export options:
    --out offers.csv      write the generated dataset as CSV
    --truth truth.csv     also write the ground-truth duplicate pairs
  match options:
    --input offers.csv    match a CSV (or .jsonl) dataset instead of
                          generating one; JSONL is read incrementally
                          (schema from the first record)
    --out matches.csv     write correspondences as CSV
    --trace out.jsonl     dump the per-task lifecycle trace as JSONL
                          (one event per line) and replay-verify that
                          every plan task completed exactly once
  plan options (plan only, no execution):
    --save plan.bin       write the serialized MatchPlan
    --top N               print the N heaviest tasks (default 5)
  plan/match/sweep options:
    --partitioning size|blocking|blocksplit|sn   (default blocking)
    --blocking-attr product_type|manufacturer
    --sn-attr ATTR        sorted-neighborhood sort key (default title)
    --window W            sorted-neighborhood window size (default 100)
    --target-pairs N      blocksplit: pair comparisons per task
                          (default (max-size/2)²; Kolb et al. balance)
    --max-size M  --min-size M     partition tuning bounds
    --nodes N --cores N --mem-gb G --threads T
    --cache C             partition cache capacity per service
    --no-affinity         disable affinity scheduling
    --engine sim|threads|dist  (default sim)
    --execute             really match inside the simulator
  sweep options:
    --cores-list 1,2,4,8,12,16
  match/sweep dist-engine options:
    --data-replicas N     data-plane servers incl. primary (default 1)
    --batch K             tasks pulled per control round trip
                          (default 1 = classic per-task pull)
    --bind HOST           host the services bind (default 127.0.0.1)
    --mem-budget BYTES    per-node §3.1 memory budget: nodes reject
                          assigned tasks whose plan footprint exceeds it
  match/serve out-of-core store options (primary data plane):
    --store resident|spill   partition store backend (default resident)
    --store-budget SIZE   spill hot-set byte budget, K/M/G suffix ok
                          (required with --store spill, e.g. 2G):
                          payloads beyond it live in checksummed spill
                          files and fault back in on demand
    --spill-dir DIR       keep spill files here (default: a fresh temp
                          dir, removed on exit)
    --hot-budget SIZE     partial replication: each data replica keeps
                          only the most-demanded frames within this
                          budget and redirects cold misses upstream
                          (default: replicas mirror everything)
  serve options (workflow + data services for multi-process matching):
    --workflow-port P     control-plane port (default 0 = ephemeral)
    --data-port P         data-plane port (default 0 = ephemeral)
    --bind HOST           host to bind (default 127.0.0.1; set to
                          0.0.0.0 together with --advertise to accept
                          remote nodes)
    --expect-nodes N      defer oversize-task splitting until N match
                          nodes have joined (default 1)
    --heartbeat-ms MS     failure-detection timeout (default 2000)
    --timeout-s S         give up after S seconds (default 3600)
    --advertise HOST      host to publish in the replica directory
                          (default 127.0.0.1; set to this machine's
                          address for multi-host runs)
    --trace out.jsonl     dump the scheduler's task-lifecycle trace
                          as JSONL when the workflow drains
    --resident            protocol v7 multi-tenant mode: keep the
                          cluster alive after the seed workflow drains
                          and accept `pem submit` plan submissions
                          (admission-controlled, fair-scheduled)
    --tenant-inflight K   fairness cap: at most K in-flight tasks per
                          submitted plan (default uncapped)
  serve --role data options (standalone data-plane replica):
    --replica-of HOST:PORT  upstream data server to sync from (required)
    --workflow HOST:PORT    coordinator to announce this replica to
    --data-port P           port to serve on (default 0 = ephemeral)
    --bind HOST             host to bind (default 127.0.0.1)
    --hot-budget SIZE       partial replica: hot-set byte budget
                            (default: mirror the full catalog)
  distmatch options (one match-service node):
    --workflow HOST:PORT  workflow service address (required)
    --data HOST:PORT[,HOST:PORT...]  data replica addresses (required;
                          the join-time directory adds any missing ones)
    --batch K             tasks pulled per round trip (default 1)
    --mem-budget BYTES    reject tasks whose footprint exceeds this
    --name NAME           node name  --threads T  --cache C
  submit options (submit a saved plan: pem submit plan.bin --to ADDR):
    --to HOST:PORT        resident workflow service (required)
    --name NAME           plan label in coordinator logs (default:
                          the file name)
    --out matches.csv     write the plan's correspondences as CSV
    --poll-ms MS          status poll period (default 200)
    --timeout-s S         give up following after S seconds (default 600)
  stats options (scrape a RUNNING cluster: pem stats HOST:PORT):
    --no-follow           scrape only the given address (by default a
                          workflow service's replica directory is
                          followed and the data servers scraped too)
    --json                print raw snapshots as JSON
    --timeout-s S         per-scrape connect/read timeout (default 5)"
    );
    std::process::exit(2);
}

fn parse_strategy(args: &Args) -> Result<StrategyKind> {
    let s = args.str_or("strategy", "wam");
    StrategyKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad strategy {s:?}"))
}

fn parse_ce(args: &Args) -> Result<ComputingEnv> {
    let nodes = args.get_or("nodes", 1usize)?;
    let cores = args.get_or("cores", 4usize)?;
    let mem_gb = args.get_or("mem-gb", 3.0f64)?;
    let mut ce = ComputingEnv::new(nodes, cores, (mem_gb * GIB as f64) as u64);
    let threads = args.get_or("threads", cores)?;
    ce = ce.with_threads(threads);
    Ok(ce)
}

/// An option that is `None` when the flag is absent (instead of a
/// default value).
fn opt_usize(args: &Args, name: &str) -> Result<Option<usize>> {
    if args.get_str(name).is_some() {
        Ok(Some(args.get_or(name, 0usize)?))
    } else {
        Ok(None)
    }
}

/// A `u64` option that is `None` when the flag is absent.
fn opt_u64(args: &Args, name: &str) -> Result<Option<u64>> {
    if args.get_str(name).is_some() {
        Ok(Some(args.get_or(name, 0u64)?))
    } else {
        Ok(None)
    }
}

/// `--mem-budget`, rejecting the degenerate 0: on the wire a budget
/// of 0 means "unlimited", and a node that fits nothing would only
/// grind the scheduler through pointless splits before the misfit.
fn parse_mem_budget(args: &Args) -> Result<Option<u64>> {
    match opt_u64(args, "mem-budget")? {
        Some(0) => bail!(
            "--mem-budget must be >= 1 (a budget of 0 would reject \
             every task; omit the flag for an unlimited node)"
        ),
        other => Ok(other),
    }
}

/// A byte count with an optional K/M/G suffix: `4096`, `512K`, `2G`.
fn parse_size_suffix(s: &str) -> Result<u64> {
    let t = s.trim();
    let (digits, mult) = match t.char_indices().last() {
        Some((i, c))
            if matches!(c.to_ascii_uppercase(), 'K' | 'M' | 'G') =>
        {
            let mult = match c.to_ascii_uppercase() {
                'K' => 1u64 << 10,
                'M' => 1u64 << 20,
                _ => 1u64 << 30,
            };
            (&t[..i], mult)
        }
        _ => (t, 1u64),
    };
    let n: u64 = digits
        .parse()
        .with_context(|| format!("bad size {s:?} (want e.g. 2G, 512M, 4096)"))?;
    Ok(n.saturating_mul(mult))
}

/// `--store resident|spill [--store-budget 2G] [--spill-dir DIR]` →
/// the primary's [`pem::store::StoreKind`].
fn parse_store(args: &Args) -> Result<pem::store::StoreKind> {
    match args.str_or("store", "resident") {
        "resident" => Ok(pem::store::StoreKind::Resident),
        "spill" => {
            let budget = match args.get_str("store-budget") {
                Some(s) => parse_size_suffix(s)?,
                None => bail!(
                    "--store spill requires --store-budget (the hot-set \
                     byte budget, e.g. --store-budget 2G)"
                ),
            };
            if budget == 0 {
                bail!("--store-budget must be >= 1");
            }
            Ok(pem::store::StoreKind::Spill {
                budget,
                dir: args
                    .get_str("spill-dir")
                    .map(std::path::PathBuf::from),
            })
        }
        other => bail!("bad --store {other:?} (resident|spill)"),
    }
}

/// `--hot-budget 64M` → the partial-replication hot-set budget
/// (`None` = replicas mirror the full catalog).
fn parse_hot_budget(args: &Args) -> Result<Option<u64>> {
    match args.get_str("hot-budget") {
        Some(s) => {
            let b = parse_size_suffix(s)?;
            if b == 0 {
                bail!("--hot-budget must be >= 1");
            }
            Ok(Some(b))
        }
        None => Ok(None),
    }
}

/// `--blocking-attr product_type|manufacturer` → the blocking method
/// shared by the blocking and blocksplit strategies.
fn parse_blocking_method(args: &Args) -> Result<BlockingMethod> {
    Ok(match args.str_or("blocking-attr", "product_type") {
        "product_type" => BlockingMethod::product_type(),
        "manufacturer" => BlockingMethod::manufacturer(),
        other => bail!("bad blocking attr {other:?}"),
    })
}

/// `--partitioning size|blocking|blocksplit|sn` → the open-API
/// strategy.
fn parse_partition_strategy(
    args: &Args,
    kind: StrategyKind,
) -> Result<Box<dyn PartitionStrategy>> {
    let max_size =
        Some(args.get_or("max-size", default_max_size(kind))?);
    Ok(match args.str_or("partitioning", "blocking") {
        "size" => Box::new(SizeBased { max_size }),
        "blocking" => Box::new(BlockingBased {
            method: parse_blocking_method(args)?,
            max_size,
            min_size: Some(
                args.get_or("min-size", default_min_size(kind))?,
            ),
        }),
        "blocksplit" | "block-split" => Box::new(BlockSplit {
            method: parse_blocking_method(args)?,
            max_size,
            min_size: Some(
                args.get_or("min-size", default_min_size(kind))?,
            ),
            target_pairs: opt_u64(args, "target-pairs")?,
        }),
        "sn" | "sorted" | "sorted-neighborhood" => Box::new(
            SortedNeighborhood {
                attribute: args
                    .str_or("sn-attr", pem::model::ATTR_TITLE)
                    .to_string(),
                window: args.get_or("window", 100usize)?,
                max_size: opt_usize(args, "max-size")?,
            },
        ),
        other => bail!("bad partitioning {other:?}"),
    })
}

/// `--engine sim|threads|dist` (+ its engine-specific flags) → the
/// open-API backend.
fn parse_backend(args: &Args) -> Result<Box<dyn ExecutionBackend>> {
    Ok(match args.str_or("engine", "sim") {
        "threads" => Box::new(Threads),
        "dist" => Box::new(Dist(DistOptions {
            replicas: args.get_or("data-replicas", 1usize)?,
            batch: args.get_or("batch", 1usize)?,
            bind: args.str_or("bind", "127.0.0.1").to_string(),
            memory_budget: parse_mem_budget(args)?,
            store: parse_store(args)?,
            replica_hot_budget: parse_hot_budget(args)?,
        })),
        "sim" => Box::new(Sim(SimOptions {
            execute: args.flag("execute"),
            calibrate: !args.flag("no-calibrate"),
            ..SimOptions::default()
        })),
        other => bail!("bad engine {other:?}"),
    })
}

fn parse_policy(args: &Args) -> Policy {
    if args.flag("no-affinity") {
        Policy::Fifo
    } else {
        Policy::Affinity
    }
}

/// Ground-truth duplicate pairs of a generated dataset.
type Truth = Vec<(pem::model::EntityId, pem::model::EntityId)>;

/// Dataset from `--input` (CSV or JSONL, by extension), or generated
/// (with its ground truth).
fn load_dataset(args: &Args) -> Result<(Dataset, Option<Truth>)> {
    match args.get_str("input") {
        Some(path) => Ok((
            pem::io::read_dataset_file(std::path::Path::new(path))?,
            None,
        )),
        None => {
            let g = GeneratorConfig::default()
                .with_entities(args.get_or("entities", 20_000usize)?)
                .with_seed(args.get_or("seed", 2010u64)?)
                .generate();
            Ok((g.dataset, Some(g.truth)))
        }
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional().first().map(String::as_str);
    match cmd {
        Some("generate") => cmd_generate(&args),
        Some("export") => cmd_export(&args),
        Some("plan") => cmd_plan(&args),
        Some("match") => cmd_match(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("serve") => cmd_serve(&args),
        Some("distmatch") => cmd_distmatch(&args),
        Some("submit") => cmd_submit(&args),
        Some("stats") => cmd_stats(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("info") => cmd_info(&args),
        _ => usage(),
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = GeneratorConfig::default()
        .with_entities(args.get_or("entities", 20_000usize)?)
        .with_seed(args.get_or("seed", 2010u64)?);
    let data = cfg.generate();
    println!(
        "generated {} offers of {} products ({} duplicate pairs)",
        data.dataset.len(),
        data.n_products,
        data.truth.len()
    );
    let blocks = BlockingMethod::product_type().run(&data.dataset);
    let hist = blocks.size_histogram();
    println!(
        "product-type blocks: {} (misc {}), sizes max={} median={} min={}",
        blocks.n_blocks(),
        blocks.misc().len(),
        hist.first().unwrap_or(&0),
        hist.get(hist.len() / 2).unwrap_or(&0),
        hist.last().unwrap_or(&0),
    );
    Ok(())
}

fn cmd_export(args: &Args) -> Result<()> {
    let data = GeneratorConfig::default()
        .with_entities(args.get_or("entities", 20_000usize)?)
        .with_seed(args.get_or("seed", 2010u64)?)
        .generate();
    let out_path = args.str_or("out", "offers.csv");
    pem::io::write_dataset_file(&data.dataset, std::path::Path::new(out_path))?;
    println!("wrote {} offers to {out_path}", data.dataset.len());
    if let Some(truth_path) = args.get_str("truth") {
        pem::io::write_truth(
            &data.truth,
            std::fs::File::create(truth_path)?,
        )?;
        println!("wrote {} truth pairs to {truth_path}", data.truth.len());
    }
    Ok(())
}

/// `pem plan`: run only the planning half and print the inspectable
/// plan — partitions, task skew, heaviest tasks, memory footprints vs
/// the per-task budget — without executing anything.
fn cmd_plan(args: &Args) -> Result<()> {
    let kind = parse_strategy(args)?;
    let ce = parse_ce(args)?;
    let (dataset, _truth) = load_dataset(args)?;
    let planned = Workflow::for_dataset(&dataset)
        .matching(kind)
        .strategy_boxed(parse_partition_strategy(args, kind)?)
        .env(ce)
        .plan()?;
    let plan = planned.plan();
    println!("{}", plan.summary());
    let budget = pem::partition::memory::mem_per_task(&ce);
    let skew = plan.skew();
    println!(
        "memory: max task footprint {} vs per-task budget {} → {}",
        fmt_bytes(skew.max_task_mem),
        fmt_bytes(budget),
        if skew.max_task_mem <= budget {
            "fits"
        } else {
            "EXCEEDS BUDGET (dist nodes with --mem-budget would reject)"
        }
    );
    // blocksplit: show the before/after balance against plain §3.2
    // tuning with the same bounds, so the operator sees what the
    // pair-space splitting bought
    if matches!(
        args.str_or("partitioning", "blocking"),
        "blocksplit" | "block-split"
    ) {
        let before = Workflow::for_dataset(&dataset)
            .matching(kind)
            .strategy_boxed(Box::new(BlockingBased {
                method: parse_blocking_method(args)?,
                max_size: Some(
                    args.get_or("max-size", default_max_size(kind))?,
                ),
                min_size: Some(
                    args.get_or("min-size", default_min_size(kind))?,
                ),
            }))
            .env(ce)
            .plan()?;
        let b = before.plan().skew();
        println!(
            "split balance: blocking_based skew {:.2} (max {} pairs, \
             {} tasks) → block_split skew {:.2} (max {} pairs, {} \
             tasks)",
            b.skew_ratio,
            b.max_pairs,
            b.n_tasks,
            skew.skew_ratio,
            skew.max_pairs,
            skew.n_tasks
        );
    }
    let top = args.get_or("top", 5usize)?;
    if top > 0 {
        println!("heaviest tasks:");
        println!("  task   left×right        pairs        memory");
        for (t, pairs, mem) in plan.top_tasks(top) {
            let span = format!("{}×{}", t.left, t.right);
            println!(
                "  {:<6} {:<15} {:>10}  {:>12}",
                t.id,
                span,
                pairs,
                fmt_bytes(mem)
            );
        }
    }
    if let Some(path) = args.get_str("save") {
        std::fs::write(path, plan.to_bytes())?;
        println!("saved plan to {path}");
    }
    println!("(plan only — nothing was executed)");
    Ok(())
}

fn cmd_match(args: &Args) -> Result<()> {
    let kind = parse_strategy(args)?;
    let ce = parse_ce(args)?;
    let (dataset, truth) = load_dataset(args)?;
    // --trace: record every task's lifecycle; dumped + replay-verified
    // after the run (1 Mi events is plenty for any CLI workload)
    let tracer = args
        .get_str("trace")
        .map(|_| pem::obs::Tracer::new(1 << 20));
    let mut wf = Workflow::for_dataset(&dataset)
        .matching(kind)
        .strategy_boxed(parse_partition_strategy(args, kind)?)
        .backend_boxed(parse_backend(args)?)
        .env(ce)
        .cache(args.get_or("cache", 0usize)?)
        .policy(parse_policy(args));
    if let Some(t) = &tracer {
        wf = wf.trace(t.clone());
    }
    let out = wf.run()?;
    println!(
        "partitions={} (misc {})  tasks={}",
        out.n_partitions, out.n_misc_partitions, out.n_tasks
    );
    println!("{}", out.metrics.summary());
    if let (true, Some(truth)) = (out.result.len() > 0, &truth) {
        let q = out.result.quality(truth);
        println!(
            "quality: precision={:.3} recall={:.3} f1={:.3}",
            q.precision, q.recall, q.f1
        );
    }
    if let Some(out_path) = args.get_str("out") {
        pem::io::write_matches(
            out.result.iter(),
            std::fs::File::create(out_path)?,
        )?;
        println!("wrote {} matches to {out_path}", out.result.len());
    }
    if let (Some(path), Some(tracer)) = (args.get_str("trace"), &tracer)
    {
        let events = tracer.events();
        std::fs::write(path, tracer.dump_jsonl())?;
        println!("wrote {} trace events to {path}", events.len());
        // replay-verify against the planned task set (the scheduler
        // records one Planned event per plan task; split children are
        // Queued with a parent, never Planned)
        let planned: Vec<u32> = events
            .iter()
            .filter(|e| e.kind == pem::obs::TraceEventKind::Planned)
            .map(|e| e.task)
            .collect();
        if planned.is_empty() {
            println!(
                "(no lifecycle events recorded — the sim engine does \
                 not trace; use --engine threads|dist)"
            );
        } else {
            match tracer.verify_plan(&planned) {
                Ok(s) => println!(
                    "trace replay: {} plan task(s) completed exactly \
                     once ({} split(s), {} requeue(s))",
                    s.plan_tasks, s.splits, s.requeues
                ),
                Err(e) => eprintln!("trace replay FAILED: {e}"),
            }
        }
    }
    println!("wall-clock: {:?}", out.elapsed);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let kind = parse_strategy(args)?;
    let cores_list: Vec<usize> =
        args.get_list("cores-list", &[1usize, 2, 4, 8, 12, 16])?;
    let data = GeneratorConfig::default()
        .with_entities(args.get_or("entities", 20_000usize)?)
        .with_seed(args.get_or("seed", 2010u64)?)
        .generate();
    // skew report: how much BlockSplit's pair-space splitting
    // rebalances the task list vs plain §3.2 tuning on this dataset
    // (plan-only — costs partitioning, not matching; only relevant —
    // and only paid — when the sweep itself runs a blocking variant)
    if matches!(
        args.str_or("partitioning", "blocking"),
        "blocking" | "blocksplit" | "block-split"
    ) {
        let ce = ComputingEnv::new(1, 4, 3 * GIB);
        let max = args.get_or("max-size", default_max_size(kind))?;
        let min = args.get_or("min-size", default_min_size(kind))?;
        let skew_of =
            |s: Box<dyn PartitionStrategy>| -> Result<pem::coordinator::PlanSkew> {
                Ok(Workflow::for_dataset(&data.dataset)
                    .matching(kind)
                    .strategy_boxed(s)
                    .env(ce)
                    .plan()?
                    .plan()
                    .skew())
            };
        let bb = skew_of(Box::new(BlockingBased {
            method: parse_blocking_method(args)?,
            max_size: Some(max),
            min_size: Some(min),
        }))?;
        let bs = skew_of(Box::new(BlockSplit {
            method: parse_blocking_method(args)?,
            max_size: Some(max),
            min_size: Some(min),
            target_pairs: opt_u64(args, "target-pairs")?,
        }))?;
        println!(
            "task skew (max/mean pairs): blocking {:.2} ({} tasks, \
             max {}) vs blocksplit {:.2} ({} tasks, max {})",
            bb.skew_ratio,
            bb.n_tasks,
            bb.max_pairs,
            bs.skew_ratio,
            bs.n_tasks,
            bs.max_pairs
        );
    }
    let mut times = Vec::new();
    // the speedup column is relative to the first *successful* cell;
    // when an earlier cell failed, say so instead of printing a
    // silently re-based Figs-8/9 column
    let mut baseline_cores: Option<usize> = None;
    let mut failed_cells = 0usize;
    println!("cores  time         speedup  hr     skew   tasks");
    for &cores in &cores_list {
        // 4 cores per node as in the paper; cores beyond one node add nodes
        let nodes = cores.div_ceil(4).max(1);
        let per = cores.div_ceil(nodes);
        let ce = ComputingEnv::new(nodes, per, 3 * GIB);
        // boxed strategies/backends are not Clone: parse per cell
        let strategy = parse_partition_strategy(args, kind)?;
        let backend = parse_backend(args)?;
        let (strategy_name, backend_name) =
            (strategy.name(), backend.name());
        let cell = Workflow::for_dataset(&data.dataset)
            .matching(kind)
            .strategy_boxed(strategy)
            .backend_boxed(backend)
            .env(ce)
            .cache(args.get_or("cache", 0usize)?)
            .policy(parse_policy(args))
            .run();
        let out = match cell {
            Ok(out) => out,
            Err(e) => {
                // one bad cell must not abort the whole sweep — name
                // the failing combination and keep sweeping
                failed_cells += 1;
                eprintln!(
                    "sweep cell failed (cores={cores}, \
                     strategy={strategy_name}, backend={backend_name}, \
                     matching={}): {e:#}",
                    kind.name()
                );
                continue;
            }
        };
        baseline_cores.get_or_insert(cores);
        times.push(out.metrics.makespan_ns);
        let s = speedups(&times);
        // observability columns come from the run's registry snapshot
        // (the same shape `pem stats` scrapes), not ad-hoc fields
        let snap = out.metrics.snapshot();
        println!(
            "{:>5}  {:>11}  {:>6.2}  {:>5.1}%  {:>5.2}  {}",
            cores,
            fmt_nanos(snap.gauge("makespan_ns").unwrap_or(0)),
            s.last().unwrap(),
            snapshot_hit_ratio(&snap) * 100.0,
            snapshot_busy_skew(&snap),
            snap.gauge("tasks").unwrap_or(0),
        );
    }
    if failed_cells == cores_list.len() {
        bail!("every sweep cell failed ({failed_cells})");
    }
    if failed_cells > 0 {
        eprintln!("{failed_cells} sweep cell(s) failed, see above");
    }
    if let Some(base) = baseline_cores {
        if base != cores_list[0] {
            println!(
                "note: speedups are relative to the {base}-core cell \
                 (earlier cells failed)"
            );
        }
    }
    Ok(())
}

/// `pem serve` dispatch: the default coordinator role, or a standalone
/// data-plane replica with `--role data`.
fn cmd_serve(args: &Args) -> Result<()> {
    match args.str_or("role", "coordinator") {
        "coordinator" => cmd_serve_coordinator(args),
        "data" => cmd_serve_data_replica(args),
        other => bail!("bad --role {other:?} (coordinator|data)"),
    }
}

/// Standalone data-plane replica: sync the full partition-frame set
/// from a running data server, optionally announce into the
/// coordinator's replica directory, serve fetches until the upstream
/// goes away, then report per-replica traffic and exit.
fn cmd_serve_data_replica(args: &Args) -> Result<()> {
    use pem::service::{announce_replica, DataServiceServer};
    let upstream = args.get_str("replica-of").ok_or_else(|| {
        anyhow::anyhow!("--replica-of HOST:PORT required with --role data")
    })?;
    // bind loopback unless the operator opts into exposure (the
    // ROADMAP fix: replicas used to bind 0.0.0.0 unconditionally)
    let bind = format!(
        "{}:{}",
        args.str_or("bind", "127.0.0.1"),
        args.get_or("data-port", 0u16)?
    );
    let srv = match parse_hot_budget(args)? {
        // partial replication: hold only the most-demanded frames
        // within the budget; cold misses redirect to the upstream
        Some(budget) => DataServiceServer::start_replica_partial(
            &bind,
            upstream,
            std::time::Duration::from_secs(30),
            budget,
        )?,
        None => DataServiceServer::start_replica(
            &bind,
            upstream,
            std::time::Duration::from_secs(30),
        )?,
    };
    println!("data replica on {} syncing from {upstream}…", srv.addr());
    let sync_timeout = std::time::Duration::from_secs(
        args.get_or("sync-timeout-s", 120u64)?,
    );
    if !srv.wait_synced(sync_timeout) {
        srv.shutdown();
        bail!("sync from {upstream} did not complete in {sync_timeout:?}");
    }
    println!("synced {} partitions", srv.partition_count());
    let advertised = format!(
        "{}:{}",
        args.str_or("advertise", "127.0.0.1"),
        srv.addr().port()
    );
    if let Some(wf) = args.get_str("workflow") {
        let dir = announce_replica(
            wf,
            &advertised,
            &srv.partition_ids(),
            std::time::Duration::from_secs(10),
        )?;
        println!(
            "announced as {advertised} to {wf}; replica directory: {}",
            dir.join(", ")
        );
    }
    // serve until the upstream (and with it the coordinator) goes away
    while !srv.upstream_lost() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    println!(
        "upstream {upstream} gone; served {} payloads / {} — exiting",
        srv.wire_messages(),
        fmt_bytes(srv.wire_bytes())
    );
    srv.shutdown();
    Ok(())
}

/// Start the coordinator half of a multi-process match: generate (or
/// load) the dataset, build the match plan, and serve the workflow +
/// data services (assignments carry the plan's §3.1 footprints) until
/// the task list drains.
fn cmd_serve_coordinator(args: &Args) -> Result<()> {
    use pem::service::{
        announce_replica, DataServiceServer, WorkflowServerConfig,
        WorkflowServiceServer,
    };
    let kind = parse_strategy(args)?;
    let ce = parse_ce(args)?;
    let policy = parse_policy(args);
    let resident = args.flag("resident");
    let (dataset, truth) = load_dataset(args)?;
    // resident mode shares the dataset with the tenant table, which
    // validates submitted plans' provenance against it
    let dataset = std::sync::Arc::new(dataset);
    let planned = Workflow::for_dataset(&dataset)
        .matching(kind)
        .strategy_boxed(parse_partition_strategy(args, kind)?)
        .env(ce)
        .plan()?;
    let plan = planned.into_plan();
    let tasks = plan.tasks.clone();
    let task_mem: std::collections::HashMap<u32, u64> = plan
        .tasks
        .iter()
        .zip(plan.task_mem.iter())
        .map(|(t, &m)| (t.id, m))
        .collect();
    let task_sizes = plan.task_sizes();
    let store_kind = parse_store(args)?;
    let store = std::sync::Arc::new(
        pem::store::DataService::build_with(
            &dataset,
            &plan.partitions,
            store_kind
                .open()
                .context("opening the partition store")?,
        )
        .context("loading partitions into the store")?,
    );
    println!(
        "dataset: {} entities → {} partitions (misc {}) → {} tasks",
        dataset.len(),
        plan.n_partitions(),
        plan.n_misc_partitions(),
        plan.n_tasks()
    );
    if let pem::store::StoreKind::Spill { budget, dir } = &store_kind {
        let stats = store.store_stats();
        println!(
            "partition store: spill (hot budget {}, {} on disk{})",
            fmt_bytes(*budget),
            fmt_bytes(stats.spill_bytes),
            dir.as_deref()
                .map(|d| format!(" in {}", d.display()))
                .unwrap_or_default()
        );
    }

    // bind loopback unless the operator opts in with --bind (the
    // ROADMAP fix: the coordinator used to bind 0.0.0.0
    // unconditionally, exposing an unauthenticated control plane on
    // every interface)
    let bind_host = args.str_or("bind", "127.0.0.1");
    let data_bind =
        format!("{bind_host}:{}", args.get_or("data-port", 0u16)?);
    let wf_bind =
        format!("{bind_host}:{}", args.get_or("workflow-port", 0u16)?);
    let data_srv = DataServiceServer::start(store.clone(), &data_bind)?;
    // --trace: the scheduler records every assignment / rejection /
    // split / completion; dumped as JSONL when the workflow drains
    let tracer = args.get_str("trace").map(|_| {
        pem::obs::Tracer::new(pem::obs::DEFAULT_TRACE_CAPACITY)
    });
    let wf_srv = WorkflowServiceServer::start(
        tasks,
        WorkflowServerConfig {
            policy,
            heartbeat_timeout: std::time::Duration::from_millis(
                args.get_or("heartbeat-ms", 2000u64)?,
            ),
            task_mem,
            task_sizes,
            expected_services: args.get_or("expect-nodes", 1usize)?,
            tracer: tracer.clone(),
            tenancy: if resident {
                Some(pem::service::TenantHostConfig {
                    dataset: dataset.clone(),
                    store: store.clone(),
                    per_tenant_inflight: opt_usize(
                        args,
                        "tenant-inflight",
                    )?,
                })
            } else {
                None
            },
        },
        &wf_bind,
    )?;
    println!("workflow service listening on {}", wf_srv.addr());
    println!("data service listening on {}", data_srv.addr());
    // register the primary in the replica directory so joining nodes
    // and later `pem serve --role data` replicas discover it; the
    // announced host must be reachable by the nodes (`--advertise`)
    let advertise = args.str_or("advertise", "127.0.0.1");
    let primary_addr =
        format!("{advertise}:{}", data_srv.addr().port());
    // self-announce over a host we can actually reach: loopback when
    // bound to loopback or every interface, the bound host otherwise
    let self_host = if bind_host == "0.0.0.0" {
        "127.0.0.1"
    } else {
        bind_host
    };
    announce_replica(
        &format!("{self_host}:{}", wf_srv.addr().port()),
        &primary_addr,
        &data_srv.partition_ids(),
        std::time::Duration::from_secs(10),
    )?;
    println!(
        "attach data replicas with: pem serve --role data \
         --replica-of {primary_addr} --workflow {advertise}:{}",
        wf_srv.addr().port()
    );
    println!(
        "attach nodes with: pem distmatch --workflow {advertise}:{} \
         --data {primary_addr} --strategy {}",
        wf_srv.addr().port(),
        kind.name()
    );

    let started = pem::obs::Stopwatch::start();
    let timeout = std::time::Duration::from_secs(
        args.get_or("timeout-s", 3600u64)?,
    );
    if resident {
        // a resident coordinator has no natural "done": nodes stay
        // attached between submitted plans, so serve until the
        // operator's --timeout-s budget elapses (or the process is
        // killed), then tear down and report
        println!(
            "resident mode: accepting plan submissions for \
             {timeout:?} — pem submit plan.bin --to {advertise}:{}",
            wf_srv.addr().port()
        );
        std::thread::sleep(timeout);
        // parting snapshot: the same tenant table `pem stats` shows,
        // so the operator sees what every submitted plan ended as
        if let Ok(snap) = scrape_stats(
            &format!("{self_host}:{}", wf_srv.addr().port()),
            std::time::Duration::from_secs(5),
        ) {
            print_stats("self", &snap, args.flag("json"));
        }
    } else {
        match wf_srv.wait_outcome(timeout) {
            pem::service::WaitStatus::Done => {}
            pem::service::WaitStatus::Misfit(misfit) => {
                // the §3.1 fail-fast: tell the operator *now* instead
                // of idling until --timeout-s
                data_srv.shutdown();
                return Err(anyhow::Error::new(misfit).context(
                    "workflow failed fast (§3.1 memory model): add \
                     roomier nodes or re-plan with a smaller --max-size",
                ));
            }
            pem::service::WaitStatus::Timeout => {
                data_srv.shutdown();
                bail!(
                    "timed out after {timeout:?} with {} tasks complete",
                    wf_srv.completed()
                );
            }
        }
        // grace period: let the nodes observe `done` and leave cleanly
        std::thread::sleep(std::time::Duration::from_millis(250));
    }
    let elapsed = started.elapsed();
    let report = wf_srv.finish();
    let mut result = pem::model::MatchResult::new();
    for c in report.correspondences {
        result.add(c);
    }
    println!(
        "completed {}/{} tasks on {} service(s): {} comparisons, {} matches",
        report.completed_tasks,
        report.total_tasks,
        report.services_joined,
        report.comparisons,
        result.len()
    );
    println!(
        "control plane: {} messages / {}; data plane (primary): {} \
         payloads / {}; requeued {} task(s), {} stale completion(s)",
        report.control_messages,
        fmt_bytes(report.control_wire_bytes),
        data_srv.wire_messages(),
        fmt_bytes(data_srv.wire_bytes()),
        report.requeued_tasks,
        report.stale_completions
    );
    if report.oversize_rejections > 0 {
        println!(
            "memory model: {} oversize rejection(s) re-routed to \
             roomier nodes",
            report.oversize_rejections
        );
    }
    if report.runtime_splits > 0 {
        println!(
            "memory model: {} task(s) split at run time into \
             budget-fitting sub-tasks (results merged exactly once)",
            report.runtime_splits
        );
    }
    if report.batch_requests > 0 {
        // assignment_pulls also counts classic (batch = 1) TaskRequest
        // frames, so the two counters are reported side by side rather
        // than as a subset
        println!(
            "batched assignment: {} batch pull(s); {} pull(s) across \
             all nodes carried no completion report",
            report.batch_requests, report.assignment_pulls
        );
    }
    if report.data_replicas.len() > 1 {
        println!(
            "replica directory: {} (remote replicas report their own \
             wire traffic on exit)",
            report.data_replicas.join(", ")
        );
    }
    if report.version_rejections > 0 {
        println!(
            "rejected {} peer(s) for protocol-version mismatch",
            report.version_rejections
        );
    }
    if let Some(truth) = &truth {
        let q = result.quality(truth);
        println!(
            "quality: precision={:.3} recall={:.3} f1={:.3}",
            q.precision, q.recall, q.f1
        );
    }
    if let Some(out_path) = args.get_str("out") {
        pem::io::write_matches(
            result.iter(),
            std::fs::File::create(out_path)?,
        )?;
        println!("wrote {} matches to {out_path}", result.len());
    }
    if let (Some(path), Some(tracer)) = (args.get_str("trace"), &tracer)
    {
        std::fs::write(path, tracer.dump_jsonl())?;
        println!("wrote {} trace events to {path}", tracer.len());
    }
    println!("match wall-clock: {elapsed:?}");
    data_srv.shutdown();
    Ok(())
}

/// Run one match-service node against a `pem serve` coordinator.
fn cmd_distmatch(args: &Args) -> Result<()> {
    use pem::service::{run_match_node, MatchNodeConfig};
    let kind = parse_strategy(args)?;
    let workflow = args
        .get_str("workflow")
        .ok_or_else(|| anyhow::anyhow!("--workflow HOST:PORT required"))?;
    let data = args.get_str("data").ok_or_else(|| {
        anyhow::anyhow!("--data HOST:PORT[,HOST:PORT...] required")
    })?;
    let mut data_addrs = data
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty());
    let first = data_addrs.next().ok_or_else(|| {
        anyhow::anyhow!("--data needs at least one HOST:PORT")
    })?;
    let mut cfg = MatchNodeConfig::new(workflow.to_string(), first);
    cfg.data_addrs.extend(data_addrs);
    cfg.name = args.str_or("name", "distmatch").to_string();
    cfg.threads = args.get_or("threads", 4usize)?;
    cfg.cache_capacity = args.get_or("cache", 0usize)?;
    cfg.batch = args.get_or("batch", 1usize)?.max(1);
    cfg.task_memory_budget = parse_mem_budget(args)?;
    let exec: std::sync::Arc<dyn pem::worker::TaskExecutor> =
        std::sync::Arc::new(pem::worker::RustExecutor::new(
            MatchStrategy::new(kind),
        ));
    println!(
        "node {:?}: joining workflow service {workflow}, data replicas \
         [{}], {} thread(s), cache {}",
        cfg.name,
        cfg.data_addrs.join(", "),
        cfg.threads,
        cfg.cache_capacity
    );
    let report = run_match_node(&cfg, exec)?;
    let accesses = report.cache_hits + report.cache_misses;
    println!(
        "service #{}: completed {} tasks, {} comparisons, cache hr {:.0}%{}",
        report.service,
        report.tasks_completed,
        report.comparisons,
        if accesses == 0 {
            0.0
        } else {
            100.0 * report.cache_hits as f64 / accesses as f64
        },
        if report.lost_coordinator {
            " (coordinator went away)"
        } else {
            ""
        }
    );
    if report.tasks_rejected > 0 {
        println!(
            "rejected {} oversize task(s) (budget {})",
            report.tasks_rejected,
            cfg.task_memory_budget
                .map(fmt_bytes)
                .unwrap_or_else(|| "-".into())
        );
    }
    println!(
        "fetches per data replica: [{}]{}{}",
        report
            .fetches_per_replica
            .iter()
            .map(|n| n.to_string())
            .collect::<Vec<_>>()
            .join(", "),
        if report.replica_failovers > 0 {
            format!(" ({} replica failover(s))", report.replica_failovers)
        } else {
            String::new()
        },
        if report.replica_readmissions > 0 {
            format!(
                " ({} replica(s) re-admitted after cooldown)",
                report.replica_readmissions
            )
        } else {
            String::new()
        }
    );
    Ok(())
}

/// `pem submit plan.bin --to HOST:PORT`: submit a saved match plan
/// (`pem plan --save`) to a *resident* coordinator (protocol v7) and
/// follow it to its terminal state.  An over-budget plan is refused
/// in one round trip with the typed §3.1 admission verdict.
fn cmd_submit(args: &Args) -> Result<()> {
    use pem::rpc::{Message, Transport};
    use pem::service::{
        AdmissionDenied, TENANT_ABORTED, TENANT_DONE, TENANT_FAILED,
    };
    let path = args.positional().get(1).cloned().ok_or_else(|| {
        anyhow::anyhow!("usage: pem submit plan.bin --to HOST:PORT")
    })?;
    let to = args
        .get_str("to")
        .ok_or_else(|| anyhow::anyhow!("--to HOST:PORT required"))?;
    let name = args.str_or("name", path.as_str()).to_string();
    let plan_bytes = std::fs::read(&path)?;
    let timeout = std::time::Duration::from_secs(
        args.get_or("timeout-s", 600u64)?,
    );
    let poll = std::time::Duration::from_millis(
        args.get_or("poll-ms", 200u64)?,
    );
    let mut t =
        Transport::connect(to, std::time::Duration::from_secs(5))?;
    let plan_id = match t.request(&Message::PlanSubmit {
        name: name.clone(),
        plan: plan_bytes,
    })? {
        Message::PlanAccepted { plan } => plan,
        Message::PlanRejected {
            required,
            available,
            reason,
        } => {
            if required > 0 {
                // the typed admission verdict: scripts can downcast
                // to `AdmissionDenied` for the exact byte numbers
                return Err(anyhow::Error::new(AdmissionDenied {
                    required,
                    available,
                })
                .context(format!("plan {name:?} refused by {to}")));
            }
            bail!("plan {name:?} refused by {to}: {reason}");
        }
        other => bail!("unexpected reply: {}", other.kind()),
    };
    println!("plan {name:?} admitted by {to} as plan #{plan_id}");
    let started = pem::obs::Stopwatch::start();
    loop {
        if started.elapsed() > timeout {
            bail!(
                "gave up following plan #{plan_id} after {timeout:?} \
                 (it keeps running server-side; poll with pem stats)"
            );
        }
        match t.request(&Message::PlanStatus { plan: plan_id })? {
            Message::PlanStatusReport {
                completed, total, ..
            } => {
                println!("plan #{plan_id}: {completed}/{total} tasks");
            }
            Message::PlanResult {
                state,
                comparisons,
                matches,
                detail,
                ..
            } => {
                return match state {
                    TENANT_DONE => {
                        println!(
                            "plan #{plan_id} done: {comparisons} \
                             comparisons, {} matches",
                            matches.len()
                        );
                        if let Some(out_path) = args.get_str("out") {
                            pem::io::write_matches(
                                matches.iter(),
                                std::fs::File::create(out_path)?,
                            )?;
                            println!(
                                "wrote {} matches to {out_path}",
                                matches.len()
                            );
                        }
                        Ok(())
                    }
                    TENANT_ABORTED => {
                        bail!("plan #{plan_id} aborted: {detail}")
                    }
                    TENANT_FAILED => {
                        bail!("plan #{plan_id} failed: {detail}")
                    }
                    other => bail!(
                        "plan #{plan_id}: unknown terminal state {other}"
                    ),
                };
            }
            Message::Error { message } => {
                bail!("coordinator refused the status poll: {message}")
            }
            other => bail!("unexpected reply: {}", other.kind()),
        }
        std::thread::sleep(poll);
    }
}

/// Human name of a `tenant.{id}.state` gauge value.
fn tenant_state_name(state: u64) -> &'static str {
    use pem::service::{
        TENANT_ABORTED, TENANT_DONE, TENANT_FAILED, TENANT_RUNNING,
    };
    match state {
        s if s == TENANT_RUNNING as u64 => "running",
        s if s == TENANT_DONE as u64 => "done",
        s if s == TENANT_ABORTED as u64 => "aborted",
        s if s == TENANT_FAILED as u64 => "failed",
        _ => "?",
    }
}

/// The paper's cache hit ratio `hr` from a snapshot's raw counters.
fn snapshot_hit_ratio(snap: &pem::obs::MetricsSnapshot) -> f64 {
    let hits = snap.counter("cache_hits").unwrap_or(0);
    let misses = snap.counter("cache_misses").unwrap_or(0);
    let total = hits + misses;
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// Max/mean busy-time skew across the `thread.{i}.busy_ns` gauges of
/// a run snapshot (1.0 = perfectly balanced).
fn snapshot_busy_skew(snap: &pem::obs::MetricsSnapshot) -> f64 {
    let mut busy: Vec<u64> = Vec::new();
    while let Some(b) =
        snap.gauge(&format!("thread.{}.busy_ns", busy.len()))
    {
        busy.push(b);
    }
    if busy.is_empty() {
        return 1.0;
    }
    let max = *busy.iter().max().unwrap() as f64;
    let mean = busy.iter().sum::<u64>() as f64 / busy.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// One `StatsRequest` round trip against a running service.
fn scrape_stats(
    addr: &str,
    timeout: std::time::Duration,
) -> Result<pem::obs::MetricsSnapshot> {
    use pem::rpc::{Message, Transport};
    let mut t = Transport::connect(addr, timeout)?;
    match t.request(&Message::StatsRequest)? {
        Message::StatsReport { stats } => {
            Ok(pem::obs::MetricsSnapshot::from_bytes(&stats)?)
        }
        other => {
            bail!("unexpected reply from {addr}: {}", other.kind())
        }
    }
}

/// Render one scraped snapshot: labels, gauges, counters, histogram
/// summaries, then the derived ratios operators actually ask for.
fn print_stats(addr: &str, snap: &pem::obs::MetricsSnapshot, json: bool) {
    if json {
        println!("{}", snap.to_json());
        return;
    }
    let role = snap.label("role").unwrap_or("?");
    println!("── {role} @ {addr} ──");
    for (k, v) in &snap.labels {
        if k != "role" {
            println!("  {k} = {v}");
        }
    }
    if !snap.gauges.is_empty() {
        println!("  gauges:");
        for (k, v) in &snap.gauges {
            if k.starts_with("tenant.") {
                // rendered as the derived per-plan table below
                continue;
            }
            if k.ends_with("_ns") {
                println!("    {k:<28} {}", fmt_nanos(*v));
            } else if k.ends_with("bytes") {
                println!("    {k:<28} {}", fmt_bytes(*v));
            } else {
                println!("    {k:<28} {v}");
            }
        }
    }
    if !snap.counters.is_empty() {
        println!("  counters:");
        for (k, v) in &snap.counters {
            if k.ends_with("bytes") {
                println!("    {k:<28} {}", fmt_bytes(*v));
            } else {
                println!("    {k:<28} {v}");
            }
        }
    }
    for (k, h) in &snap.histograms {
        println!("  histogram {k}: {}", h.summary());
    }
    if snap.counter("cache_hits").is_some() {
        println!(
            "  derived: cache hr {:.1}%",
            snapshot_hit_ratio(snap) * 100.0
        );
    }
    // resident coordinator (protocol v7): one row per submitted plan
    // — plan ids are dense from 1, and terminal tenants stay in the
    // table, so walking until the first gap covers them all
    if let Some(active) = snap.gauge("tenants_active").filter(|&a| {
        a > 0
            || snap
                .gauge(&pem::obs::tenant_gauge(1, "state"))
                .is_some()
    }) {
        println!("  tenants ({active} running):");
        let mut id = 1u32;
        while let Some(state) =
            snap.gauge(&pem::obs::tenant_gauge(id, "state"))
        {
            println!(
                "    plan #{id}: {:<8} {}/{} tasks",
                tenant_state_name(state),
                snap.gauge(&pem::obs::tenant_gauge(id, "tasks_completed"))
                    .unwrap_or(0),
                snap.gauge(&pem::obs::tenant_gauge(id, "tasks_total"))
                    .unwrap_or(0)
            );
            id += 1;
        }
    }
}

/// `pem stats <addr>`: scrape the live metrics of a RUNNING cluster
/// over the wire (protocol v6 `StatsRequest`).  A workflow service's
/// reply carries the replica directory as a label, so the data
/// servers are scraped in the same invocation unless `--no-follow`.
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args.positional().get(1).cloned().ok_or_else(|| {
        anyhow::anyhow!("usage: pem stats HOST:PORT [--no-follow]")
    })?;
    let timeout = std::time::Duration::from_secs(
        args.get_or("timeout-s", 5u64)?,
    );
    let json = args.flag("json");
    let snap = scrape_stats(&addr, timeout)?;
    print_stats(&addr, &snap, json);
    if !args.flag("no-follow") {
        if let Some(dir) = snap.label("data_replicas") {
            for d in dir.split(',').filter(|s| !s.is_empty()) {
                match scrape_stats(d, timeout) {
                    Ok(s) => print_stats(d, &s, json),
                    Err(e) => eprintln!(
                        "scrape of data server {d} failed: {e:#}"
                    ),
                }
            }
        }
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = pem::runtime::default_artifact_dir();
    let manifest = pem::runtime::Manifest::load(&dir)?;
    println!("artifact dir: {}", dir.display());
    for e in &manifest.entries {
        println!(
            "  {:<28} strategy={} capacity={} dim={}",
            e.name,
            e.strategy.name(),
            e.capacity,
            e.feature_dim
        );
    }
    if args.flag("smoke") {
        use pem::worker::TaskExecutor;
        let data = GeneratorConfig::tiny().with_entities(120).generate();
        let ids: Vec<pem::model::EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = pem::partition::partition_size_based(&ids, 60);
        let store = pem::store::DataService::build(&data.dataset, &parts);
        let engine =
            std::sync::Arc::new(pem::runtime::MatchEngine::new(&dir)?);
        let kind = parse_strategy(args)?;
        let exec = pem::runtime::PjrtExecutor::new(
            engine,
            MatchStrategy::new(kind),
        );
        let p0 = store.fetch(pem::partition::PartitionId(0))?;
        let found = exec.execute(&p0, &p0, true);
        println!(
            "smoke: matched partition of {} with itself → {} correspondences",
            p0.len(),
            found.len()
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let ce = parse_ce(args)?;
    println!(
        "CE = ({} nodes, {} cores, {})  threads/node={}",
        ce.nodes,
        ce.cores_per_node,
        fmt_bytes(ce.max_mem),
        ce.threads_per_node
    );
    println!("mem per thread: {}", fmt_bytes(ce.mem_per_thread()));
    for kind in [StrategyKind::Wam, StrategyKind::Lrm] {
        println!(
            "{}: c_ms={} B/pair → max partition size m={}",
            kind.name(),
            kind.memory_per_pair(),
            max_partition_size(&ce, kind)
        );
    }
    Ok(())
}
