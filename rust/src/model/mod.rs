//! Entity model: entities, schemas, datasets, correspondences.
//!
//! Mirrors the paper's preliminaries (§2): entities are attribute records
//! (product name, description, manufacturer, product type, …); entity
//! matching produces correspondences `(e1, e2, sim)` with `sim ∈ [0, 1]`,
//! and all pairs above a threshold are considered matches.

use std::collections::HashMap;
use std::fmt;

/// Stable identifier of an entity inside a [`Dataset`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct EntityId(pub u32);

impl fmt::Display for EntityId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "e{}", self.0)
    }
}

/// Identifier of an input source (paper §3.3 matches multiple sources).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, Default)]
pub struct SourceId(pub u16);

/// Attribute names used by the product schema.  The generator fills all
/// 23 attributes of the paper's product-offer dataset; matching uses the
/// well-known ones via the typed accessors below.
pub const ATTR_TITLE: &str = "title";
pub const ATTR_DESCRIPTION: &str = "description";
pub const ATTR_MANUFACTURER: &str = "manufacturer";
pub const ATTR_PRODUCT_TYPE: &str = "product_type";

/// A schema is an ordered list of attribute names; entities store values
/// positionally so the per-entity footprint stays small.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schema {
    attributes: Vec<String>,
    index: HashMap<String, usize>,
}

impl Schema {
    pub fn new<S: Into<String>>(attributes: Vec<S>) -> Schema {
        let attributes: Vec<String> =
            attributes.into_iter().map(Into::into).collect();
        let index = attributes
            .iter()
            .enumerate()
            .map(|(i, a)| (a.clone(), i))
            .collect();
        Schema { attributes, index }
    }

    /// The 23-attribute product-offer schema of the evaluation dataset.
    pub fn product_offers() -> Schema {
        Schema::new(vec![
            ATTR_TITLE,
            ATTR_DESCRIPTION,
            ATTR_MANUFACTURER,
            ATTR_PRODUCT_TYPE,
            "ean",
            "sku",
            "model_number",
            "price",
            "currency",
            "availability",
            "shop_name",
            "shop_url",
            "category_path",
            "color",
            "weight_g",
            "width_mm",
            "height_mm",
            "depth_mm",
            "warranty_months",
            "energy_label",
            "release_year",
            "rating",
            "delivery_days",
        ])
    }

    pub fn len(&self) -> usize {
        self.attributes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.attributes.is_empty()
    }

    pub fn position(&self, attribute: &str) -> Option<usize> {
        self.index.get(attribute).copied()
    }

    pub fn attributes(&self) -> &[String] {
        &self.attributes
    }
}

/// An entity: a record of optional attribute values (missing values are
/// what sends entities to the *misc* block during blocking).
#[derive(Clone, Debug, PartialEq)]
pub struct Entity {
    pub id: EntityId,
    pub source: SourceId,
    values: Vec<Option<String>>,
}

impl Entity {
    pub fn new(id: EntityId, schema: &Schema) -> Entity {
        Entity {
            id,
            source: SourceId::default(),
            values: vec![None; schema.len()],
        }
    }

    pub fn set(&mut self, schema: &Schema, attribute: &str, value: String) {
        let pos = schema
            .position(attribute)
            .unwrap_or_else(|| panic!("unknown attribute {attribute:?}"));
        self.values[pos] = Some(value);
    }

    pub fn get<'a>(&'a self, schema: &Schema, attribute: &str) -> Option<&'a str> {
        schema
            .position(attribute)?
            .checked_sub(0)
            .and_then(|pos| self.values.get(pos))
            .and_then(|v| v.as_deref())
    }

    pub fn title<'a>(&'a self, schema: &Schema) -> &'a str {
        self.get(schema, ATTR_TITLE).unwrap_or("")
    }

    pub fn description<'a>(&'a self, schema: &Schema) -> &'a str {
        self.get(schema, ATTR_DESCRIPTION).unwrap_or("")
    }

    pub fn manufacturer<'a>(&'a self, schema: &Schema) -> Option<&'a str> {
        self.get(schema, ATTR_MANUFACTURER)
    }

    pub fn product_type<'a>(&'a self, schema: &Schema) -> Option<&'a str> {
        self.get(schema, ATTR_PRODUCT_TYPE)
    }

    /// Approximate in-memory footprint in bytes (drives the data-service
    /// transfer cost model).
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Entity>()
            + self
                .values
                .iter()
                .map(|v| v.as_ref().map_or(0, |s| s.len() + 24))
                .sum::<usize>()
    }
}

/// A dataset: schema + entities from one or more sources.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub schema: Schema,
    pub entities: Vec<Entity>,
}

impl Dataset {
    pub fn new(schema: Schema) -> Dataset {
        Dataset {
            schema,
            entities: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.entities.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entities.is_empty()
    }

    pub fn push(&mut self, entity: Entity) {
        self.entities.push(entity);
    }

    pub fn get(&self, id: EntityId) -> Option<&Entity> {
        // ids are dense indices in generated datasets; fall back to scan.
        match self.entities.get(id.0 as usize) {
            Some(e) if e.id == id => Some(e),
            _ => self.entities.iter().find(|e| e.id == id),
        }
    }

    /// Union of several datasets (paper §3.3): entities are re-tagged
    /// with their source and re-identified to stay unique.
    pub fn union(sources: Vec<Dataset>) -> Dataset {
        assert!(!sources.is_empty());
        let schema = sources[0].schema.clone();
        for s in &sources {
            assert_eq!(
                s.schema, schema,
                "union requires aligned schemas (run schema matching first)"
            );
        }
        let mut out = Dataset::new(schema);
        let mut next = 0u32;
        for (si, src) in sources.into_iter().enumerate() {
            for mut e in src.entities {
                e.id = EntityId(next);
                e.source = SourceId(si as u16);
                next += 1;
                out.entities.push(e);
            }
        }
        out
    }

    pub fn approx_bytes(&self) -> usize {
        self.entities.iter().map(Entity::approx_bytes).sum()
    }
}

/// A correspondence: two entities believed to refer to the same real-world
/// object, with their combined similarity.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Correspondence {
    pub e1: EntityId,
    pub e2: EntityId,
    pub sim: f32,
}

impl Correspondence {
    /// Normalized so `e1 < e2` — correspondences are unordered pairs.
    pub fn new(a: EntityId, b: EntityId, sim: f32) -> Correspondence {
        assert_ne!(a, b, "self-correspondence");
        let (e1, e2) = if a < b { (a, b) } else { (b, a) };
        Correspondence { e1, e2, sim }
    }

    pub fn pair(&self) -> (EntityId, EntityId) {
        (self.e1, self.e2)
    }
}

/// The merged match result: deduplicated correspondences (max similarity
/// wins when the same pair is reported by several match tasks, which can
/// happen for pairs co-located in aggregated blocks *and* the misc task).
#[derive(Clone, Debug, Default)]
pub struct MatchResult {
    by_pair: HashMap<(EntityId, EntityId), f32>,
}

impl MatchResult {
    pub fn new() -> MatchResult {
        MatchResult::default()
    }

    pub fn add(&mut self, c: Correspondence) {
        let entry = self.by_pair.entry(c.pair()).or_insert(c.sim);
        if c.sim > *entry {
            *entry = c.sim;
        }
    }

    pub fn merge(&mut self, other: MatchResult) {
        for ((e1, e2), sim) in other.by_pair {
            self.add(Correspondence { e1, e2, sim });
        }
    }

    pub fn len(&self) -> usize {
        self.by_pair.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_pair.is_empty()
    }

    pub fn contains(&self, a: EntityId, b: EntityId) -> bool {
        let key = if a < b { (a, b) } else { (b, a) };
        self.by_pair.contains_key(&key)
    }

    pub fn similarity(&self, a: EntityId, b: EntityId) -> Option<f32> {
        let key = if a < b { (a, b) } else { (b, a) };
        self.by_pair.get(&key).copied()
    }

    pub fn iter(&self) -> impl Iterator<Item = Correspondence> + '_ {
        self.by_pair
            .iter()
            .map(|(&(e1, e2), &sim)| Correspondence { e1, e2, sim })
    }

    /// Precision/recall/F1 against a ground-truth pair set.
    pub fn quality(&self, truth: &[(EntityId, EntityId)]) -> Quality {
        let truth_set: std::collections::HashSet<(EntityId, EntityId)> = truth
            .iter()
            .map(|&(a, b)| if a < b { (a, b) } else { (b, a) })
            .collect();
        let tp = self
            .by_pair
            .keys()
            .filter(|k| truth_set.contains(k))
            .count();
        let precision = if self.len() == 0 {
            0.0
        } else {
            tp as f64 / self.len() as f64
        };
        let recall = if truth_set.is_empty() {
            0.0
        } else {
            tp as f64 / truth_set.len() as f64
        };
        let f1 = if precision + recall == 0.0 {
            0.0
        } else {
            2.0 * precision * recall / (precision + recall)
        };
        Quality {
            true_positives: tp,
            predicted: self.len(),
            actual: truth_set.len(),
            precision,
            recall,
            f1,
        }
    }
}

/// Match quality against ground truth.
#[derive(Clone, Copy, Debug)]
pub struct Quality {
    pub true_positives: usize,
    pub predicted: usize,
    pub actual: usize,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_schema() -> Schema {
        Schema::new(vec![ATTR_TITLE, ATTR_MANUFACTURER, ATTR_PRODUCT_TYPE])
    }

    #[test]
    fn schema_positions() {
        let s = small_schema();
        assert_eq!(s.position(ATTR_TITLE), Some(0));
        assert_eq!(s.position(ATTR_PRODUCT_TYPE), Some(2));
        assert_eq!(s.position("nope"), None);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn product_schema_has_23_attributes() {
        assert_eq!(Schema::product_offers().len(), 23);
    }

    #[test]
    fn entity_set_get() {
        let s = small_schema();
        let mut e = Entity::new(EntityId(0), &s);
        e.set(&s, ATTR_TITLE, "LG GH22NS50".into());
        assert_eq!(e.title(&s), "LG GH22NS50");
        assert_eq!(e.manufacturer(&s), None);
        assert_eq!(e.product_type(&s), None);
    }

    #[test]
    #[should_panic]
    fn entity_set_unknown_attribute_panics() {
        let s = small_schema();
        let mut e = Entity::new(EntityId(0), &s);
        e.set(&s, "bogus", "x".into());
    }

    #[test]
    fn correspondence_normalizes_order() {
        let c = Correspondence::new(EntityId(5), EntityId(2), 0.9);
        assert_eq!(c.pair(), (EntityId(2), EntityId(5)));
    }

    #[test]
    #[should_panic]
    fn self_correspondence_panics() {
        Correspondence::new(EntityId(1), EntityId(1), 1.0);
    }

    #[test]
    fn match_result_dedupes_max_sim() {
        let mut r = MatchResult::new();
        r.add(Correspondence::new(EntityId(1), EntityId(2), 0.8));
        r.add(Correspondence::new(EntityId(2), EntityId(1), 0.9));
        r.add(Correspondence::new(EntityId(1), EntityId(2), 0.7));
        assert_eq!(r.len(), 1);
        assert_eq!(r.similarity(EntityId(1), EntityId(2)), Some(0.9));
    }

    #[test]
    fn match_result_merge() {
        let mut a = MatchResult::new();
        a.add(Correspondence::new(EntityId(1), EntityId(2), 0.8));
        let mut b = MatchResult::new();
        b.add(Correspondence::new(EntityId(3), EntityId(4), 0.85));
        b.add(Correspondence::new(EntityId(1), EntityId(2), 0.95));
        a.merge(b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.similarity(EntityId(2), EntityId(1)), Some(0.95));
    }

    #[test]
    fn quality_metrics() {
        let mut r = MatchResult::new();
        r.add(Correspondence::new(EntityId(1), EntityId(2), 0.9)); // tp
        r.add(Correspondence::new(EntityId(3), EntityId(4), 0.9)); // fp
        let truth = vec![
            (EntityId(2), EntityId(1)),
            (EntityId(5), EntityId(6)), // fn
        ];
        let q = r.quality(&truth);
        assert_eq!(q.true_positives, 1);
        assert!((q.precision - 0.5).abs() < 1e-12);
        assert!((q.recall - 0.5).abs() < 1e-12);
        assert!((q.f1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn union_retags_sources_and_ids() {
        let s = small_schema();
        let mut d1 = Dataset::new(s.clone());
        let mut d2 = Dataset::new(s.clone());
        for i in 0..3 {
            d1.push(Entity::new(EntityId(i), &s));
            d2.push(Entity::new(EntityId(i), &s));
        }
        let u = Dataset::union(vec![d1, d2]);
        assert_eq!(u.len(), 6);
        let ids: Vec<u32> = u.entities.iter().map(|e| e.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(u.entities[0].source, SourceId(0));
        assert_eq!(u.entities[5].source, SourceId(1));
    }

    #[test]
    fn dataset_get_by_id() {
        let s = small_schema();
        let mut d = Dataset::new(s.clone());
        for i in 0..5 {
            d.push(Entity::new(EntityId(i), &s));
        }
        assert_eq!(d.get(EntityId(3)).unwrap().id, EntityId(3));
        assert!(d.get(EntityId(99)).is_none());
    }

    #[test]
    fn approx_bytes_grows_with_content() {
        let s = small_schema();
        let mut e1 = Entity::new(EntityId(0), &s);
        let mut e2 = Entity::new(EntityId(1), &s);
        e1.set(&s, ATTR_TITLE, "x".into());
        e2.set(&s, ATTR_TITLE, "a much longer product title".into());
        assert!(e2.approx_bytes() > e1.approx_bytes());
    }
}
