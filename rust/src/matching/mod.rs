//! Matchers and match strategies (paper §2, §5.1).
//!
//! A *matcher* computes one similarity for an entity pair (edit distance
//! on the title, TriGram on the description, …).  A *match strategy*
//! combines several matchers into one decision:
//!
//! * [`StrategyKind::Wam`] — weighted average of edit-distance(title) and
//!   TriGram(description), with the paper's threshold-discard memory
//!   optimization;
//! * [`StrategyKind::Lrm`] — logistic regression over Jaccard(title),
//!   TriGram(description) and Cosine(title‖description), trainable via
//!   [`train`].
//!
//! Strategies also expose their **memory model** `c_ms` (bytes per entity
//! pair), which drives the memory-restricted partition sizing of §3.1.

pub mod editdist;
pub mod strategy;
pub mod train;

pub use strategy::{MatchStrategy, StrategyKind, StrategyParams};

use crate::features::{EntityFeatures, QGramSet, TokenSet};

/// TriGram similarity (Dice coefficient over q-gram multisets):
/// `2·|A∩B| / (|A| + |B|)`.
pub fn trigram_dice(a: &QGramSet, b: &QGramSet) -> f64 {
    let denom = a.len() + b.len();
    if denom == 0 {
        return 0.0;
    }
    2.0 * a.intersection_size(b) as f64 / denom as f64
}

/// Jaccard similarity over token sets: `|A∩B| / |A∪B|`.
pub fn jaccard(a: &TokenSet, b: &TokenSet) -> f64 {
    let inter = a.intersection_size(b);
    let union = a.len() + b.len() - inter;
    if union == 0 {
        return 0.0;
    }
    inter as f64 / union as f64
}

/// Cosine similarity over q-gram multisets (counts as the vector) —
/// exact, via sparse count vectors.
pub fn cosine(a: &QGramSet, b: &QGramSet) -> f64 {
    let (sa, sb) = (a.to_sparse(), b.to_sparse());
    let denom = (sa.normsq as f64).sqrt() * (sb.normsq as f64).sqrt();
    if denom == 0.0 {
        return 0.0;
    }
    sa.dot(&sb) / denom
}

/// Cosine over the concatenation of two attribute vectors, assembled from
/// the per-attribute parts (mirrors the L2 graph's composition).
pub fn cosine_concat(
    a1: &QGramSet,
    a2: &QGramSet,
    b1: &QGramSet,
    b2: &QGramSet,
) -> f64 {
    cosine_concat_sparse(
        &a1.to_sparse(),
        &a2.to_sparse(),
        &b1.to_sparse(),
        &b2.to_sparse(),
    )
}

/// Hot-path cosine over precomputed sparse count vectors (§Perf): exact
/// (no hash buckets), one sorted-merge dot per attribute, no allocation.
pub fn cosine_concat_sparse(
    a1: &crate::features::SparseCounts,
    a2: &crate::features::SparseCounts,
    b1: &crate::features::SparseCounts,
    b2: &crate::features::SparseCounts,
) -> f64 {
    let dot = a1.dot(b1) + a2.dot(b2);
    let na = (a1.normsq + a2.normsq) as f64;
    let nb = (b1.normsq + b2.normsq) as f64;
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// The raw matcher outputs for one entity pair, as fed to a combiner.
#[derive(Clone, Copy, Debug, Default)]
pub struct MatcherScores {
    pub edit_title: f64,
    pub trigram_desc: f64,
    pub jaccard_title: f64,
    pub cosine_concat: f64,
}

impl MatcherScores {
    /// Evaluate every matcher (used by LRM training; strategies evaluate
    /// only the matchers they need on the hot path).
    pub fn all(a: &EntityFeatures, b: &EntityFeatures) -> MatcherScores {
        MatcherScores {
            edit_title: editdist::edit_similarity(&a.title_norm, &b.title_norm),
            trigram_desc: trigram_dice(&a.desc_grams, &b.desc_grams),
            jaccard_title: jaccard(&a.title_tokens, &b.title_tokens),
            cosine_concat: cosine_concat_sparse(
                &a.title_sparse,
                &a.desc_sparse,
                &b.title_sparse,
                &b.desc_sparse,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::QGramSet;

    fn g(s: &str) -> QGramSet {
        QGramSet::new(s, 3)
    }

    #[test]
    fn trigram_dice_identity_and_disjoint() {
        let a = g("samsung spinpoint");
        assert!((trigram_dice(&a, &a) - 1.0).abs() < 1e-12);
        let b = g("zzzzqqqq");
        assert!(trigram_dice(&a, &b) < 0.15);
    }

    #[test]
    fn trigram_dice_empty() {
        let e = QGramSet::new("", 3);
        // normalized "" still yields boundary grams; two empties match
        assert!(trigram_dice(&e, &e) > 0.0);
    }

    #[test]
    fn jaccard_basics() {
        let a = TokenSet::new("western digital caviar green");
        let b = TokenSet::new("wd caviar green 1tb");
        // inter = {caviar, green} = 2; union = 6
        assert!((jaccard(&a, &b) - 2.0 / 6.0).abs() < 1e-12);
        assert!((jaccard(&a, &a) - 1.0).abs() < 1e-12);
        let empty = TokenSet::new("");
        assert_eq!(jaccard(&empty, &empty), 0.0);
    }

    #[test]
    fn cosine_identity_range() {
        let a = g("intel x25-m postville");
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-9);
        let b = g("lg flatron monitor");
        let c = cosine(&a, &b);
        assert!((0.0..=1.0).contains(&c));
    }

    #[test]
    fn cosine_concat_consistent_with_parts() {
        // identical pairs → exactly 1 regardless of composition
        let (t, d) = (g("samsung f1"), g("internal sata 1tb"));
        assert!((cosine_concat(&t, &d, &t, &d) - 1.0).abs() < 1e-9);
        // orthogonal on both attributes → 0
        let (t2, d2) = (g("zzz"), g("qqq"));
        let v = cosine_concat(&t, &d, &t2, &d2);
        assert!(v < 0.2, "{v}");
    }

    #[test]
    fn similar_strings_score_higher() {
        let a = g("samsung spinpoint f1 1tb");
        let close = g("samsung spinpoint f1 1 tb");
        let far = g("canon pixma printer");
        assert!(trigram_dice(&a, &close) > trigram_dice(&a, &far));
        assert!(cosine(&a, &close) > cosine(&a, &far));
    }
}
