//! Logistic-regression training for the LRM strategy.
//!
//! The paper's LRM combines three matcher outputs with a model learned by
//! a machine-learning method (§2: SVM, decision tree or logistic
//! regression; §5.1 uses logistic regression).  This module implements
//! the training half: gradient descent on the cross-entropy loss over
//! labeled entity pairs, producing a [`StrategyParams`] for
//! [`super::StrategyKind::Lrm`].

use super::strategy::StrategyParams;
use super::MatcherScores;
use crate::datagen::GeneratedData;
use crate::features::EntityFeatures;
use crate::model::EntityId;
use crate::util::Rng;

/// One labeled training example: matcher outputs + duplicate label.
#[derive(Clone, Copy, Debug)]
pub struct LabeledPair {
    pub scores: MatcherScores,
    pub label: bool,
}

/// Training configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub learning_rate: f64,
    /// L2 regularization strength.
    pub l2: f64,
    /// Warm-start weights (bias + 3); `None` starts from zero.
    pub init: Option<[f32; 4]>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 300,
            learning_rate: 0.5,
            l2: 1e-4,
            init: None,
        }
    }
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

fn feature_vec(s: &MatcherScores) -> [f64; 3] {
    [s.jaccard_title, s.trigram_desc, s.cosine_concat]
}

/// Train LRM weights by full-batch gradient descent.
pub fn train_lrm(pairs: &[LabeledPair], cfg: &TrainConfig) -> StrategyParams {
    assert!(!pairs.is_empty(), "no training pairs");
    let mut w = cfg
        .init
        .map(|v| v.map(|x| x as f64))
        .unwrap_or([0.0f64; 4]); // bias + 3 weights
    let n = pairs.len() as f64;
    for _ in 0..cfg.epochs {
        let mut grad = [0.0f64; 4];
        for p in pairs {
            let x = feature_vec(&p.scores);
            let z = w[0] + w[1] * x[0] + w[2] * x[1] + w[3] * x[2];
            let err = sigmoid(z) - (p.label as u8 as f64);
            grad[0] += err;
            grad[1] += err * x[0];
            grad[2] += err * x[1];
            grad[3] += err * x[2];
        }
        for k in 0..4 {
            let reg = if k == 0 { 0.0 } else { cfg.l2 * w[k] };
            w[k] -= cfg.learning_rate * (grad[k] / n + reg);
        }
    }
    StrategyParams {
        values: [w[0] as f32, w[1] as f32, w[2] as f32, w[3] as f32],
    }
}

/// Cross-entropy loss of a parameter set on labeled pairs (for tests and
/// convergence reporting).
pub fn log_loss(pairs: &[LabeledPair], params: &StrategyParams) -> f64 {
    let [w0, w1, w2, w3] = params.values.map(|v| v as f64);
    let mut loss = 0.0;
    for p in pairs {
        let x = feature_vec(&p.scores);
        let z = w0 + w1 * x[0] + w2 * x[1] + w3 * x[2];
        let y = sigmoid(z).clamp(1e-12, 1.0 - 1e-12);
        loss -= if p.label { y.ln() } else { (1.0 - y).ln() };
    }
    loss / pairs.len() as f64
}

/// Build a labeled training sample from generated data: all (or up to
/// `max_pos`) true duplicate pairs as positives plus `neg_ratio`× random
/// non-duplicate pairs as negatives.
pub fn training_pairs(
    data: &GeneratedData,
    max_pos: usize,
    neg_ratio: usize,
    seed: u64,
) -> Vec<LabeledPair> {
    let mut rng = Rng::new(seed);
    let feats: Vec<EntityFeatures> = data
        .dataset
        .entities
        .iter()
        .map(|e| EntityFeatures::of(e, &data.dataset))
        .collect();
    let truth: std::collections::HashSet<(EntityId, EntityId)> =
        data.truth.iter().copied().collect();

    let mut out = Vec::new();
    for &(a, b) in data.truth.iter().take(max_pos) {
        out.push(LabeledPair {
            scores: MatcherScores::all(&feats[a.0 as usize], &feats[b.0 as usize]),
            label: true,
        });
    }
    let n_pos = out.len();
    let n = data.dataset.len();
    let mut negs = 0;
    while negs < n_pos * neg_ratio {
        let i = rng.gen_range(n);
        let j = rng.gen_range(n);
        if i == j {
            continue;
        }
        let key = if i < j {
            (EntityId(i as u32), EntityId(j as u32))
        } else {
            (EntityId(j as u32), EntityId(i as u32))
        };
        if truth.contains(&key) {
            continue;
        }
        out.push(LabeledPair {
            scores: MatcherScores::all(&feats[i], &feats[j]),
            label: false,
        });
        negs += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::matching::{MatchStrategy, StrategyKind};

    fn synthetic_pairs() -> Vec<LabeledPair> {
        // separable toy data: matches have high scores everywhere
        let mut pairs = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let pos = rng.gen_bool(0.5);
            let base = if pos { 0.8 } else { 0.15 };
            let jitter = |r: &mut Rng| (r.gen_f64() - 0.5) * 0.2;
            pairs.push(LabeledPair {
                scores: MatcherScores {
                    edit_title: 0.0,
                    jaccard_title: (base + jitter(&mut rng)).clamp(0.0, 1.0),
                    trigram_desc: (base + jitter(&mut rng)).clamp(0.0, 1.0),
                    cosine_concat: (base + jitter(&mut rng)).clamp(0.0, 1.0),
                },
                label: pos,
            });
        }
        pairs
    }

    #[test]
    fn training_reduces_loss() {
        let pairs = synthetic_pairs();
        let init = StrategyParams {
            values: [0.0, 0.0, 0.0, 0.0],
        };
        let trained = train_lrm(&pairs, &TrainConfig::default());
        assert!(
            log_loss(&pairs, &trained) < log_loss(&pairs, &init) * 0.5,
            "loss {} vs {}",
            log_loss(&pairs, &trained),
            log_loss(&pairs, &init)
        );
    }

    #[test]
    fn trained_model_separates_synthetic_data() {
        let pairs = synthetic_pairs();
        let params = train_lrm(&pairs, &TrainConfig::default());
        let strategy = MatchStrategy::new(StrategyKind::Lrm)
            .with_params(params)
            .with_threshold(0.5);
        let correct = pairs
            .iter()
            .filter(|p| (strategy.combine(&p.scores) >= 0.5) == p.label)
            .count();
        assert!(
            correct as f64 >= 0.95 * pairs.len() as f64,
            "{correct}/{}",
            pairs.len()
        );
    }

    #[test]
    fn training_on_generated_data_beats_default() {
        let data = GeneratorConfig::tiny().with_seed(3).generate();
        let pairs = training_pairs(&data, 200, 3, 7);
        assert!(pairs.iter().any(|p| p.label));
        assert!(pairs.iter().any(|p| !p.label));
        // warm-start from the hand-tuned default: gradient descent with a
        // small step on the convex loss must not end up worse
        let default = StrategyParams::lrm_default();
        let cfg = TrainConfig {
            learning_rate: 0.05,
            epochs: 400,
            l2: 0.0,
            init: Some(default.values),
        };
        let trained = train_lrm(&pairs, &cfg);
        assert!(
            log_loss(&pairs, &trained) <= log_loss(&pairs, &default) + 1e-9,
            "trained {} default {}",
            log_loss(&pairs, &trained),
            log_loss(&pairs, &default)
        );
        // cold-start training still reaches a usable model
        let cold = train_lrm(&pairs, &TrainConfig::default());
        assert!(log_loss(&pairs, &cold) < 0.35, "{}", log_loss(&pairs, &cold));
    }

    #[test]
    fn positive_weights_on_positive_signals() {
        let pairs = synthetic_pairs();
        let p = train_lrm(&pairs, &TrainConfig::default());
        // all three matcher weights should come out positive
        assert!(p.values[1] > 0.0 && p.values[2] > 0.0 && p.values[3] > 0.0);
        // bias negative (most random pairs are non-matches at z=0... here
        // balanced, so just check it's finite)
        assert!(p.values[0].is_finite());
    }
}
