//! Match strategies: WAM and LRM (paper §5.1) plus their memory models.

use super::{editdist, jaccard, trigram_dice, MatcherScores};
use crate::features::EntityFeatures;

/// Which match strategy a workflow runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// Weighted average of edit-distance(title) and TriGram(description);
    /// memory-optimized via threshold discard.
    Wam,
    /// Logistic regression over Jaccard(title), TriGram(description),
    /// Cosine(title‖description) — the learner-based strategy.
    Lrm,
}

impl StrategyKind {
    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Wam => "wam",
            StrategyKind::Lrm => "lrm",
        }
    }

    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s.to_ascii_lowercase().as_str() {
            "wam" => Some(StrategyKind::Wam),
            "lrm" => Some(StrategyKind::Lrm),
            _ => None,
        }
    }

    /// Average memory requirement per entity pair, `c_ms` (paper §3.1).
    ///
    /// WAM with threshold discard keeps only candidate correspondences
    /// (~20 B/pair in the paper); LRM materializes per-matcher vectors for
    /// the model (~1 kB/pair).  These constants feed the
    /// memory-restricted partition sizing `m ≤ √(max_mem/(#cores·c_ms))`.
    pub fn memory_per_pair(&self) -> u64 {
        match self {
            StrategyKind::Wam => 20,
            StrategyKind::Lrm => 1024,
        }
    }

    /// Matchers the strategy executes (for reporting).
    pub fn n_matchers(&self) -> usize {
        match self {
            StrategyKind::Wam => 2,
            StrategyKind::Lrm => 3,
        }
    }
}

/// Runtime parameters of a strategy — the `f32[4]` params vector of the
/// AOT-compiled executables uses the same layout.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StrategyParams {
    pub values: [f32; 4],
}

impl StrategyParams {
    /// WAM defaults: equal weights, decision threshold 0.75 (the paper's
    /// running example), no extra margin.
    pub fn wam_default() -> StrategyParams {
        StrategyParams {
            values: [0.5, 0.5, 0.75, 0.0],
        }
    }

    /// LRM defaults: a sensible hand-initialized model; production flows
    /// replace this with [`super::train::train_lrm`] output.
    pub fn lrm_default() -> StrategyParams {
        StrategyParams {
            values: [-8.0, 4.0, 5.0, 6.0],
        }
    }

    pub fn default_for(kind: StrategyKind) -> StrategyParams {
        match kind {
            StrategyKind::Wam => Self::wam_default(),
            StrategyKind::Lrm => Self::lrm_default(),
        }
    }
}

/// A fully-configured match strategy: kind + params + decision threshold.
#[derive(Clone, Copy, Debug)]
pub struct MatchStrategy {
    pub kind: StrategyKind,
    pub params: StrategyParams,
    /// Final match decision threshold on the combined similarity.
    pub threshold: f64,
}

impl MatchStrategy {
    pub fn new(kind: StrategyKind) -> MatchStrategy {
        MatchStrategy {
            kind,
            params: StrategyParams::default_for(kind),
            threshold: match kind {
                StrategyKind::Wam => 0.75,
                StrategyKind::Lrm => 0.5,
            },
        }
    }

    pub fn with_params(mut self, params: StrategyParams) -> Self {
        self.params = params;
        self
    }

    pub fn with_threshold(mut self, threshold: f64) -> Self {
        self.threshold = threshold;
        self
    }

    /// Combined similarity for one entity pair (exact matchers).
    ///
    /// WAM applies the threshold-discard optimization *inside* the
    /// evaluation: if the title similarity alone already caps the
    /// achievable average below the threshold, the (more expensive)
    /// description matcher is skipped and 0 is returned.  This mirrors the
    /// paper's "correspondences with a single-matcher similarity below
    /// 2·θ−1 can be discarded" rule and is also why WAM's memory per pair
    /// stays tiny.
    pub fn similarity(&self, a: &EntityFeatures, b: &EntityFeatures) -> f64 {
        match self.kind {
            StrategyKind::Wam => {
                let [w1, w2, thresh, margin] = self.params.values;
                let (w1, w2) = (w1 as f64, w2 as f64);
                let thresh = thresh as f64 - margin as f64;
                let wsum = w1 + w2;
                // discard bound: best case for the unseen matcher is 1.0
                let min_title = (thresh * wsum - w2) / w1.max(1e-9);
                // §Perf iteration log: an Ukkonen q-gram lower-bound
                // prefilter (dist ≥ (max|G| − |G∩|)/q) was tried here and
                // measured neutral-to-negative — the banded DP's own
                // length check + row-min early exit already kills
                // dissimilar pairs cheaply.  Reverted.
                let s_title = editdist::edit_similarity_min_chars(
                    &a.title_chars,
                    &b.title_chars,
                    min_title.clamp(0.0, 1.0),
                );
                if s_title == 0.0 && min_title > 0.0 {
                    return 0.0; // discarded
                }
                let s_desc = trigram_dice(&a.desc_grams, &b.desc_grams);
                let combined = (w1 * s_title + w2 * s_desc) / wsum;
                if combined >= thresh {
                    combined
                } else {
                    0.0
                }
            }
            StrategyKind::Lrm => {
                let [w0, w1, w2, w3] = self.params.values;
                let s_jac = jaccard(&a.title_tokens, &b.title_tokens);
                let s_tri = trigram_dice(&a.desc_grams, &b.desc_grams);
                let s_cos = super::cosine_concat_sparse(
                    &a.title_sparse,
                    &a.desc_sparse,
                    &b.title_sparse,
                    &b.desc_sparse,
                );
                let z = w0 as f64
                    + w1 as f64 * s_jac
                    + w2 as f64 * s_tri
                    + w3 as f64 * s_cos;
                1.0 / (1.0 + (-z).exp())
            }
        }
    }

    /// Does the pair match under this strategy?
    pub fn matches(&self, a: &EntityFeatures, b: &EntityFeatures) -> bool {
        self.similarity(a, b) >= self.threshold
    }

    /// Combined score from precomputed matcher outputs (training/eval).
    pub fn combine(&self, s: &MatcherScores) -> f64 {
        match self.kind {
            StrategyKind::Wam => {
                let [w1, w2, thresh, margin] = self.params.values;
                let combined = (w1 as f64 * s.edit_title
                    + w2 as f64 * s.trigram_desc)
                    / (w1 as f64 + w2 as f64);
                if combined >= (thresh - margin) as f64 {
                    combined
                } else {
                    0.0
                }
            }
            StrategyKind::Lrm => {
                let [w0, w1, w2, w3] = self.params.values;
                let z = w0 as f64
                    + w1 as f64 * s.jaccard_title
                    + w2 as f64 * s.trigram_desc
                    + w3 as f64 * s.cosine_concat;
                1.0 / (1.0 + (-z).exp())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Dataset, Entity, EntityId, Schema};
    use crate::model::{ATTR_DESCRIPTION, ATTR_TITLE};

    fn features(title: &str, desc: &str) -> EntityFeatures {
        let schema = Schema::new(vec![ATTR_TITLE, ATTR_DESCRIPTION]);
        let mut ds = Dataset::new(schema.clone());
        let mut e = Entity::new(EntityId(0), &schema);
        e.set(&schema, ATTR_TITLE, title.into());
        e.set(&schema, ATTR_DESCRIPTION, desc.into());
        ds.push(e);
        EntityFeatures::of(&ds.entities[0], &ds)
    }

    #[test]
    fn wam_identical_pair_is_match() {
        let s = MatchStrategy::new(StrategyKind::Wam);
        let a = features("Samsung SpinPoint F1 1TB", "internal sata 7200rpm");
        assert!((s.similarity(&a, &a) - 1.0).abs() < 1e-9);
        assert!(s.matches(&a, &a));
    }

    #[test]
    fn wam_near_duplicate_matches() {
        let s = MatchStrategy::new(StrategyKind::Wam);
        let a = features(
            "Samsung SpinPoint F1 HD103UJ 1TB",
            "internal sata 7200rpm 32MB cache",
        );
        let b = features(
            "Samsung Spinpoint F1 HD103UJ 1 TB",
            "internal sata 7200rpm 32 MB cache",
        );
        let sim = s.similarity(&a, &b);
        assert!(sim >= 0.75, "near-dup sim {sim}");
    }

    #[test]
    fn wam_discards_obvious_nonmatch() {
        let s = MatchStrategy::new(StrategyKind::Wam);
        let a = features("Samsung SpinPoint F1", "internal hdd");
        let b = features("Canon PIXMA iP4600", "photo printer usb");
        assert_eq!(s.similarity(&a, &b), 0.0, "discarded to exactly 0");
    }

    #[test]
    fn wam_discard_never_drops_true_matches() {
        // combine() without discard vs similarity() with discard must
        // agree on everything above the threshold.
        let s = MatchStrategy::new(StrategyKind::Wam);
        let pairs = [
            ("LG GH22NS50 black", "dvd burner sata", "LG GH22NS50, black", "dvd burner sata bulk"),
            ("WD Caviar Green 1TB", "low-power 5400rpm", "WD Caviar Green WD10EADS 1TB", "5400rpm low-power"),
            ("Intel X25-M 80GB", "ssd mlc sata", "Plextor PX-B320SA", "blu-ray combo drive"),
        ];
        for (t1, d1, t2, d2) in pairs {
            let a = features(t1, d1);
            let b = features(t2, d2);
            let fast = s.similarity(&a, &b);
            let scores = MatcherScores::all(&a, &b);
            let slow = (0.5 * scores.edit_title + 0.5 * scores.trigram_desc)
                .max(0.0);
            if slow >= s.threshold {
                assert!(
                    (fast - slow).abs() < 1e-9,
                    "match lost by discard: {fast} vs {slow}"
                );
            } else {
                assert_eq!(fast, 0.0);
            }
        }
    }

    #[test]
    fn lrm_scores_in_unit_interval_and_ordered() {
        let s = MatchStrategy::new(StrategyKind::Lrm);
        let a = features("Sony Bravia KDL-40", "lcd tv full-hd 1080p");
        let dup = features("Sony Bravia KDL40", "lcd-tv full-hd 1080p");
        let other = features("Garmin nuvi 255", "navigation europe maps");
        let s_dup = s.similarity(&a, &dup);
        let s_other = s.similarity(&a, &other);
        assert!((0.0..=1.0).contains(&s_dup));
        assert!((0.0..=1.0).contains(&s_other));
        assert!(s_dup > s_other);
        assert!(s.matches(&a, &dup));
        assert!(!s.matches(&a, &other));
    }

    #[test]
    fn memory_model_constants() {
        assert_eq!(StrategyKind::Wam.memory_per_pair(), 20);
        assert_eq!(StrategyKind::Lrm.memory_per_pair(), 1024);
        assert_eq!(StrategyKind::Wam.n_matchers(), 2);
        assert_eq!(StrategyKind::Lrm.n_matchers(), 3);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [StrategyKind::Wam, StrategyKind::Lrm] {
            assert_eq!(StrategyKind::parse(k.name()), Some(k));
        }
        assert_eq!(StrategyKind::parse("WAM"), Some(StrategyKind::Wam));
        assert_eq!(StrategyKind::parse("svm"), None);
    }

    #[test]
    fn combine_matches_similarity_for_lrm() {
        let s = MatchStrategy::new(StrategyKind::Lrm);
        let a = features("Asus Eee PC 1000H", "netbook 10 inch atom");
        let b = features("ASUS EeePC 1000 H", "netbook 10in intel atom");
        let direct = s.similarity(&a, &b);
        let combined = s.combine(&MatcherScores::all(&a, &b));
        assert!((direct - combined).abs() < 1e-9);
    }
}
