//! Levenshtein edit distance and edit similarity.
//!
//! This is the paper's WAM title matcher.  The accelerated PJRT path
//! substitutes a trigram proxy (see DESIGN.md §Hardware-Adaptation); this
//! exact implementation is the reference the substitution is validated
//! against, and what the pure-Rust execution engine runs.

/// Levenshtein distance, two-row DP, O(|a|·|b|) time, O(min) space.
pub fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    // keep the inner row the shorter one
    let (a, b) = if a.len() < b.len() { (b, a) } else { (a, b) };
    if b.is_empty() {
        return a.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur[j + 1] = (prev[j + 1] + 1)
                .min(cur[j] + 1)
                .min(prev[j] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Banded Levenshtein: early-exits with `None` when the distance exceeds
/// `max_dist`.  O(max_dist · min(|a|,|b|)) — the hot-path variant used by
/// the WAM matcher, where anything below the discard threshold is dropped
/// anyway.
pub fn levenshtein_bounded(a: &str, b: &str, max_dist: usize) -> Option<usize> {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    levenshtein_bounded_chars(&a, &b, max_dist)
}

/// Banded Levenshtein over pre-collected char slices (§Perf: the hot
/// path keeps `title_chars` in [`crate::features::EntityFeatures`] so no
/// per-pair char collection happens).
pub fn levenshtein_bounded_chars(
    a: &[char],
    b: &[char],
    max_dist: usize,
) -> Option<usize> {
    let (a, b) = if a.len() < b.len() { (b, a) } else { (a, b) };
    if a.len() - b.len() > max_dist {
        return None;
    }
    if b.is_empty() {
        return Some(a.len());
    }
    const INF: usize = usize::MAX / 2;
    // Note (§Perf iteration log): a thread-local scratch-row variant was
    // tried and measured *slower* (TLS + RefCell overhead exceeded the
    // two small allocations it saved) — reverted to plain Vecs.
    let mut prev = vec![INF; b.len() + 1];
    let mut cur = vec![INF; b.len() + 1];
    levenshtein_bounded_inner(a, b, max_dist, &mut prev, &mut cur)
}

fn levenshtein_bounded_inner(
    a: &[char],
    b: &[char],
    max_dist: usize,
    prev: &mut Vec<usize>,
    cur: &mut Vec<usize>,
) -> Option<usize> {
    const INF: usize = usize::MAX / 2;
    for (j, p) in prev.iter_mut().enumerate().take(max_dist.min(b.len()) + 1) {
        *p = j;
    }
    for i in 1..=a.len() {
        let lo = i.saturating_sub(max_dist).max(1);
        let hi = (i + max_dist).min(b.len());
        if lo > hi {
            return None;
        }
        cur[lo - 1] = if lo == 1 { i } else { INF };
        let mut row_min = cur[lo - 1];
        for j in lo..=hi {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
            row_min = row_min.min(cur[j]);
        }
        if hi < b.len() {
            cur[hi + 1..].iter_mut().for_each(|x| *x = INF);
        }
        if row_min > max_dist {
            return None;
        }
        // O(1): swaps the Vec headers (pointer/len/cap), not contents
        std::mem::swap(prev, cur);
    }
    let d = prev[b.len()];
    (d <= max_dist).then_some(d)
}

/// Normalized edit similarity: `1 - dist / max(|a|, |b|)`, in `[0, 1]`.
pub fn edit_similarity(a: &str, b: &str) -> f64 {
    let la = a.chars().count();
    let lb = b.chars().count();
    if la == 0 && lb == 0 {
        return 1.0;
    }
    1.0 - levenshtein(a, b) as f64 / la.max(lb) as f64
}

/// Edit similarity with a floor: returns 0.0 as soon as similarity cannot
/// reach `min_sim` (banded DP).  The WAM discard optimization in matcher
/// form.
pub fn edit_similarity_min(a: &str, b: &str, min_sim: f64) -> f64 {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    edit_similarity_min_chars(&a, &b, min_sim)
}

/// [`edit_similarity_min`] over pre-collected char slices (hot path).
pub fn edit_similarity_min_chars(a: &[char], b: &[char], min_sim: f64) -> f64 {
    let (la, lb) = (a.len(), b.len());
    if la == 0 && lb == 0 {
        return 1.0;
    }
    let max_len = la.max(lb);
    let max_dist = ((1.0 - min_sim) * max_len as f64).floor() as usize;
    match levenshtein_bounded_chars(a, b, max_dist) {
        Some(d) => 1.0 - d as f64 / max_len as f64,
        None => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::Rng;

    #[test]
    fn known_distances() {
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("flaw", "lawn"), 2);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("abc", "abc"), 0);
    }

    #[test]
    fn similarity_range_and_identity() {
        assert_eq!(edit_similarity("", ""), 1.0);
        assert_eq!(edit_similarity("abc", "abc"), 1.0);
        assert_eq!(edit_similarity("abc", "xyz"), 0.0);
        let s = edit_similarity("samsung", "samsunk");
        assert!(s > 0.8 && s < 1.0);
    }

    fn random_string(rng: &mut Rng, max_len: usize) -> String {
        let n = rng.gen_range(max_len + 1);
        (0..n)
            .map(|_| (b'a' + rng.gen_range(4) as u8) as char)
            .collect()
    }

    #[test]
    fn prop_metric_axioms() {
        forall("edit-metric", 150, |rng| {
            let a = random_string(rng, 12);
            let b = random_string(rng, 12);
            let c = random_string(rng, 12);
            let dab = levenshtein(&a, &b);
            assert_eq!(dab, levenshtein(&b, &a), "symmetry");
            assert_eq!(levenshtein(&a, &a), 0, "identity");
            // triangle inequality
            assert!(dab <= levenshtein(&a, &c) + levenshtein(&c, &b));
            // length bound
            assert!(
                dab >= a.chars().count().abs_diff(b.chars().count())
                    && dab <= a.chars().count().max(b.chars().count())
            );
        });
    }

    #[test]
    fn prop_bounded_agrees_with_full() {
        forall("edit-bounded", 200, |rng| {
            let a = random_string(rng, 10);
            let b = random_string(rng, 10);
            let full = levenshtein(&a, &b);
            for max_dist in 0..=10 {
                match levenshtein_bounded(&a, &b, max_dist) {
                    Some(d) => assert_eq!(d, full, "{a:?} {b:?} {max_dist}"),
                    None => assert!(full > max_dist, "{a:?} {b:?} {max_dist}"),
                }
            }
        });
    }

    #[test]
    fn prop_similarity_min_agrees() {
        forall("edit-sim-min", 150, |rng| {
            let a = random_string(rng, 10);
            let b = random_string(rng, 10);
            let s = edit_similarity(&a, &b);
            for min_sim in [0.0, 0.3, 0.5, 0.8, 1.0] {
                let sm = edit_similarity_min(&a, &b, min_sim);
                if s >= min_sim {
                    assert!(
                        (sm - s).abs() < 1e-12,
                        "{a:?} {b:?} {min_sim}: {sm} vs {s}"
                    );
                } else {
                    assert!(
                        sm == 0.0 || (sm - s).abs() < 1e-12,
                        "below-floor must be 0 or exact"
                    );
                }
            }
        });
    }

    #[test]
    fn unicode_counts_chars_not_bytes() {
        assert_eq!(levenshtein("über", "uber"), 1);
        assert_eq!(levenshtein("ü", ""), 1);
    }
}
