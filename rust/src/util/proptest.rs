//! Tiny property-testing harness (std-only replacement for `proptest`).
//!
//! A property is a closure over a seeded [`Rng`]; the harness runs it for
//! `cases` random seeds derived from a base seed and reports the failing
//! seed on panic so failures reproduce exactly.  No shrinking — inputs
//! here are small enough that the failing seed is directly debuggable.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the workspace rpath to
//! // libxla_extension; the behavior is covered by unit tests below.)
//! use pem::util::proptest::forall;
//! forall("addition commutes", 64, |rng| {
//!     let (a, b) = (rng.gen_range(1000) as u64, rng.gen_range(1000) as u64);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Base seed for all property tests; override with `PEM_PROP_SEED` to
/// explore a different part of the space, or set it to a failing seed
/// printed by a previous run to reproduce.
pub fn base_seed() -> u64 {
    std::env::var("PEM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED)
}

/// “pem seed 2010” — arbitrary but fixed.
const DEFAULT_SEED: u64 = 0x7e31_5eed_2010_cafe;

/// Run `property` for `cases` independently seeded Rngs.
pub fn forall<F: Fn(&mut Rng)>(name: &str, cases: u64, property: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| property(&mut rng)),
        );
        if let Err(panic) = result {
            eprintln!(
                "property {name:?} failed at case {case}/{cases} \
                 (reproduce with PEM_PROP_SEED={seed})"
            );
            std::panic::resume_unwind(panic);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("count", 32, |_rng| {
            // interior mutability not needed; use a raw pointer trick via
            // AssertUnwindSafe is overkill — count via atomic instead.
        });
        // simplest observable check: a property using the rng stays in range
        forall("in-range", 32, |rng| {
            assert!(rng.gen_range(10) < 10);
        });
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic]
    fn failing_property_propagates() {
        forall("always-fails", 4, |_rng| panic!("boom"));
    }

    #[test]
    fn seed_env_roundtrip() {
        // base_seed is stable within a process unless the env var is set
        assert_eq!(base_seed(), base_seed());
    }
}
