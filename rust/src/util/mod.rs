//! Std-only utility layer.
//!
//! The build environment is fully offline and only the crates vendored for
//! the `xla` bridge are available, so the usual ecosystem helpers (rand,
//! lru, serde, clap, criterion, proptest) are re-implemented here in the
//! small form this crate needs.

pub mod cli;
pub mod lru;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use lru::LruCache;
pub use rng::{Rng, Zipf};

/// One kibibyte/mebibyte/gibibyte in bytes.
pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;

/// FNV-1a 64-bit hash — the deterministic string hash used everywhere
/// (feature hashing, canopy seeds).  Stable across runs and platforms,
/// unlike `std::collections::hash_map::DefaultHasher`.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Ceiling division for positive integers.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    assert!(b > 0, "div_ceil by zero");
    a.div_ceil(b)
}

/// Format a byte count human-readably (for reports).
pub fn fmt_bytes(bytes: u64) -> String {
    if bytes >= GIB {
        format!("{:.2} GiB", bytes as f64 / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.2} MiB", bytes as f64 / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.2} KiB", bytes as f64 / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Lock a mutex, recovering the data if a previous holder panicked.
///
/// The resident services guard shared state (scheduler, members,
/// results, tenant tables) with mutexes that are locked on every
/// connection-handling path.  A bare `.lock().unwrap()` there turns
/// one panicked frame handler into a poisoned lock that wedges every
/// other tenant forever (PR 8 satellite fix).  All state guarded this
/// way is valid after any partial update — counters, maps and vecs
/// with no multi-field invariants spanning a panic point — so
/// recovering the poisoned guard is sound: the panic fails its own
/// request, not the cluster.
pub fn lock_poisonless<T: ?Sized>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`lock_poisonless`], for `RwLock` read guards.
pub fn read_poisonless<T: ?Sized>(l: &std::sync::RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`lock_poisonless`], for `RwLock` write guards.
pub fn write_poisonless<T: ?Sized>(
    l: &std::sync::RwLock<T>,
) -> std::sync::RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Format a virtual-time duration given in nanoseconds.
pub fn fmt_nanos(ns: u64) -> String {
    let s = ns as f64 / 1e9;
    if s >= 3600.0 {
        format!("{:.1} h", s / 3600.0)
    } else if s >= 60.0 {
        format!("{:.1} min", s / 60.0)
    } else if s >= 1.0 {
        format!("{s:.2} s")
    } else if ns >= 1_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // Reference values for FNV-1a 64.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fnv1a_deterministic_and_spread() {
        assert_eq!(fnv1a(b"samsung"), fnv1a(b"samsung"));
        assert_ne!(fnv1a(b"samsung"), fnv1a(b"samsunh"));
    }

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
        assert_eq!(div_ceil(114_000, 500), 228);
    }

    #[test]
    #[should_panic]
    fn div_ceil_zero_divisor_panics() {
        div_ceil(1, 0);
    }

    #[test]
    fn poisonless_locks_recover_the_data() {
        use std::sync::{Arc, Mutex, RwLock};
        let m = Arc::new(Mutex::new(41u32));
        let m2 = m.clone();
        assert!(std::thread::spawn(move || {
            let mut g = m2.lock().unwrap();
            *g = 42;
            panic!("poison while holding the mutex");
        })
        .join()
        .is_err());
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_poisonless(&m), 42);

        let l = Arc::new(RwLock::new(7u32));
        let l2 = l.clone();
        assert!(std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison while holding the write lock");
        })
        .join()
        .is_err());
        assert_eq!(*read_poisonless(&l), 7);
        *write_poisonless(&l) = 8;
        assert_eq!(*read_poisonless(&l), 8);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * MIB), "2.00 MiB");
        assert_eq!(fmt_nanos(500), "500 ns");
        assert_eq!(fmt_nanos(90 * 1_000_000_000), "1.5 min");
        assert_eq!(fmt_nanos(2 * 3600 * 1_000_000_000), "2.0 h");
    }
}
