//! LRU cache (std-only replacement for the `lru` crate).
//!
//! Backs the match services' partition caches (paper §4: “caches are
//! managed according to a LRU replacement strategy”).  Capacity is counted
//! in *entries* (the paper configures caches as “maximal number of cached
//! partitions c”).
//!
//! Implementation: `HashMap` + monotone access stamps. `O(capacity)` scan
//! on eviction — capacities here are tiny (the paper uses c = 16), so the
//! simplicity beats a doubly-linked-list intrusive design.

use std::collections::HashMap;
use std::hash::Hash;

#[derive(Debug)]
pub struct LruCache<K, V> {
    capacity: usize,
    tick: u64,
    map: HashMap<K, (V, u64)>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// `capacity == 0` disables the cache (every lookup misses, nothing is
    /// stored) — this is the paper's `c = 0` configuration.
    pub fn new(capacity: usize) -> Self {
        LruCache {
            capacity,
            tick: 0,
            map: HashMap::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up a key, refreshing its recency on hit.  Counts hit/miss.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(key) {
            Some((v, stamp)) => {
                *stamp = tick;
                self.hits += 1;
                Some(v)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Check presence without touching recency or stats (used by the
    /// workflow service's approximate cache-status bookkeeping).
    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Insert, evicting the least-recently-used entry when full.
    /// Returns the evicted pair, if any.
    pub fn put(&mut self, key: K, value: V) -> Option<(K, V)> {
        if self.capacity == 0 {
            return None;
        }
        self.tick += 1;
        if self.map.contains_key(&key) {
            self.map.insert(key, (value, self.tick));
            return None;
        }
        let mut evicted = None;
        if self.map.len() >= self.capacity {
            // O(n) scan for the oldest stamp; n <= capacity (tiny).
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
                .expect("non-empty map");
            let (v, _) = self.map.remove(&oldest).unwrap();
            self.evictions += 1;
            evicted = Some((oldest, v));
        }
        self.map.insert(key, (value, self.tick));
        evicted
    }

    /// Current key set (cache-status report piggybacked on task results).
    pub fn keys(&self) -> Vec<K> {
        self.map.keys().cloned().collect()
    }

    /// Iterate the cached values without touching recency or stats —
    /// resident-size accounting reads payload sizes through this.
    pub fn values(&self) -> impl Iterator<Item = &V> {
        self.map.values().map(|(v, _)| v)
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Hit ratio over all `get` calls so far (paper's `hr` metric).
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_hit_miss() {
        let mut c = LruCache::new(2);
        assert!(c.get(&1).is_none());
        c.put(1, "a");
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        c.get(&1); // 2 is now LRU
        let evicted = c.put(3, 30);
        assert_eq!(evicted, Some((2, 20)));
        assert!(c.contains(&1) && c.contains(&3) && !c.contains(&2));
    }

    #[test]
    fn reinsert_refreshes_recency() {
        let mut c = LruCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        c.put(1, 11); // refresh 1; 2 becomes LRU
        let evicted = c.put(3, 30);
        assert_eq!(evicted, Some((2, 20)));
        assert_eq!(c.get(&1), Some(&11));
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = LruCache::new(0);
        assert!(c.put(1, 1).is_none());
        assert!(c.get(&1).is_none());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn never_exceeds_capacity() {
        let mut c = LruCache::new(16);
        for i in 0..1000 {
            c.put(i, i);
            assert!(c.len() <= 16);
        }
        assert_eq!(c.evictions(), 1000 - 16);
    }

    #[test]
    fn contains_does_not_count_stats() {
        let mut c = LruCache::new(4);
        c.put(1, 1);
        assert!(c.contains(&1));
        assert!(!c.contains(&2));
        assert_eq!(c.hits() + c.misses(), 0);
    }

    #[test]
    fn keys_reports_cached_set() {
        let mut c = LruCache::new(3);
        c.put(5, ());
        c.put(7, ());
        let mut ks = c.keys();
        ks.sort_unstable();
        assert_eq!(ks, vec![5, 7]);
    }
}
