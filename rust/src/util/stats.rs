//! Small statistics helpers for the bench harness and reports.

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
    pub stddev: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = percentile_sorted(&sorted, 50.0);
        let var = samples
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / n as f64;
        let mut devs: Vec<f64> =
            samples.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            median,
            min: sorted[0],
            max: sorted[n - 1],
            mad: percentile_sorted(&devs, 50.0),
            stddev: var.sqrt(),
        }
    }
}

/// Linear-interpolation percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Simple fixed-width text table builder for experiment reports.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  "),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.mad, 0.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!(s.mean > s.median, "outlier pulls mean");
        assert!(s.mad <= 2.0, "MAD robust to the outlier");
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let inv = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &inv) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["cores", "time"]);
        t.row(vec!["1", "376"]).row(vec!["16", "24"]);
        let out = t.render();
        assert!(out.contains("cores"));
        assert!(out.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
