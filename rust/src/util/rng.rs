//! Seeded PRNG + samplers (std-only replacement for `rand`).
//!
//! Everything that involves randomness in this crate — data generation,
//! canopy seeding, property tests — goes through [`Rng`] so runs are fully
//! reproducible from a single `u64` seed.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (the canonical xoshiro seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        // Lemire's unbiased method (mul-shift with rejection).
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
            // reject and retry (rare)
        }
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.gen_range(i + 1));
        }
    }

    /// Poisson sample (Knuth's method; fine for small lambda).
    pub fn gen_poisson(&mut self, lambda: f64) -> u32 {
        let l = (-lambda).exp();
        let mut k = 0u32;
        let mut p = 1.0;
        loop {
            p *= self.gen_f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // guard against pathological lambda
            }
        }
    }
}

/// Zipf-distributed sampler over `{0, …, n-1}` with exponent `s`.
///
/// Used by the data generator to skew manufacturer / product-type
/// popularity: real product catalogs have a few huge brands and a long
/// tail, which is exactly what makes blocking-based partition *tuning*
/// necessary (paper §3.2).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.gen_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.gen_range(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit in 1000 draws");
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffled");
    }

    #[test]
    fn zipf_is_skewed_and_ordered() {
        let z = Zipf::new(50, 1.0);
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 50];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // rank 0 much more popular than rank 10, which beats rank 40
        assert!(counts[0] > 3 * counts[10]);
        assert!(counts[10] > counts[40]);
        // rank 0 frequency ≈ 1/H(50) ≈ 0.222
        let f0 = counts[0] as f64 / 50_000.0;
        assert!((f0 - 0.222).abs() < 0.03, "f0 {f0}");
    }

    #[test]
    fn poisson_mean_close_to_lambda() {
        let mut rng = Rng::new(9);
        let n = 20_000;
        let sum: u64 = (0..n).map(|_| rng.gen_poisson(1.5) as u64).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 1.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(100);
        let mut a = root.fork();
        let mut b = root.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
