//! Minimal command-line argument parser (std-only replacement for clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments; typed accessors with defaults.  Enough for the `pem`
//! binary, the examples and the benches.
//!
//! Grammar note: `--name token` is parsed as an option with value
//! `token` whenever `token` does not itself start with `--`.  Boolean
//! flags must therefore appear last, before another `--option`, or be
//! written as `--name=true`.

use std::collections::HashMap;
use std::fmt::Display;
use std::str::FromStr;

#[derive(Debug, Default)]
pub struct Args {
    pub program: String,
    positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue {
        key: String,
        value: String,
        msg: String,
    },
}

impl Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(name) => {
                write!(f, "option --{name} expects a value")
            }
            CliError::BadValue { key, value, msg } => {
                write!(f, "cannot parse --{key} value {value:?}: {msg}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from `std::env::args()`.
    pub fn from_env() -> Result<Args, CliError> {
        let mut it = std::env::args();
        let program = it.next().unwrap_or_default();
        Self::parse(program, it.collect())
    }

    /// Parse from an explicit vector (testable).
    pub fn parse(program: String, argv: Vec<String>) -> Result<Args, CliError> {
        let mut args = Args {
            program,
            ..Default::default()
        };
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--")
                {
                    args.options
                        .insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self
                .options
                .get(name)
                .is_some_and(|v| v == "true" || v == "1")
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get_str(name).unwrap_or(default)
    }

    /// Typed option with a default.
    pub fn get_or<T>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T: FromStr,
        T::Err: Display,
    {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: T::Err| CliError::BadValue {
                key: name.to_string(),
                value: v.clone(),
                msg: e.to_string(),
            }),
        }
    }

    /// Comma-separated typed list option.
    pub fn get_list<T>(&self, name: &str, default: &[T]) -> Result<Vec<T>, CliError>
    where
        T: FromStr + Clone,
        T::Err: Display,
    {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|e: T::Err| CliError::BadValue {
                        key: name.to_string(),
                        value: p.to_string(),
                        msg: e.to_string(),
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Args {
        Args::parse(
            "pem".into(),
            argv.iter().map(|s| s.to_string()).collect(),
        )
        .unwrap()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = parse(&["--seed", "42", "--nodes=4"]);
        assert_eq!(a.get_or("seed", 0u64).unwrap(), 42);
        assert_eq!(a.get_or("nodes", 1usize).unwrap(), 4);
    }

    #[test]
    fn parses_flags_and_positionals() {
        // flags come last or use `=` form — `--verbose input.csv` would
        // parse as an option (documented ambiguity of the grammar)
        let a = parse(&["run", "input.csv", "--verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional(), &["run", "input.csv"]);
        let b = parse(&["run", "--verbose=true", "input.csv"]);
        assert!(b.flag("verbose"));
        assert_eq!(b.positional(), &["run", "input.csv"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_or("threads", 4usize).unwrap(), 4);
        assert_eq!(a.str_or("strategy", "wam"), "wam");
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["--threads", "many"]);
        assert!(a.get_or("threads", 1usize).is_err());
    }

    #[test]
    fn list_option() {
        let a = parse(&["--cores", "1,2,4,8"]);
        assert_eq!(
            a.get_list("cores", &[16usize]).unwrap(),
            vec![1, 2, 4, 8]
        );
        assert_eq!(a.get_list("other", &[16usize]).unwrap(), vec![16]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--cache"]);
        assert!(a.flag("cache"));
    }
}
