//! Dataset and result I/O: CSV import/export.
//!
//! Real deployments do not generate their offers — they load them from
//! catalog exports.  This module reads/writes RFC-4180-style CSV
//! (quoted fields, embedded commas/quotes/newlines) without external
//! crates:
//!
//! * [`read_dataset`] / [`write_dataset`] — entities against a schema
//!   (header row = attribute names; empty cells = missing values);
//! * [`write_matches`] / [`read_matches`] — correspondence lists
//!   `(e1, e2, sim)` for downstream consumption;
//! * [`write_truth`] — ground-truth pair exports for evaluation.

use crate::model::{Correspondence, Dataset, Entity, EntityId, Schema};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Parse one CSV record from a reader-backed line iterator.  Returns the
/// fields, consuming continuation lines for quoted embedded newlines.
fn parse_record(
    lines: &mut impl Iterator<Item = std::io::Result<String>>,
) -> Result<Option<Vec<String>>> {
    let Some(first) = lines.next() else {
        return Ok(None);
    };
    let mut buf = first?;
    loop {
        match try_parse_line(&buf) {
            Some(fields) => return Ok(Some(fields)),
            None => {
                // unbalanced quotes: record continues on the next line
                match lines.next() {
                    Some(next) => {
                        buf.push('\n');
                        buf.push_str(&next?);
                    }
                    None => bail!("unterminated quoted field at EOF"),
                }
            }
        }
    }
}

/// Parse a complete CSV line into fields; `None` if quotes are open.
fn try_parse_line(line: &str) -> Option<Vec<String>> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match (in_quotes, c) {
            (false, ',') => fields.push(std::mem::take(&mut cur)),
            (false, '"') if cur.is_empty() => in_quotes = true,
            (false, ch) => cur.push(ch),
            (true, '"') => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            (true, ch) => cur.push(ch),
        }
    }
    if in_quotes {
        return None;
    }
    fields.push(cur);
    Some(fields)
}

fn escape(field: &str) -> String {
    if field.contains(',')
        || field.contains('"')
        || field.contains('\n')
        || field.contains('\r')
    {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Write a dataset as CSV: header = schema attribute names, one row per
/// entity, empty cell = missing value.
pub fn write_dataset<W: Write>(dataset: &Dataset, w: W) -> Result<()> {
    let mut w = BufWriter::new(w);
    let attrs = dataset.schema.attributes();
    writeln!(
        w,
        "{}",
        attrs.iter().map(|a| escape(a)).collect::<Vec<_>>().join(",")
    )?;
    for e in &dataset.entities {
        let row: Vec<String> = attrs
            .iter()
            .map(|a| escape(e.get(&dataset.schema, a).unwrap_or("")))
            .collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a dataset from CSV.  The header row defines the schema; entity
/// ids are assigned densely in row order.
pub fn read_dataset<R: Read>(r: R) -> Result<Dataset> {
    let mut lines = BufReader::new(r).lines();
    let header = parse_record(&mut lines)?
        .context("empty CSV: missing header row")?;
    if header.is_empty() || header.iter().all(|h| h.trim().is_empty()) {
        bail!("CSV header has no attribute names");
    }
    let schema = Schema::new(header.clone());
    let mut dataset = Dataset::new(schema.clone());
    let mut row_no = 1usize;
    while let Some(fields) = parse_record(&mut lines)? {
        row_no += 1;
        if fields.len() != header.len() {
            bail!(
                "row {row_no}: {} fields, header has {}",
                fields.len(),
                header.len()
            );
        }
        let mut e = Entity::new(EntityId(dataset.len() as u32), &schema);
        for (attr, value) in header.iter().zip(fields) {
            if !value.is_empty() {
                e.set(&schema, attr, value);
            }
        }
        dataset.push(e);
    }
    Ok(dataset)
}

/// Write correspondences as `e1,e2,sim` CSV (with header).
pub fn write_matches<W: Write>(
    matches: impl Iterator<Item = Correspondence>,
    w: W,
) -> Result<()> {
    let mut w = BufWriter::new(w);
    writeln!(w, "e1,e2,sim")?;
    let mut rows: Vec<Correspondence> = matches.collect();
    rows.sort_by_key(|c| (c.e1, c.e2));
    for c in rows {
        writeln!(w, "{},{},{:.6}", c.e1.0, c.e2.0, c.sim)?;
    }
    Ok(())
}

/// Read correspondences written by [`write_matches`].
pub fn read_matches<R: Read>(r: R) -> Result<Vec<Correspondence>> {
    let mut lines = BufReader::new(r).lines();
    let header = parse_record(&mut lines)?.context("empty matches CSV")?;
    if header != ["e1", "e2", "sim"] {
        bail!("unexpected matches header {header:?}");
    }
    let mut out = Vec::new();
    while let Some(fields) = parse_record(&mut lines)? {
        if fields.len() != 3 {
            bail!("bad matches row {fields:?}");
        }
        out.push(Correspondence::new(
            EntityId(fields[0].parse()?),
            EntityId(fields[1].parse()?),
            fields[2].parse()?,
        ));
    }
    Ok(out)
}

/// Write ground-truth duplicate pairs as `e1,e2` CSV.
pub fn write_truth<W: Write>(
    truth: &[(EntityId, EntityId)],
    w: W,
) -> Result<()> {
    let mut w = BufWriter::new(w);
    writeln!(w, "e1,e2")?;
    for &(a, b) in truth {
        writeln!(w, "{},{}", a.0, b.0)?;
    }
    Ok(())
}

/// File-path conveniences.
pub fn write_dataset_file(dataset: &Dataset, path: &Path) -> Result<()> {
    write_dataset(dataset, std::fs::File::create(path)?)
}

pub fn read_dataset_file(path: &Path) -> Result<Dataset> {
    read_dataset(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;

    #[test]
    fn csv_line_parsing() {
        assert_eq!(
            try_parse_line("a,b,c").unwrap(),
            vec!["a", "b", "c"]
        );
        assert_eq!(
            try_parse_line(r#""a,b",c"#).unwrap(),
            vec!["a,b", "c"]
        );
        assert_eq!(
            try_parse_line(r#""he said ""hi""",x"#).unwrap(),
            vec![r#"he said "hi""#, "x"]
        );
        assert_eq!(try_parse_line("").unwrap(), vec![""]);
        assert!(try_parse_line(r#""open"#).is_none(), "unbalanced");
    }

    #[test]
    fn dataset_roundtrip_preserves_everything() {
        let data = GeneratorConfig::tiny().with_entities(200).generate();
        let mut buf = Vec::new();
        write_dataset(&data.dataset, &mut buf).unwrap();
        let back = read_dataset(&buf[..]).unwrap();
        assert_eq!(back.schema, data.dataset.schema);
        assert_eq!(back.len(), data.dataset.len());
        for (a, b) in data.dataset.entities.iter().zip(&back.entities) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn dataset_with_awkward_values_roundtrips() {
        let schema = Schema::new(vec!["title", "description"]);
        let mut ds = Dataset::new(schema.clone());
        let mut e = Entity::new(EntityId(0), &schema);
        e.set(&schema, "title", "comma, \"quote\" and\nnewline".into());
        ds.push(e);
        let mut e2 = Entity::new(EntityId(1), &schema);
        e2.set(&schema, "description", "plain".into());
        ds.push(e2); // e2.title stays missing
        let mut buf = Vec::new();
        write_dataset(&ds, &mut buf).unwrap();
        let back = read_dataset(&buf[..]).unwrap();
        assert_eq!(
            back.entities[0].get(&schema, "title"),
            Some("comma, \"quote\" and\nnewline")
        );
        assert_eq!(back.entities[1].get(&schema, "title"), None);
    }

    #[test]
    fn missing_values_stay_missing() {
        let csv = "title,product_type\nLG GH22,\n,drive\n";
        let ds = read_dataset(csv.as_bytes()).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.entities[0].get(&ds.schema, "product_type"), None);
        assert_eq!(ds.entities[1].get(&ds.schema, "title"), None);
    }

    #[test]
    fn malformed_inputs_rejected() {
        assert!(read_dataset("".as_bytes()).is_err());
        assert!(read_dataset("a,b\n1,2,3\n".as_bytes()).is_err());
        assert!(read_dataset("a,b\n\"open,2\n".as_bytes()).is_err());
    }

    #[test]
    fn matches_roundtrip() {
        let matches = vec![
            Correspondence::new(EntityId(3), EntityId(1), 0.91),
            Correspondence::new(EntityId(2), EntityId(7), 0.755),
        ];
        let mut buf = Vec::new();
        write_matches(matches.iter().copied(), &mut buf).unwrap();
        let back = read_matches(&buf[..]).unwrap();
        assert_eq!(back.len(), 2);
        // sorted by (e1, e2); Correspondence::new normalizes order
        assert_eq!(back[0].pair(), (EntityId(1), EntityId(3)));
        assert!((back[0].sim - 0.91).abs() < 1e-5);
    }

    #[test]
    fn truth_export_format() {
        let mut buf = Vec::new();
        write_truth(&[(EntityId(0), EntityId(5))], &mut buf).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), "e1,e2\n0,5\n");
    }

    #[test]
    fn loaded_dataset_is_matchable() {
        // end-to-end: export generated data, reload, match — results
        // must equal matching the original
        use crate::cluster::ComputingEnv;
        use crate::coordinator::workflow::EngineChoice;
        use crate::coordinator::{run_workflow, WorkflowConfig};
        use crate::matching::StrategyKind;
        let data = GeneratorConfig::tiny().with_entities(300).generate();
        let mut buf = Vec::new();
        write_dataset(&data.dataset, &mut buf).unwrap();
        let reloaded = read_dataset(&buf[..]).unwrap();
        let ce = ComputingEnv::new(1, 2, crate::util::GIB);
        let cfg = WorkflowConfig::size_based(StrategyKind::Wam)
            .with_engine(EngineChoice::Threads);
        let a = run_workflow(&data, &cfg, &ce).unwrap();
        let b = run_workflow(&reloaded, &cfg, &ce).unwrap();
        assert_eq!(a.result.len(), b.result.len());
    }
}
