//! Dataset and result I/O: CSV / JSONL import, CSV export.
//!
//! Real deployments do not generate their offers — they load them from
//! catalog exports.  This module reads/writes RFC-4180-style CSV
//! (quoted fields, embedded commas/quotes/newlines) and strict flat
//! JSON-Lines without external crates:
//!
//! * [`stream_dataset`] — the incremental loader: entities are parsed
//!   one record at a time, never holding the raw file in memory.  The
//!   out-of-core path (`pem match --input big.jsonl --store spill`)
//!   feeds from this;
//! * [`read_dataset`] / [`write_dataset`] — entities against a schema
//!   (header row = attribute names; empty cells = missing values);
//!   `read_dataset` is [`stream_dataset`] collected;
//! * [`write_dataset_jsonl`] — the same catalog as JSON-Lines, one
//!   flat string-valued object per line;
//! * [`write_matches`] / [`read_matches`] — correspondence lists
//!   `(e1, e2, sim)` for downstream consumption;
//! * [`write_truth`] — ground-truth pair exports for evaluation.

use crate::model::{Correspondence, Dataset, Entity, EntityId, Schema};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Parse one CSV record from a reader-backed line iterator.  Returns the
/// fields, consuming continuation lines for quoted embedded newlines.
fn parse_record(
    lines: &mut impl Iterator<Item = std::io::Result<String>>,
) -> Result<Option<Vec<String>>> {
    let Some(first) = lines.next() else {
        return Ok(None);
    };
    let mut buf = first?;
    loop {
        match try_parse_line(&buf) {
            Some(fields) => return Ok(Some(fields)),
            None => {
                // unbalanced quotes: record continues on the next line
                match lines.next() {
                    Some(next) => {
                        buf.push('\n');
                        buf.push_str(&next?);
                    }
                    None => bail!("unterminated quoted field at EOF"),
                }
            }
        }
    }
}

/// Parse a complete CSV line into fields; `None` if quotes are open.
fn try_parse_line(line: &str) -> Option<Vec<String>> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match (in_quotes, c) {
            (false, ',') => fields.push(std::mem::take(&mut cur)),
            (false, '"') if cur.is_empty() => in_quotes = true,
            (false, ch) => cur.push(ch),
            (true, '"') => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            (true, ch) => cur.push(ch),
        }
    }
    if in_quotes {
        return None;
    }
    fields.push(cur);
    Some(fields)
}

fn escape(field: &str) -> String {
    if field.contains(',')
        || field.contains('"')
        || field.contains('\n')
        || field.contains('\r')
    {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Write a dataset as CSV: header = schema attribute names, one row per
/// entity, empty cell = missing value.
pub fn write_dataset<W: Write>(dataset: &Dataset, w: W) -> Result<()> {
    let mut w = BufWriter::new(w);
    let attrs = dataset.schema.attributes();
    writeln!(
        w,
        "{}",
        attrs.iter().map(|a| escape(a)).collect::<Vec<_>>().join(",")
    )?;
    for e in &dataset.entities {
        let row: Vec<String> = attrs
            .iter()
            .map(|a| escape(e.get(&dataset.schema, a).unwrap_or("")))
            .collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// The record encodings [`stream_dataset`] understands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetFormat {
    /// RFC-4180-style CSV; the header row defines the schema.
    Csv,
    /// JSON Lines: one flat, string-valued JSON object per line; the
    /// first record's keys define the schema.
    Jsonl,
}

impl DatasetFormat {
    /// Pick the format from a file extension: `.jsonl`/`.json` →
    /// [`DatasetFormat::Jsonl`], everything else CSV.
    pub fn from_path(path: &Path) -> DatasetFormat {
        match path.extension().and_then(|e| e.to_str()) {
            Some(ext)
                if ext.eq_ignore_ascii_case("jsonl")
                    || ext.eq_ignore_ascii_case("json") =>
            {
                DatasetFormat::Jsonl
            }
            _ => DatasetFormat::Csv,
        }
    }
}

/// An incremental dataset reader: yields one [`Entity`] per input
/// record without ever buffering the file.  The schema is fixed by the
/// first record (CSV header / first JSONL object) and available from
/// [`DatasetStream::schema`] before any entity is consumed — so an
/// out-of-core build can plan partitions and spill payloads while the
/// catalog is still streaming in.  Entity ids are assigned densely in
/// record order.
pub struct DatasetStream<B: BufRead> {
    lines: std::io::Lines<B>,
    schema: Schema,
    /// Attribute order of incoming records (CSV column order / first
    /// JSONL record's key order).
    header: Vec<String>,
    format: DatasetFormat,
    /// First JSONL record, parsed while establishing the schema.
    pending: Option<Vec<Option<String>>>,
    next_id: u32,
    row_no: usize,
}

impl<B: BufRead> DatasetStream<B> {
    /// The schema every yielded entity conforms to.
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Assemble the next entity from per-column values aligned with
    /// the header (`None` = missing).
    fn entity_from(&mut self, values: Vec<Option<String>>) -> Entity {
        let mut e = Entity::new(EntityId(self.next_id), &self.schema);
        self.next_id += 1;
        for (attr, value) in self.header.iter().zip(values) {
            if let Some(v) = value {
                if !v.is_empty() {
                    e.set(&self.schema, attr, v);
                }
            }
        }
        e
    }

    /// The next non-blank JSONL line, as `(row_no, line)`.
    fn next_jsonl_line(&mut self) -> Option<Result<String>> {
        loop {
            match self.lines.next()? {
                Ok(line) => {
                    self.row_no += 1;
                    if !line.trim().is_empty() {
                        return Some(Ok(line));
                    }
                }
                Err(e) => return Some(Err(e.into())),
            }
        }
    }
}

impl<B: BufRead> Iterator for DatasetStream<B> {
    type Item = Result<Entity>;

    fn next(&mut self) -> Option<Self::Item> {
        if let Some(values) = self.pending.take() {
            return Some(Ok(self.entity_from(values)));
        }
        match self.format {
            DatasetFormat::Csv => {
                let fields = match parse_record(&mut self.lines) {
                    Ok(Some(f)) => f,
                    Ok(None) => return None,
                    Err(e) => return Some(Err(e)),
                };
                self.row_no += 1;
                if fields.len() != self.header.len() {
                    return Some(Err(anyhow::anyhow!(
                        "row {}: {} fields, header has {}",
                        self.row_no,
                        fields.len(),
                        self.header.len()
                    )));
                }
                Some(Ok(self.entity_from(
                    fields.into_iter().map(Some).collect(),
                )))
            }
            DatasetFormat::Jsonl => {
                let line = match self.next_jsonl_line()? {
                    Ok(l) => l,
                    Err(e) => return Some(Err(e)),
                };
                let row = self.row_no;
                let record = match parse_jsonl_record(&line, row) {
                    Ok(r) => r,
                    Err(e) => return Some(Err(e)),
                };
                match align_jsonl_record(&self.header, record, row) {
                    Ok(values) => Some(Ok(self.entity_from(values))),
                    Err(e) => Some(Err(e)),
                }
            }
        }
    }
}

/// Open an incremental dataset reader over `r` (see
/// [`DatasetStream`]).  Fails immediately if the schema-defining first
/// record is missing or malformed.
pub fn stream_dataset<R: Read>(
    r: R,
    format: DatasetFormat,
) -> Result<DatasetStream<BufReader<R>>> {
    let mut lines = BufReader::new(r).lines();
    match format {
        DatasetFormat::Csv => {
            let header = parse_record(&mut lines)?
                .context("empty CSV: missing header row")?;
            if header.is_empty()
                || header.iter().all(|h| h.trim().is_empty())
            {
                bail!("CSV header has no attribute names");
            }
            let schema = Schema::new(header.clone());
            Ok(DatasetStream {
                lines,
                schema,
                header,
                format,
                pending: None,
                next_id: 0,
                row_no: 1,
            })
        }
        DatasetFormat::Jsonl => {
            let mut row_no = 0usize;
            let first = loop {
                match lines.next() {
                    None => bail!("empty JSONL: no records"),
                    Some(line) => {
                        let line = line?;
                        row_no += 1;
                        if !line.trim().is_empty() {
                            break line;
                        }
                    }
                }
            };
            let record = parse_jsonl_record(&first, row_no)?;
            if record.is_empty() {
                bail!("row {row_no}: first record has no attributes");
            }
            let header: Vec<String> =
                record.iter().map(|(k, _)| k.clone()).collect();
            let schema = Schema::new(header.clone());
            let pending =
                Some(record.into_iter().map(|(_, v)| v).collect());
            Ok(DatasetStream {
                lines,
                schema,
                header,
                format,
                pending,
                next_id: 0,
                row_no,
            })
        }
    }
}

/// Open an incremental reader over a file, picking the format from the
/// extension (`.jsonl`/`.json` → JSONL, else CSV).
pub fn stream_dataset_file(
    path: &Path,
) -> Result<DatasetStream<BufReader<std::fs::File>>> {
    stream_dataset(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
        DatasetFormat::from_path(path),
    )
}

/// Read a dataset from CSV.  The header row defines the schema; entity
/// ids are assigned densely in row order.  This is [`stream_dataset`]
/// collected into a materialized [`Dataset`].
pub fn read_dataset<R: Read>(r: R) -> Result<Dataset> {
    collect_stream(stream_dataset(r, DatasetFormat::Csv)?)
}

/// Drain a stream into a materialized [`Dataset`].
fn collect_stream<B: BufRead>(stream: DatasetStream<B>) -> Result<Dataset> {
    let mut dataset = Dataset::new(stream.schema().clone());
    for entity in stream {
        dataset.push(entity?);
    }
    Ok(dataset)
}

/// Map a parsed JSONL record onto the schema's attribute order.
/// Unknown keys are errors (the schema is fixed by the first record);
/// absent keys are missing values.
fn align_jsonl_record(
    header: &[String],
    record: Vec<(String, Option<String>)>,
    row: usize,
) -> Result<Vec<Option<String>>> {
    let mut values: Vec<Option<String>> = vec![None; header.len()];
    for (key, value) in record {
        let Some(pos) = header.iter().position(|h| *h == key) else {
            bail!(
                "row {row}: attribute {key:?} not in the schema \
                 (fixed by the first record: {header:?})"
            );
        };
        if values[pos].is_some() {
            bail!("row {row}: duplicate attribute {key:?}");
        }
        values[pos] = Some(value.unwrap_or_default());
    }
    Ok(values)
}

/// Parse one strict JSONL record: a single flat JSON object whose
/// values are strings (or `null` = missing).  Returns `(key, value)`
/// pairs in appearance order.
fn parse_jsonl_record(
    line: &str,
    row: usize,
) -> Result<Vec<(String, Option<String>)>> {
    let mut chars = line.chars().peekable();
    let mut out: Vec<(String, Option<String>)> = Vec::new();
    skip_ws(&mut chars);
    if chars.next() != Some('{') {
        bail!("row {row}: JSONL record must be a JSON object");
    }
    skip_ws(&mut chars);
    if chars.peek() == Some(&'}') {
        chars.next();
    } else {
        loop {
            skip_ws(&mut chars);
            let key = parse_json_string(&mut chars)
                .with_context(|| format!("row {row}: object key"))?;
            skip_ws(&mut chars);
            if chars.next() != Some(':') {
                bail!("row {row}: expected ':' after key {key:?}");
            }
            skip_ws(&mut chars);
            let value = match chars.peek() {
                Some('"') => Some(
                    parse_json_string(&mut chars).with_context(
                        || format!("row {row}: value of {key:?}"),
                    )?,
                ),
                Some('n') => {
                    for want in ['n', 'u', 'l', 'l'] {
                        if chars.next() != Some(want) {
                            bail!(
                                "row {row}: malformed literal for \
                                 {key:?}"
                            );
                        }
                    }
                    None
                }
                _ => bail!(
                    "row {row}: value of {key:?} must be a string or \
                     null (flat string-valued objects only)"
                ),
            };
            out.push((key, value));
            skip_ws(&mut chars);
            match chars.next() {
                Some(',') => continue,
                Some('}') => break,
                _ => bail!("row {row}: expected ',' or '}}'"),
            }
        }
    }
    skip_ws(&mut chars);
    if chars.next().is_some() {
        bail!("row {row}: trailing data after the JSON object");
    }
    Ok(out)
}

fn skip_ws(chars: &mut std::iter::Peekable<std::str::Chars<'_>>) {
    while matches!(chars.peek(), Some(' ' | '\t' | '\r')) {
        chars.next();
    }
}

/// Parse a JSON string literal (leading `"` still unconsumed),
/// handling the full escape set including `\uXXXX` surrogate pairs.
fn parse_json_string(
    chars: &mut std::iter::Peekable<std::str::Chars<'_>>,
) -> Result<String> {
    if chars.next() != Some('"') {
        bail!("expected '\"'");
    }
    let mut out = String::new();
    loop {
        match chars.next() {
            None => bail!("unterminated string"),
            Some('"') => return Ok(out),
            Some('\\') => match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some('/') => out.push('/'),
                Some('b') => out.push('\u{8}'),
                Some('f') => out.push('\u{c}'),
                Some('n') => out.push('\n'),
                Some('r') => out.push('\r'),
                Some('t') => out.push('\t'),
                Some('u') => {
                    let hi = parse_hex4(chars)?;
                    let c = if (0xD800..0xDC00).contains(&hi) {
                        // surrogate pair: the low half must follow
                        if chars.next() != Some('\\')
                            || chars.next() != Some('u')
                        {
                            bail!("lone high surrogate");
                        }
                        let lo = parse_hex4(chars)?;
                        if !(0xDC00..0xE000).contains(&lo) {
                            bail!("invalid low surrogate");
                        }
                        0x10000
                            + ((hi - 0xD800) << 10)
                            + (lo - 0xDC00)
                    } else if (0xDC00..0xE000).contains(&hi) {
                        bail!("lone low surrogate");
                    } else {
                        hi
                    };
                    out.push(
                        char::from_u32(c)
                            .context("invalid unicode escape")?,
                    );
                }
                other => bail!("bad escape {other:?}"),
            },
            Some(c) => out.push(c),
        }
    }
}

fn parse_hex4(
    chars: &mut std::iter::Peekable<std::str::Chars<'_>>,
) -> Result<u32> {
    let mut v = 0u32;
    for _ in 0..4 {
        let c = chars.next().context("truncated \\u escape")?;
        v = v * 16
            + c.to_digit(16)
                .with_context(|| format!("bad hex digit {c:?}"))?;
    }
    Ok(v)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

/// Write a dataset as JSON Lines: one flat string-valued object per
/// entity, every schema attribute present (`null` = missing) so the
/// first record fixes the full schema for [`stream_dataset`].
pub fn write_dataset_jsonl<W: Write>(dataset: &Dataset, w: W) -> Result<()> {
    let mut w = BufWriter::new(w);
    let attrs = dataset.schema.attributes();
    for e in &dataset.entities {
        let fields: Vec<String> = attrs
            .iter()
            .map(|a| match e.get(&dataset.schema, a) {
                Some(v) => {
                    format!("\"{}\":\"{}\"", json_escape(a), json_escape(v))
                }
                None => format!("\"{}\":null", json_escape(a)),
            })
            .collect();
        writeln!(w, "{{{}}}", fields.join(","))?;
    }
    Ok(())
}

/// Write correspondences as `e1,e2,sim` CSV (with header).
pub fn write_matches<W: Write>(
    matches: impl Iterator<Item = Correspondence>,
    w: W,
) -> Result<()> {
    let mut w = BufWriter::new(w);
    writeln!(w, "e1,e2,sim")?;
    let mut rows: Vec<Correspondence> = matches.collect();
    rows.sort_by_key(|c| (c.e1, c.e2));
    for c in rows {
        writeln!(w, "{},{},{:.6}", c.e1.0, c.e2.0, c.sim)?;
    }
    Ok(())
}

/// Read correspondences written by [`write_matches`].
pub fn read_matches<R: Read>(r: R) -> Result<Vec<Correspondence>> {
    let mut lines = BufReader::new(r).lines();
    let header = parse_record(&mut lines)?.context("empty matches CSV")?;
    if header != ["e1", "e2", "sim"] {
        bail!("unexpected matches header {header:?}");
    }
    let mut out = Vec::new();
    while let Some(fields) = parse_record(&mut lines)? {
        if fields.len() != 3 {
            bail!("bad matches row {fields:?}");
        }
        out.push(Correspondence::new(
            EntityId(fields[0].parse()?),
            EntityId(fields[1].parse()?),
            fields[2].parse()?,
        ));
    }
    Ok(out)
}

/// Write ground-truth duplicate pairs as `e1,e2` CSV.
pub fn write_truth<W: Write>(
    truth: &[(EntityId, EntityId)],
    w: W,
) -> Result<()> {
    let mut w = BufWriter::new(w);
    writeln!(w, "e1,e2")?;
    for &(a, b) in truth {
        writeln!(w, "{},{}", a.0, b.0)?;
    }
    Ok(())
}

/// File-path conveniences.
pub fn write_dataset_file(dataset: &Dataset, path: &Path) -> Result<()> {
    write_dataset(dataset, std::fs::File::create(path)?)
}

/// Read a dataset from a file, picking CSV or JSONL from the
/// extension (see [`DatasetFormat::from_path`]).
pub fn read_dataset_file(path: &Path) -> Result<Dataset> {
    collect_stream(stream_dataset_file(path)?)
}

/// Write a dataset as JSON Lines to a file.
pub fn write_dataset_jsonl_file(
    dataset: &Dataset,
    path: &Path,
) -> Result<()> {
    write_dataset_jsonl(dataset, std::fs::File::create(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;

    #[test]
    fn csv_line_parsing() {
        assert_eq!(
            try_parse_line("a,b,c").unwrap(),
            vec!["a", "b", "c"]
        );
        assert_eq!(
            try_parse_line(r#""a,b",c"#).unwrap(),
            vec!["a,b", "c"]
        );
        assert_eq!(
            try_parse_line(r#""he said ""hi""",x"#).unwrap(),
            vec![r#"he said "hi""#, "x"]
        );
        assert_eq!(try_parse_line("").unwrap(), vec![""]);
        assert!(try_parse_line(r#""open"#).is_none(), "unbalanced");
    }

    #[test]
    fn dataset_roundtrip_preserves_everything() {
        let data = GeneratorConfig::tiny().with_entities(200).generate();
        let mut buf = Vec::new();
        write_dataset(&data.dataset, &mut buf).unwrap();
        let back = read_dataset(&buf[..]).unwrap();
        assert_eq!(back.schema, data.dataset.schema);
        assert_eq!(back.len(), data.dataset.len());
        for (a, b) in data.dataset.entities.iter().zip(&back.entities) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn dataset_with_awkward_values_roundtrips() {
        let schema = Schema::new(vec!["title", "description"]);
        let mut ds = Dataset::new(schema.clone());
        let mut e = Entity::new(EntityId(0), &schema);
        e.set(&schema, "title", "comma, \"quote\" and\nnewline".into());
        ds.push(e);
        let mut e2 = Entity::new(EntityId(1), &schema);
        e2.set(&schema, "description", "plain".into());
        ds.push(e2); // e2.title stays missing
        let mut buf = Vec::new();
        write_dataset(&ds, &mut buf).unwrap();
        let back = read_dataset(&buf[..]).unwrap();
        assert_eq!(
            back.entities[0].get(&schema, "title"),
            Some("comma, \"quote\" and\nnewline")
        );
        assert_eq!(back.entities[1].get(&schema, "title"), None);
    }

    #[test]
    fn missing_values_stay_missing() {
        let csv = "title,product_type\nLG GH22,\n,drive\n";
        let ds = read_dataset(csv.as_bytes()).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.entities[0].get(&ds.schema, "product_type"), None);
        assert_eq!(ds.entities[1].get(&ds.schema, "title"), None);
    }

    #[test]
    fn malformed_inputs_rejected() {
        assert!(read_dataset("".as_bytes()).is_err());
        assert!(read_dataset("a,b\n1,2,3\n".as_bytes()).is_err());
        assert!(read_dataset("a,b\n\"open,2\n".as_bytes()).is_err());
    }

    #[test]
    fn jsonl_roundtrip_preserves_everything() {
        let data = GeneratorConfig::tiny().with_entities(200).generate();
        let mut buf = Vec::new();
        write_dataset_jsonl(&data.dataset, &mut buf).unwrap();
        let stream =
            stream_dataset(&buf[..], DatasetFormat::Jsonl).unwrap();
        assert_eq!(*stream.schema(), data.dataset.schema);
        let back = collect_stream(stream).unwrap();
        assert_eq!(back.len(), data.dataset.len());
        for (a, b) in data.dataset.entities.iter().zip(&back.entities) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn jsonl_awkward_values_and_missing_roundtrip() {
        let jsonl = concat!(
            "{\"title\":\"comma, \\\"quote\\\" and\\nnewline\",",
            "\"description\":null}\n",
            "\n",
            "{\"description\":\"plain \\u00e9\\ud83d\\ude00\"}\n",
        );
        let ds = read_dataset_from_jsonl(jsonl);
        assert_eq!(ds.len(), 2);
        assert_eq!(
            ds.entities[0].get(&ds.schema, "title"),
            Some("comma, \"quote\" and\nnewline")
        );
        assert_eq!(ds.entities[0].get(&ds.schema, "description"), None);
        assert_eq!(ds.entities[1].get(&ds.schema, "title"), None);
        assert_eq!(
            ds.entities[1].get(&ds.schema, "description"),
            Some("plain \u{e9}\u{1f600}")
        );
    }

    fn read_dataset_from_jsonl(s: &str) -> Dataset {
        collect_stream(
            stream_dataset(s.as_bytes(), DatasetFormat::Jsonl).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn jsonl_malformed_inputs_rejected() {
        let stream = |s: &str| {
            stream_dataset(s.as_bytes(), DatasetFormat::Jsonl)
                .and_then(collect_stream)
        };
        assert!(stream("").is_err(), "no records");
        assert!(stream("[1,2]\n").is_err(), "not an object");
        assert!(stream("{\"a\":1}\n").is_err(), "non-string value");
        assert!(
            stream("{\"a\":\"x\"} trailing\n").is_err(),
            "trailing data"
        );
        assert!(
            stream("{\"a\":\"x\",\"a\":\"y\"}\n").is_err(),
            "duplicate key"
        );
        assert!(
            stream("{\"a\":\"x\"}\n{\"b\":\"y\"}\n").is_err(),
            "key outside the first record's schema"
        );
        assert!(
            stream("{\"a\":\"\\ud800 lone\"}\n").is_err(),
            "lone surrogate"
        );
        // the schema error surfaces before later records are parsed
        assert!(
            stream_dataset("{\"a\":1}\n".as_bytes(), DatasetFormat::Jsonl)
                .is_err(),
            "first record is validated eagerly"
        );
    }

    #[test]
    fn streaming_csv_is_incremental_and_matches_read() {
        let data = GeneratorConfig::tiny().with_entities(50).generate();
        let mut buf = Vec::new();
        write_dataset(&data.dataset, &mut buf).unwrap();
        let mut stream =
            stream_dataset(&buf[..], DatasetFormat::Csv).unwrap();
        // schema is available before any entity is consumed
        assert_eq!(*stream.schema(), data.dataset.schema);
        let first = stream.next().unwrap().unwrap();
        assert_eq!(first, data.dataset.entities[0]);
        assert_eq!(stream.count(), data.dataset.len() - 1);
    }

    #[test]
    fn format_detection_from_extension() {
        let f = |p: &str| DatasetFormat::from_path(Path::new(p));
        assert_eq!(f("cat.csv"), DatasetFormat::Csv);
        assert_eq!(f("cat"), DatasetFormat::Csv);
        assert_eq!(f("big.jsonl"), DatasetFormat::Jsonl);
        assert_eq!(f("big.JSONL"), DatasetFormat::Jsonl);
        assert_eq!(f("big.json"), DatasetFormat::Jsonl);
    }

    #[test]
    fn matches_roundtrip() {
        let matches = vec![
            Correspondence::new(EntityId(3), EntityId(1), 0.91),
            Correspondence::new(EntityId(2), EntityId(7), 0.755),
        ];
        let mut buf = Vec::new();
        write_matches(matches.iter().copied(), &mut buf).unwrap();
        let back = read_matches(&buf[..]).unwrap();
        assert_eq!(back.len(), 2);
        // sorted by (e1, e2); Correspondence::new normalizes order
        assert_eq!(back[0].pair(), (EntityId(1), EntityId(3)));
        assert!((back[0].sim - 0.91).abs() < 1e-5);
    }

    #[test]
    fn truth_export_format() {
        let mut buf = Vec::new();
        write_truth(&[(EntityId(0), EntityId(5))], &mut buf).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), "e1,e2\n0,5\n");
    }

    #[test]
    fn loaded_dataset_is_matchable() {
        // end-to-end: export generated data, reload, match — results
        // must equal matching the original
        use crate::cluster::ComputingEnv;
        use crate::coordinator::workflow::EngineChoice;
        use crate::coordinator::{run_workflow, WorkflowConfig};
        use crate::matching::StrategyKind;
        let data = GeneratorConfig::tiny().with_entities(300).generate();
        let mut buf = Vec::new();
        write_dataset(&data.dataset, &mut buf).unwrap();
        let reloaded = read_dataset(&buf[..]).unwrap();
        let ce = ComputingEnv::new(1, 2, crate::util::GIB);
        let cfg = WorkflowConfig::size_based(StrategyKind::Wam)
            .with_engine(EngineChoice::Threads);
        let a = run_workflow(&data, &cfg, &ce).unwrap();
        let b = run_workflow(&reloaded, &cfg, &ce).unwrap();
        assert_eq!(a.result.len(), b.result.len());
    }
}
