//! The per-match-service partition cache (paper §4).
//!
//! Each match service temporarily stores fetched entity partitions in an
//! LRU cache shared by all of its match threads; capacity is configured
//! as a maximum number of partitions `c` (`c = 0` disables caching).

use crate::partition::PartitionId;
use crate::store::PartitionData;
use crate::util::{lock_poisonless, LruCache};
use std::sync::{Arc, Mutex};

/// Thread-safe partition cache.
pub struct PartitionCache {
    inner: Mutex<LruCache<PartitionId, Arc<PartitionData>>>,
}

impl PartitionCache {
    pub fn new(capacity: usize) -> PartitionCache {
        PartitionCache {
            inner: Mutex::new(LruCache::new(capacity)),
        }
    }

    /// Look up a partition; counts a hit or miss.
    pub fn get(&self, id: PartitionId) -> Option<Arc<PartitionData>> {
        lock_poisonless(&self.inner).get(&id).cloned()
    }

    /// Store a fetched partition.
    pub fn put(&self, id: PartitionId, data: Arc<PartitionData>) {
        lock_poisonless(&self.inner).put(id, data);
    }

    /// Presence probe that touches neither recency nor the hit/miss
    /// counters — the batch-mode prefetcher uses it so warming the
    /// cache does not distort the cache statistics the reports carry.
    pub fn contains(&self, id: PartitionId) -> bool {
        lock_poisonless(&self.inner).contains(&id)
    }

    /// Cached partition ids — piggybacked on task-completion reports so
    /// the workflow service can maintain its approximate cache status
    /// without extra messages (paper §4).
    pub fn status(&self) -> Vec<PartitionId> {
        lock_poisonless(&self.inner).keys()
    }

    pub fn hits(&self) -> u64 {
        lock_poisonless(&self.inner).hits()
    }

    pub fn misses(&self) -> u64 {
        lock_poisonless(&self.inner).misses()
    }

    /// Entries evicted to stay under capacity — with hits/misses this
    /// tells cold-start misses from capacity thrash (`cache.evictions`).
    pub fn evictions(&self) -> u64 {
        lock_poisonless(&self.inner).evictions()
    }

    /// Cost-model bytes currently held by cached payloads
    /// (`cache.resident_bytes`).
    pub fn resident_bytes(&self) -> u64 {
        lock_poisonless(&self.inner)
            .values()
            .map(|d| d.approx_bytes)
            .sum()
    }

    pub fn capacity(&self) -> usize {
        lock_poisonless(&self.inner).capacity()
    }

    pub fn clear(&self) {
        lock_poisonless(&self.inner).clear()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::EntityId;

    fn dummy(id: u32) -> Arc<PartitionData> {
        Arc::new(PartitionData {
            id: PartitionId(id),
            entities: vec![EntityId(id)],
            features: vec![],
            approx_bytes: 100,
        })
    }

    #[test]
    fn caches_and_reports_status() {
        let c = PartitionCache::new(2);
        assert!(c.get(PartitionId(1)).is_none());
        c.put(PartitionId(1), dummy(1));
        assert!(c.get(PartitionId(1)).is_some());
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        let mut st = c.status();
        st.sort();
        assert_eq!(st, vec![PartitionId(1)]);
        assert_eq!(c.resident_bytes(), 100);
        assert_eq!(c.evictions(), 0);
    }

    #[test]
    fn eviction_and_residency_observability() {
        let c = PartitionCache::new(2);
        c.put(PartitionId(1), dummy(1));
        c.put(PartitionId(2), dummy(2));
        assert_eq!(c.resident_bytes(), 200);
        c.put(PartitionId(3), dummy(3)); // capacity thrash
        assert_eq!(c.evictions(), 1);
        // resident bytes track the *current* payloads, not history
        assert_eq!(c.resident_bytes(), 200);
        c.clear();
        assert_eq!(c.resident_bytes(), 0);
        assert_eq!(c.evictions(), 1, "history survives clear");
    }

    #[test]
    fn lru_eviction_via_shared_cache() {
        let c = PartitionCache::new(2);
        c.put(PartitionId(1), dummy(1));
        c.put(PartitionId(2), dummy(2));
        c.get(PartitionId(1));
        c.put(PartitionId(3), dummy(3)); // evicts 2
        assert!(c.get(PartitionId(2)).is_none());
        assert!(c.get(PartitionId(1)).is_some());
        assert!(c.get(PartitionId(3)).is_some());
    }

    #[test]
    fn zero_capacity_disabled() {
        let c = PartitionCache::new(0);
        c.put(PartitionId(1), dummy(1));
        assert!(c.get(PartitionId(1)).is_none());
        assert!(c.status().is_empty());
    }

    #[test]
    fn concurrent_access() {
        let c = Arc::new(PartitionCache::new(8));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u32 {
                    let id = PartitionId((t * 100 + i) % 16);
                    if c.get(id).is_none() {
                        c.put(id, dummy(id.0));
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.status().len() <= 8);
        assert_eq!(c.hits() + c.misses(), 400);
    }
}
