//! Match services (paper §4): task execution with partition caching.
//!
//! A match service runs on one node, executes match tasks in its match
//! threads, and keeps a [`PartitionCache`] shared by those threads.  Task
//! execution is abstracted behind [`TaskExecutor`] so the same service
//! code drives both the pure-Rust matchers and the accelerated PJRT path
//! — and both the in-process engines and the networked match-service
//! node ([`crate::service::match_node`]), which runs this exact stack
//! behind a TCP socket loop.

pub mod cache;

pub use cache::PartitionCache;

use crate::matching::MatchStrategy;
use crate::model::Correspondence;
use crate::partition::MatchTask;
use crate::store::PartitionData;

/// Executes the comparison work of one match task over two fetched
/// partitions.  `intra == true` means `left` and `right` are the same
/// partition and only unordered pairs are compared.
pub trait TaskExecutor: Send + Sync {
    fn execute(
        &self,
        left: &PartitionData,
        right: &PartitionData,
        intra: bool,
    ) -> Vec<Correspondence>;

    /// Short name for reports.
    fn name(&self) -> &'static str;
}

/// Pure-Rust execution: evaluate the match strategy on every pair,
/// keeping correspondences at/above the decision threshold.
pub struct RustExecutor {
    pub strategy: MatchStrategy,
}

impl RustExecutor {
    pub fn new(strategy: MatchStrategy) -> RustExecutor {
        RustExecutor { strategy }
    }
}

impl TaskExecutor for RustExecutor {
    fn execute(
        &self,
        left: &PartitionData,
        right: &PartitionData,
        intra: bool,
    ) -> Vec<Correspondence> {
        let mut out = Vec::new();
        if intra {
            for i in 0..left.len() {
                for j in (i + 1)..left.len() {
                    let sim = self
                        .strategy
                        .similarity(&left.features[i], &left.features[j]);
                    if sim >= self.strategy.threshold {
                        out.push(Correspondence::new(
                            left.entities[i],
                            left.entities[j],
                            sim as f32,
                        ));
                    }
                }
            }
        } else {
            for i in 0..left.len() {
                for j in 0..right.len() {
                    if left.entities[i] == right.entities[j] {
                        continue; // overlapping partitions guard
                    }
                    let sim = self
                        .strategy
                        .similarity(&left.features[i], &right.features[j]);
                    if sim >= self.strategy.threshold {
                        out.push(Correspondence::new(
                            left.entities[i],
                            right.entities[j],
                            sim as f32,
                        ));
                    }
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// Number of pair comparisons a task performs (for metrics).
pub fn task_comparisons(task: &MatchTask, left: usize, right: usize) -> u64 {
    if task.left == task.right {
        (left as u64 * (left as u64).saturating_sub(1)) / 2
    } else {
        left as u64 * right as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::features::EntityFeatures;
    use crate::matching::StrategyKind;
    use crate::model::EntityId;
    use crate::partition::PartitionId;
    use std::sync::Arc;

    fn partition_of(
        data: &crate::datagen::GeneratedData,
        ids: std::ops::Range<u32>,
        pid: u32,
    ) -> Arc<PartitionData> {
        let entities: Vec<EntityId> = ids.map(EntityId).collect();
        let features: Vec<EntityFeatures> = entities
            .iter()
            .map(|id| {
                EntityFeatures::of(
                    data.dataset.get(*id).unwrap(),
                    &data.dataset,
                )
            })
            .collect();
        Arc::new(PartitionData {
            id: PartitionId(pid),
            entities,
            features,
            approx_bytes: 1000,
        })
    }

    #[test]
    fn intra_task_finds_injected_duplicates() {
        let data = GeneratorConfig::tiny().with_seed(11).generate();
        let n = data.dataset.len() as u32;
        let p = partition_of(&data, 0..n, 0);
        let exec =
            RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
        let found = exec.execute(&p, &p, true);
        // recall over the injected truth should be high (duplicates are
        // mild corruptions)
        let found_set: std::collections::HashSet<(EntityId, EntityId)> =
            found.iter().map(|c| c.pair()).collect();
        let hit = data
            .truth
            .iter()
            .filter(|&&(a, b)| found_set.contains(&(a, b)))
            .count();
        assert!(
            hit as f64 >= 0.8 * data.truth.len() as f64,
            "recall {hit}/{}",
            data.truth.len()
        );
    }

    #[test]
    fn cross_task_skips_shared_entities() {
        let data = GeneratorConfig::tiny().with_seed(12).generate();
        let p1 = partition_of(&data, 0..50, 0);
        let p2 = partition_of(&data, 25..75, 1); // overlap 25..50
        let exec =
            RustExecutor::new(MatchStrategy::new(StrategyKind::Wam));
        let found = exec.execute(&p1, &p2, false);
        assert!(found.iter().all(|c| c.e1 != c.e2));
    }

    #[test]
    fn intra_vs_cross_consistency() {
        // splitting a partition in two and running the 3 tasks finds the
        // same correspondences as one intra task over the union
        let data = GeneratorConfig::tiny().with_seed(13).generate();
        let whole = partition_of(&data, 0..80, 0);
        let a = partition_of(&data, 0..40, 1);
        let b = partition_of(&data, 40..80, 2);
        let exec =
            RustExecutor::new(MatchStrategy::new(StrategyKind::Lrm));
        let mut combined: Vec<Correspondence> = Vec::new();
        combined.extend(exec.execute(&a, &a, true));
        combined.extend(exec.execute(&b, &b, true));
        combined.extend(exec.execute(&a, &b, false));
        let mut whole_res = exec.execute(&whole, &whole, true);
        let key = |c: &Correspondence| (c.e1, c.e2);
        combined.sort_by_key(key);
        whole_res.sort_by_key(key);
        assert_eq!(
            combined.iter().map(key).collect::<Vec<_>>(),
            whole_res.iter().map(key).collect::<Vec<_>>()
        );
    }

    #[test]
    fn comparisons_formula() {
        let t_intra = MatchTask {
            id: 0,
            left: PartitionId(0),
            right: PartitionId(0),
        };
        let t_cross = MatchTask {
            id: 1,
            left: PartitionId(0),
            right: PartitionId(1),
        };
        assert_eq!(task_comparisons(&t_intra, 10, 10), 45);
        assert_eq!(task_comparisons(&t_cross, 10, 20), 200);
        assert_eq!(task_comparisons(&t_intra, 0, 0), 0);
    }
}
