//! Simulated RMI: the communication cost model between services.
//!
//! The paper's services (workflow / data / match) talk over Java RMI on a
//! LAN.  For the simulator, communication is modeled as a deterministic
//! cost: every message pays `latency` plus `bytes / bandwidth`.  The
//! virtual-time engine charges these costs on the simulated clock; the
//! thread engine can optionally inject them as real sleeps (off by
//! default).  The *real-wire* counterpart of this module is
//! [`crate::rpc`] + [`crate::service`]: actual TCP services whose
//! delivered-bytes accounting flows through the same [`TrafficStats`].
//!
//! Delivered-bytes accounting feeds the communication-overhead numbers in
//! the experiment reports.
//!
//! The *real* I/O layer lives next door in [`reactor`]: the
//! readiness-driven event loop the TCP servers run on.  It parks in
//! the kernel on [`poll`] (`epoll(7)` on Linux, `poll(2)` elsewhere)
//! until a socket is actually ready, serves any number of servers on
//! one thread, and is woken for shutdown through a [`poll::Waker`];
//! framing stays incremental via [`crate::rpc::session`].

pub mod poll;
pub mod reactor;

use std::sync::atomic::{AtomicU64, Ordering};

/// Deterministic network cost model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// One-way message latency in nanoseconds (RMI call overhead).
    pub latency_ns: u64,
    /// Payload bandwidth in bytes per second.
    pub bandwidth_bps: u64,
}

impl CostModel {
    /// Gigabit-LAN-ish defaults matching the paper's testbed era:
    /// ~0.3 ms per RMI round trip, 1 Gbit/s payload bandwidth.
    pub fn lan() -> CostModel {
        CostModel {
            latency_ns: 300_000,
            bandwidth_bps: 125_000_000,
        }
    }

    /// Data-service fetch path: a partition fetch is not a raw socket
    /// transfer but a DBMS round trip — query execution, JDBC row
    /// marshalling and RMI serialization of entity objects.  Effective
    /// figures for that era's stack: ~7 ms request overhead, ~15 MB/s
    /// sustained payload throughput.  This is what makes partition
    /// caching worth 10–26% in the paper's Tables 1–2.
    pub fn dbms() -> CostModel {
        CostModel {
            latency_ns: 7_000_000,
            bandwidth_bps: 15_000_000,
        }
    }

    /// Zero-cost model (everything local; for unit tests).
    pub fn free() -> CostModel {
        CostModel {
            latency_ns: 0,
            bandwidth_bps: u64::MAX,
        }
    }

    /// Time to transfer a payload of `bytes`: latency + bytes/bandwidth.
    pub fn transfer_time_ns(&self, bytes: u64) -> u64 {
        let bw = if self.bandwidth_bps == 0 {
            1
        } else {
            self.bandwidth_bps
        };
        self.latency_ns
            + ((bytes as u128 * 1_000_000_000u128) / bw as u128) as u64
    }

    /// Cost of a small control message (task assignment, completion
    /// report with piggybacked cache status — paper §4).
    pub fn control_message_ns(&self) -> u64 {
        self.latency_ns
    }
}

/// Traffic accounting shared by all services of a run.
#[derive(Debug, Default)]
pub struct TrafficStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

impl TrafficStats {
    pub fn new() -> TrafficStats {
        TrafficStats::default()
    }

    pub fn record(&self, bytes: u64) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn total_messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_components() {
        let m = CostModel {
            latency_ns: 1000,
            bandwidth_bps: 1_000_000_000, // 1 GB/s
        };
        assert_eq!(m.transfer_time_ns(0), 1000);
        // 1 MB at 1 GB/s = 1 ms
        assert_eq!(m.transfer_time_ns(1_000_000), 1000 + 1_000_000);
    }

    #[test]
    fn free_model_is_zero() {
        let m = CostModel::free();
        assert_eq!(m.transfer_time_ns(u32::MAX as u64), 0);
        assert_eq!(m.control_message_ns(), 0);
    }

    #[test]
    fn lan_model_orders_of_magnitude() {
        let m = CostModel::lan();
        // fetching a 2 MB partition ≈ 16 ms + 0.3 ms latency
        let t = m.transfer_time_ns(2_000_000);
        assert!(t > 15_000_000 && t < 20_000_000, "{t}");
    }

    #[test]
    fn traffic_accounting() {
        let t = TrafficStats::new();
        t.record(100);
        t.record(200);
        assert_eq!(t.total_messages(), 2);
        assert_eq!(t.total_bytes(), 300);
    }

    #[test]
    fn zero_bandwidth_does_not_divide_by_zero() {
        let m = CostModel {
            latency_ns: 0,
            bandwidth_bps: 0,
        };
        let _ = m.transfer_time_ns(1000);
    }
}
