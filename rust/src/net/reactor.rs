//! A std-only readiness-driven event loop for the TCP servers.
//!
//! The first two PRs ran every server connection on its own blocking
//! OS thread.  PR 3 collapsed that to one thread per server — but it
//! *polled*: a tick loop over every nonblocking socket with a 500 µs
//! sleep whenever no byte moved, so an idle server still burned
//! thousands of syscalls per second, O(connections) each tick.  PR 8
//! replaces the spin with real kernel readiness via
//! [`crate::net::poll`]: the reactor **parks** in `epoll_wait` /
//! `poll(2)` until a socket actually has bytes (or buffer space) for
//! it, and a [`Waker`] pokes it when a shutdown flag flips — the old
//! "no poke needed, the loop polls" contract is gone.
//!
//! One reactor now hosts *any number of servers* (listener + handler
//! + shutdown flag), so the dist engine runs the workflow and data
//! services on a single thread: see [`Reactor::add_server`].  Per
//! readiness event the reactor:
//!
//! 1. accepts every pending connection on a ready listener (fatal
//!    accept errors are counted via `reactor.accept_errors`, never
//!    silently swallowed);
//! 2. for a ready connection, drains writable bytes from its
//!    [`SessionEncoder`], reads whatever chunk the kernel has
//!    (possibly half a length prefix), feeds it to the
//!    [`SessionDecoder`], and hands every completed frame to the
//!    owning server's [`FrameHandler`];
//! 3. keeps kernel-side write interest in sync with whether the
//!    connection has queued outbound bytes, so a parked reactor is
//!    woken exactly when progress is possible;
//! 4. drops connections that closed, errored, violated framing
//!    (oversized length header) or exceeded the outbound buffer cap
//!    ([`MAX_SESSION_SEND_BYTES`]).
//!
//! Handlers run on the reactor thread and must not block; the
//! workflow/data handlers only touch in-memory state behind short
//! critical sections.  Replies are *queued*, never written inline —
//! a slow peer stalls only its own buffer, not the loop.
//!
//! Each hosted server's obs registry gains `reactor.*` metrics:
//! `accept_errors`, `conns_accepted`, `conns_open`, `wakeups`
//! (kernel un-parks — the spin detector), and `busy_ns` (cumulative
//! CPU time of the reactor thread, shared across co-hosted servers).

use crate::net::poll::{thread_cpu_time_ns, Event, Poller, Waker};
use crate::obs::{Counter, Gauge, Registry};
use crate::rpc::session::{SessionDecoder, SessionEncoder, MAX_SESSION_SEND_BYTES};
use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Identifies one connection within a reactor (monotonic, never
/// reused).
pub type ConnId = u64;

/// What the handler wants done with the connection after a frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Keep the connection open.
    Continue,
    /// Flush what fits and hang up.
    Close,
}

/// Server logic driven by the reactor: one callback per complete
/// inbound frame.  Replies (zero or more frames) are queued on `out`.
pub trait FrameHandler: Send {
    /// A complete frame payload arrived on connection `conn`.
    fn on_frame(
        &mut self,
        conn: ConnId,
        out: &mut SessionEncoder,
        payload: &[u8],
    ) -> Action;

    /// Connection `conn` is gone (peer closed, error, or server
    /// hangup).  Default: nothing.
    fn on_close(&mut self, _conn: ConnId) {}
}

/// Upper bound on how long a shutdown flag can go unnoticed if its
/// owner forgets to [`Waker::wake`] the reactor.  Pure robustness: the
/// services always poke, so a parked reactor normally sees ~4 of
/// these ticks per second and nothing else.
const FALLBACK_WAIT: Duration = Duration::from_millis(250);

/// Poll tokens below this are listener slots (index into `servers`);
/// tokens at or above it are connections.
const CONN_BASE: u64 = 1 << 32;

/// Per-server `reactor.*` instruments, created in the server's own
/// obs registry by [`Reactor::add_server`].
struct SlotMetrics {
    accept_errors: Arc<Counter>,
    conns_accepted: Arc<Counter>,
    conns_open: Arc<Gauge>,
    wakeups: Arc<Counter>,
    busy_ns: Arc<Gauge>,
}

impl SlotMetrics {
    fn from_registry(reg: &Registry) -> SlotMetrics {
        SlotMetrics {
            accept_errors: reg.counter("reactor.accept_errors"),
            conns_accepted: reg.counter("reactor.conns_accepted"),
            conns_open: reg.gauge("reactor.conns_open"),
            wakeups: reg.counter("reactor.wakeups"),
            busy_ns: reg.gauge("reactor.busy_ns"),
        }
    }
}

/// One hosted server: its listener (until shutdown), handler, flag
/// and metrics.
struct ServerSlot {
    listener: Option<TcpListener>,
    handler: Box<dyn FrameHandler>,
    shutdown: Arc<AtomicBool>,
    open_conns: u64,
    metrics: SlotMetrics,
}

struct Conn {
    id: ConnId,
    server: usize,
    stream: TcpStream,
    dec: SessionDecoder,
    enc: SessionEncoder,
    /// Whether kernel-side write interest is currently registered.
    want_write: bool,
}

/// A readiness-driven event loop hosting one or more TCP servers on a
/// single thread ([`Reactor::run`] / [`Reactor::spawn`]).
///
/// Lifecycle: [`Reactor::build`], then [`Reactor::add_server`] for
/// each server, grab a [`Reactor::waker`], then [`Reactor::spawn`].
/// Each server stops when its own shutdown flag is set *and* the
/// waker is poked (or at the next [`FALLBACK_WAIT`] tick); the thread
/// exits when every hosted server has stopped.
pub struct Reactor {
    poll: Poller,
    servers: Vec<ServerSlot>,
    conns: HashMap<u64, Conn>,
    next_conn: u64,
}

impl Reactor {
    /// An empty reactor with no servers yet.
    pub fn build() -> io::Result<Reactor> {
        Ok(Reactor {
            poll: Poller::new()?,
            servers: Vec::new(),
            conns: HashMap::new(),
            next_conn: CONN_BASE,
        })
    }

    /// A handle that un-parks the loop from any thread.  Required
    /// after setting a server's shutdown flag; harmless at any other
    /// time.
    pub fn waker(&self) -> Waker {
        self.poll.waker()
    }

    /// Host `listener`'s connections on this reactor, dispatching
    /// frames to `handler`.  The listener is switched to nonblocking
    /// mode.  Setting `shutdown` (then waking) closes the listener
    /// and this server's connections without touching co-hosted
    /// servers.  `reactor.*` metrics are created in `registry`.
    pub fn add_server(
        &mut self,
        listener: TcpListener,
        handler: Box<dyn FrameHandler>,
        shutdown: Arc<AtomicBool>,
        registry: &Registry,
    ) -> io::Result<()> {
        listener.set_nonblocking(true)?;
        let token = self.servers.len() as u64;
        assert!(token < CONN_BASE, "too many servers on one reactor");
        self.poll.register(listener.as_raw_fd(), token, true, false)?;
        self.servers.push(ServerSlot {
            listener: Some(listener),
            handler,
            shutdown,
            open_conns: 0,
            metrics: SlotMetrics::from_registry(registry),
        });
        Ok(())
    }

    /// Run the event loop on the calling thread until every hosted
    /// server's shutdown flag is set; each server's connections are
    /// dropped as it stops, so blocked peers unblock with a
    /// connection error.
    pub fn run(mut self) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            self.reap_stopped();
            if self.servers.iter().all(|s| s.listener.is_none()) {
                break;
            }
            if let Err(e) = self.poll.wait(&mut events, Some(FALLBACK_WAIT)) {
                // not expected on any supported platform; make sure a
                // persistent failure cannot become a hot error loop
                eprintln!("reactor: poll wait failed: {e}");
                std::thread::sleep(FALLBACK_WAIT);
                continue;
            }
            let busy = thread_cpu_time_ns();
            for slot in self.servers.iter().filter(|s| s.listener.is_some()) {
                slot.metrics.wakeups.inc();
                slot.metrics.busy_ns.set(busy);
            }
            for ev in events.drain(..) {
                if ev.token < CONN_BASE {
                    self.accept_burst(ev.token as usize);
                } else {
                    self.service_event(ev.token);
                }
            }
        }
    }

    /// Spawn a named thread running [`Reactor::run`].
    pub fn spawn(self, name: &str) -> io::Result<std::thread::JoinHandle<()>> {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || self.run())
    }

    /// Tear down every server whose shutdown flag is set.
    fn reap_stopped(&mut self) {
        for idx in 0..self.servers.len() {
            if self.servers[idx].listener.is_some()
                && self.servers[idx].shutdown.load(Ordering::SeqCst)
            {
                self.teardown_server(idx);
            }
        }
    }

    fn teardown_server(&mut self, idx: usize) {
        if let Some(listener) = self.servers[idx].listener.take() {
            let _ = self.poll.deregister(listener.as_raw_fd());
        }
        let doomed: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.server == idx)
            .map(|(&t, _)| t)
            .collect();
        for token in doomed {
            self.close_conn(token);
        }
    }

    /// Hang up on a connection and notify its server's handler.
    fn close_conn(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            let _ = self.poll.deregister(conn.stream.as_raw_fd());
            let _ = conn.stream.shutdown(Shutdown::Both);
            let slot = &mut self.servers[conn.server];
            slot.open_conns = slot.open_conns.saturating_sub(1);
            slot.metrics.conns_open.set(slot.open_conns);
            slot.handler.on_close(conn.id);
        }
    }

    /// Accept every pending connection on server `idx`'s listener.
    fn accept_burst(&mut self, idx: usize) {
        loop {
            let slot = &mut self.servers[idx];
            let Some(listener) = slot.listener.as_ref() else {
                return;
            };
            match listener.accept() {
                Ok((stream, _)) => {
                    // a stream we cannot switch to nonblocking mode
                    // would wedge the whole loop on its first read:
                    // close it *explicitly* and count the failure
                    // (PR 8 satellite — this used to be a silent
                    // `continue` that leaked the stream to Drop)
                    if stream.set_nonblocking(true).is_err() {
                        slot.metrics.accept_errors.inc();
                        let _ = stream.shutdown(Shutdown::Both);
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    let token = self.next_conn;
                    self.next_conn += 1;
                    if self.poll.register(stream.as_raw_fd(), token, true, false).is_err() {
                        slot.metrics.accept_errors.inc();
                        let _ = stream.shutdown(Shutdown::Both);
                        continue;
                    }
                    slot.open_conns += 1;
                    slot.metrics.conns_open.set(slot.open_conns);
                    slot.metrics.conns_accepted.inc();
                    self.conns.insert(
                        token,
                        Conn {
                            id: token,
                            server: idx,
                            stream,
                            dec: SessionDecoder::new(),
                            enc: SessionEncoder::new(),
                            want_write: false,
                        },
                    );
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    // fatal listener error (EMFILE, ENFILE, …): count
                    // it instead of swallowing it (PR 8 satellite —
                    // this used to be a bare `break`).  The listener
                    // stays level-triggered-ready while the condition
                    // persists, so back off briefly rather than spin.
                    slot.metrics.accept_errors.inc();
                    std::thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
    }

    /// Service a readiness event for one connection.
    fn service_event(&mut self, token: u64) {
        let keep = {
            let Reactor { conns, servers, poll, .. } = self;
            let Some(conn) = conns.get_mut(&token) else {
                return;
            };
            let slot = &mut servers[conn.server];
            let mut keep = service_conn(conn, slot.handler.as_mut());
            if keep {
                // keep kernel write interest in sync with whether
                // outbound bytes are queued, so the loop parks until
                // the peer's socket can make progress
                let want = !conn.enc.is_empty();
                if want != conn.want_write {
                    let fd = conn.stream.as_raw_fd();
                    if poll.modify(fd, token, true, want).is_ok() {
                        conn.want_write = want;
                    } else {
                        keep = false;
                    }
                }
            }
            keep
        };
        if !keep {
            self.close_conn(token);
        }
    }
}

/// Flush, read, decode, dispatch for one connection.  Returns `false`
/// when the connection should be closed.
fn service_conn(conn: &mut Conn, handler: &mut dyn FrameHandler) -> bool {
    // drain what the socket will take of earlier replies
    if conn.enc.flush_into(&mut conn.stream).is_err() {
        return false;
    }
    // read whatever chunk has arrived; frames are extracted as they
    // complete so inbound buffering never exceeds one frame
    let mut buf = [0u8; 16 * 1024];
    loop {
        match conn.stream.read(&mut buf) {
            Ok(0) => return false,
            Ok(n) => {
                conn.dec.feed(&buf[..n]);
                loop {
                    match conn.dec.next_frame() {
                        Ok(Some(payload)) => {
                            let action = handler.on_frame(conn.id, &mut conn.enc, &payload);
                            if action == Action::Close {
                                // best-effort flush of the final reply
                                let _ = conn.enc.flush_into(&mut conn.stream);
                                return false;
                            }
                        }
                        Ok(None) => break,
                        Err(_) => {
                            // framing violation (oversized header):
                            // the stream is garbage — hang up
                            return false;
                        }
                    }
                }
                if n < buf.len() {
                    break; // socket likely drained
                }
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    // push replies queued by this event's frames
    if conn.enc.flush_into(&mut conn.stream).is_err() {
        return false;
    }
    // a peer that stopped draining its socket does not get to pin
    // server memory: cap the outbound buffer and hang up beyond it
    conn.enc.pending_bytes() <= MAX_SESSION_SEND_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ServiceId;
    use crate::rpc::{read_frame, Message, Transport};
    use std::io::Write;
    use std::sync::atomic::AtomicU64;
    use std::time::Instant;

    /// Deadline-bounded readiness wait (PR 8 satellite): polls
    /// `ready` every millisecond until it holds or `timeout` lapses,
    /// so a slow CI machine stretches the wait instead of flaking.
    fn wait_until(timeout: Duration, ready: impl Fn() -> bool) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            if ready() {
                return true;
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Echoes every frame back unchanged; counts closes.
    struct Echo {
        closes: Arc<AtomicU64>,
    }

    impl FrameHandler for Echo {
        fn on_frame(
            &mut self,
            _conn: ConnId,
            out: &mut SessionEncoder,
            payload: &[u8],
        ) -> Action {
            out.queue_payload(payload);
            Action::Continue
        }

        fn on_close(&mut self, _conn: ConnId) {
            self.closes.fetch_add(1, Ordering::SeqCst);
        }
    }

    struct EchoServer {
        addr: std::net::SocketAddr,
        shutdown: Arc<AtomicBool>,
        waker: Waker,
        closes: Arc<AtomicU64>,
        registry: Arc<Registry>,
        handle: std::thread::JoinHandle<()>,
    }

    impl EchoServer {
        /// Flag + wake + join: the post-PR-8 shutdown contract.
        fn stop(self) {
            self.shutdown.store(true, Ordering::SeqCst);
            self.waker.wake();
            self.handle.join().unwrap();
        }
    }

    fn start_echo() -> EchoServer {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shutdown = Arc::new(AtomicBool::new(false));
        let closes = Arc::new(AtomicU64::new(0));
        let registry = Arc::new(Registry::new());
        let mut reactor = Reactor::build().unwrap();
        reactor
            .add_server(
                listener,
                Box::new(Echo { closes: closes.clone() }),
                shutdown.clone(),
                &registry,
            )
            .unwrap();
        let waker = reactor.waker();
        let handle = reactor.spawn("test-reactor").unwrap();
        EchoServer { addr, shutdown, waker, closes, registry, handle }
    }

    #[test]
    fn echoes_frames_from_multiple_blocking_clients() {
        let srv = start_echo();
        let mut a = Transport::connect(srv.addr, Duration::from_secs(5)).unwrap();
        let mut b = Transport::connect(srv.addr, Duration::from_secs(5)).unwrap();
        for i in 0..5u32 {
            let msg = Message::Heartbeat {
                service: ServiceId(i as usize),
                busy_ns: 0,
                cache_hits: 0,
                cache_misses: 0,
                tasks_done: 0,
            };
            assert_eq!(a.request(&msg).unwrap().encode(), msg.encode());
            let msg = Message::NoTask { done: i % 2 == 0 };
            assert_eq!(b.request(&msg).unwrap().encode(), msg.encode());
        }
        drop(a);
        drop(b);
        // the reactor notices both hangups
        let closes = srv.closes.clone();
        assert!(
            wait_until(Duration::from_secs(10), || {
                closes.load(Ordering::SeqCst) >= 2
            }),
            "reactor never noticed the client hangups"
        );
        assert_eq!(srv.closes.load(Ordering::SeqCst), 2);
        srv.stop();
    }

    /// The tentpole property at the socket level: a client dribbling
    /// one byte at a time (split length prefix included) still gets a
    /// complete, correct reply.
    #[test]
    fn one_byte_writes_reassemble_into_frames() {
        let srv = start_echo();
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let msg = Message::Join {
            name: "dribbler".into(),
            version: crate::rpc::PROTOCOL_VERSION,
            mem_budget: 0,
        };
        let payload = msg.encode();
        let mut wire = (payload.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&payload);
        for byte in &wire {
            stream.write_all(std::slice::from_ref(byte)).unwrap();
            stream.flush().unwrap();
        }
        let reply = read_frame(&mut stream).unwrap();
        assert_eq!(reply.encode(), payload);
        srv.stop();
    }

    /// Shutdown (flag + waker) drops open connections so blocked
    /// clients unblock.
    #[test]
    fn shutdown_drops_connections() {
        let srv = start_echo();
        let mut c = Transport::connect(srv.addr, Duration::from_secs(5)).unwrap();
        let msg = Message::LeaveAck;
        assert!(c.request(&msg).is_ok());
        let closes = srv.closes.clone();
        srv.stop();
        // the open connection was torn down and its close was
        // reported to the handler; the next round trip fails
        assert_eq!(closes.load(Ordering::SeqCst), 1);
        assert!(c.request(&msg).is_err());
    }

    /// Robustness: even *without* the waker poke, a set shutdown flag
    /// is noticed at the next fallback tick, bounded by
    /// [`FALLBACK_WAIT`] — a misbehaving owner gets a slow stop, not
    /// a stuck thread.
    #[test]
    fn shutdown_flag_alone_lands_at_the_fallback_tick() {
        let EchoServer { addr, shutdown, handle, .. } = start_echo();
        let mut c = Transport::connect(addr, Duration::from_secs(5)).unwrap();
        assert!(c.request(&Message::LeaveAck).is_ok());
        shutdown.store(true, Ordering::SeqCst);
        // no wake() on purpose
        handle.join().unwrap();
        assert!(c.request(&Message::LeaveAck).is_err());
    }

    /// A corrupt length header (beyond MAX_FRAME_BYTES) gets the
    /// connection dropped, not a hung or confused server.
    #[test]
    fn oversized_header_hangs_up() {
        let srv = start_echo();
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        stream.write_all(&[0xFF, 0xFF, 0xFF, 0xFF, 0x00]).unwrap();
        // the server hangs up: the next read sees EOF/reset
        let mut sink = [0u8; 8];
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match stream.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            assert!(Instant::now() < deadline, "server never hung up");
        }
        let closes = srv.closes.clone();
        assert!(
            wait_until(Duration::from_secs(10), || {
                closes.load(Ordering::SeqCst) >= 1
            }),
            "close was never reported to the handler"
        );
        srv.stop();
    }

    /// The PR 8 idle-cost regression proof at unit-test scale: with
    /// k parked connections and no traffic, the reactor thread takes
    /// only its ~4 Hz fallback ticks (the 500 µs spin loop it
    /// replaces would log ~1200 wakeups over the same window) and
    /// burns a negligible slice of CPU.  Wall-clock based — no
    /// ManualClock — because the claim is about the real kernel
    /// parking the real thread.
    #[test]
    fn idle_connections_accumulate_no_busy_time() {
        let srv = start_echo();
        let mut conns: Vec<Transport> = (0..8)
            .map(|_| Transport::connect(srv.addr, Duration::from_secs(5)).unwrap())
            .collect();
        // one round trip per connection so all eight are registered
        for c in conns.iter_mut() {
            c.request(&Message::LeaveAck).unwrap();
        }
        let snap0 = srv.registry.snapshot();
        let busy0 = snap0.gauge("reactor.busy_ns").unwrap_or(0);
        let wakeups0 = snap0.counter("reactor.wakeups").unwrap_or(0);
        std::thread::sleep(Duration::from_millis(600));
        // one probe round trip refreshes the busy gauge
        conns[0].request(&Message::LeaveAck).unwrap();
        let snap1 = srv.registry.snapshot();
        assert_eq!(snap1.gauge("reactor.conns_open"), Some(8));
        let wakeups = snap1.counter("reactor.wakeups").unwrap_or(0) - wakeups0;
        let busy = snap1.gauge("reactor.busy_ns").unwrap_or(0).saturating_sub(busy0);
        assert!(
            wakeups <= 60,
            "reactor woke {wakeups} times across a ~600 ms idle window — busy-polling?"
        );
        assert!(
            busy < 200_000_000,
            "reactor burned {busy} ns of CPU across a ~600 ms idle window"
        );
        srv.stop();
    }

    /// Two servers hosted on one reactor thread stop independently:
    /// shutting one down leaves the other serving, and the thread
    /// exits only when both are gone.
    #[test]
    fn two_servers_share_one_reactor() {
        let la = TcpListener::bind("127.0.0.1:0").unwrap();
        let lb = TcpListener::bind("127.0.0.1:0").unwrap();
        let (addr_a, addr_b) = (la.local_addr().unwrap(), lb.local_addr().unwrap());
        let (shut_a, shut_b) = (
            Arc::new(AtomicBool::new(false)),
            Arc::new(AtomicBool::new(false)),
        );
        let closes_a = Arc::new(AtomicU64::new(0));
        let closes_b = Arc::new(AtomicU64::new(0));
        let (reg_a, reg_b) = (Registry::new(), Registry::new());
        let mut reactor = Reactor::build().unwrap();
        reactor
            .add_server(la, Box::new(Echo { closes: closes_a.clone() }), shut_a.clone(), &reg_a)
            .unwrap();
        reactor
            .add_server(lb, Box::new(Echo { closes: closes_b.clone() }), shut_b.clone(), &reg_b)
            .unwrap();
        let waker = reactor.waker();
        let handle = reactor.spawn("test-shared-reactor").unwrap();

        let mut ca = Transport::connect(addr_a, Duration::from_secs(5)).unwrap();
        let mut cb = Transport::connect(addr_b, Duration::from_secs(5)).unwrap();
        assert!(ca.request(&Message::LeaveAck).is_ok());
        assert!(cb.request(&Message::LeaveAck).is_ok());

        // stop server A only
        shut_a.store(true, Ordering::SeqCst);
        waker.wake();
        assert!(
            wait_until(Duration::from_secs(10), || {
                closes_a.load(Ordering::SeqCst) >= 1
            }),
            "server A's connection was not torn down"
        );
        assert!(ca.request(&Message::LeaveAck).is_err(), "server A still serving");
        // server B is untouched: the old connection still works and
        // new ones are accepted
        assert!(cb.request(&Message::LeaveAck).is_ok());
        let mut cb2 = Transport::connect(addr_b, Duration::from_secs(5)).unwrap();
        assert!(cb2.request(&Message::NoTask { done: true }).is_ok());

        // stopping B ends the shared thread
        shut_b.store(true, Ordering::SeqCst);
        waker.wake();
        handle.join().unwrap();
    }
}
