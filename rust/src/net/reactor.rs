//! A std-only readiness-driven event loop for the TCP servers.
//!
//! The first two PRs ran every server connection on its own blocking
//! OS thread — faithful to the paper's RMI era, but a coordinator
//! burning one thread per match worker tops out at a few dozen nodes.
//! This reactor replaces that model: **one thread serves every
//! connection of a server**, polling nonblocking sockets in a level-
//! triggered loop (the same shape as a mio/epoll reactor, but built on
//! nothing outside `std` — `WouldBlock` *is* the readiness signal).
//!
//! Per tick the reactor:
//!
//! 1. accepts every pending connection on the nonblocking listener;
//! 2. for each connection, drains writable bytes from its
//!    [`SessionEncoder`], reads whatever chunk the kernel has
//!    (possibly half a length prefix), feeds it to the
//!    [`SessionDecoder`], and hands every completed frame to the
//!    server's [`FrameHandler`];
//! 3. drops connections that closed, errored, violated framing
//!    (oversized length header) or exceeded the outbound buffer cap
//!    ([`MAX_SESSION_SEND_BYTES`]);
//! 4. sleeps briefly only when no byte moved anywhere, so an idle
//!    server costs microseconds and a busy one runs flat out.
//!
//! Handlers run on the reactor thread and must not block; the
//! workflow/data handlers only touch in-memory state behind short
//! critical sections.  Replies are *queued*, never written inline —
//! a slow peer stalls only its own buffer, not the loop.

use crate::rpc::session::{
    SessionDecoder, SessionEncoder, MAX_SESSION_SEND_BYTES,
};
use std::io::{self, Read};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Identifies one connection within a reactor (monotonic, never
/// reused).
pub type ConnId = u64;

/// What the handler wants done with the connection after a frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Keep the connection open.
    Continue,
    /// Flush what fits and hang up.
    Close,
}

/// Server logic driven by the reactor: one callback per complete
/// inbound frame.  Replies (zero or more frames) are queued on `out`.
pub trait FrameHandler: Send {
    /// A complete frame payload arrived on connection `conn`.
    fn on_frame(
        &mut self,
        conn: ConnId,
        out: &mut SessionEncoder,
        payload: &[u8],
    ) -> Action;

    /// Connection `conn` is gone (peer closed, error, or server
    /// hangup).  Default: nothing.
    fn on_close(&mut self, _conn: ConnId) {}
}

struct Conn {
    id: ConnId,
    stream: TcpStream,
    dec: SessionDecoder,
    enc: SessionEncoder,
    open: bool,
}

/// One listener + its connections + the server's handler, executed by
/// a single thread ([`Reactor::run`] / [`Reactor::spawn`]).
pub struct Reactor<H: FrameHandler> {
    listener: TcpListener,
    handler: H,
    shutdown: Arc<AtomicBool>,
    conns: Vec<Conn>,
    next_id: ConnId,
}

/// Sleep between ticks when no byte moved anywhere (level-triggered
/// polling needs no wakeup channel; this bounds idle CPU at a few
/// thousand cheap syscalls per second while adding well under a
/// millisecond of request latency).
const IDLE_SLEEP: Duration = Duration::from_micros(500);

impl<H: FrameHandler> Reactor<H> {
    /// Wrap an already-bound listener.  The listener is switched to
    /// nonblocking mode; `shutdown` stops [`Reactor::run`] at the next
    /// tick (no wakeup poke needed — the loop polls).
    pub fn new(
        listener: TcpListener,
        handler: H,
        shutdown: Arc<AtomicBool>,
    ) -> io::Result<Reactor<H>> {
        listener.set_nonblocking(true)?;
        Ok(Reactor {
            listener,
            handler,
            shutdown,
            conns: Vec::new(),
            next_id: 0,
        })
    }

    /// Run the event loop on the calling thread until the shutdown
    /// flag is set; every open connection is dropped on exit, so
    /// blocked peers unblock with a connection error.
    pub fn run(mut self) {
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            if !self.tick() {
                std::thread::sleep(IDLE_SLEEP);
            }
        }
        for conn in &self.conns {
            let _ = conn.stream.shutdown(Shutdown::Both);
        }
    }

    /// Spawn a named thread running [`Reactor::run`].
    pub fn spawn(
        self,
        name: &str,
    ) -> io::Result<std::thread::JoinHandle<()>>
    where
        H: 'static,
    {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || self.run())
    }

    /// One pass over listener + connections; `true` if any byte moved.
    fn tick(&mut self) -> bool {
        let mut progressed = false;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    progressed = true;
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    let id = self.next_id;
                    self.next_id += 1;
                    self.conns.push(Conn {
                        id,
                        stream,
                        dec: SessionDecoder::new(),
                        enc: SessionEncoder::new(),
                        open: true,
                    });
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                    break;
                }
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => {
                    continue;
                }
                Err(_) => break,
            }
        }
        let Reactor { conns, handler, .. } = self;
        for conn in conns.iter_mut() {
            if conn.open {
                progressed |= service_conn(conn, handler);
            }
        }
        conns.retain(|c| c.open);
        progressed
    }
}

/// Hang up on `conn` (idempotent) and notify the handler.
fn close_conn<H: FrameHandler>(conn: &mut Conn, handler: &mut H) {
    if conn.open {
        conn.open = false;
        let _ = conn.stream.shutdown(Shutdown::Both);
        handler.on_close(conn.id);
    }
}

/// Flush, read, decode, dispatch for one connection.  Returns `true`
/// if any byte moved.
fn service_conn<H: FrameHandler>(conn: &mut Conn, handler: &mut H) -> bool {
    let mut progressed = false;
    // drain what the socket will take of earlier replies
    match conn.enc.flush_into(&mut conn.stream) {
        Ok(n) => progressed |= n > 0,
        Err(_) => {
            close_conn(conn, handler);
            return progressed;
        }
    }
    // read whatever chunk has arrived; frames are extracted as they
    // complete so inbound buffering never exceeds one frame
    let mut buf = [0u8; 16 * 1024];
    loop {
        match conn.stream.read(&mut buf) {
            Ok(0) => {
                close_conn(conn, handler);
                return progressed;
            }
            Ok(n) => {
                progressed = true;
                conn.dec.feed(&buf[..n]);
                loop {
                    match conn.dec.next_frame() {
                        Ok(Some(payload)) => {
                            let action = handler.on_frame(
                                conn.id,
                                &mut conn.enc,
                                &payload,
                            );
                            if action == Action::Close {
                                // best-effort flush of the final reply
                                let _ = conn
                                    .enc
                                    .flush_into(&mut conn.stream);
                                close_conn(conn, handler);
                                return true;
                            }
                        }
                        Ok(None) => break,
                        Err(_) => {
                            // framing violation (oversized header):
                            // the stream is garbage — hang up
                            close_conn(conn, handler);
                            return true;
                        }
                    }
                }
                if n < buf.len() {
                    break; // socket likely drained
                }
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => {
                continue;
            }
            Err(_) => {
                close_conn(conn, handler);
                return progressed;
            }
        }
    }
    // push replies queued by this tick's frames
    match conn.enc.flush_into(&mut conn.stream) {
        Ok(n) => progressed |= n > 0,
        Err(_) => close_conn(conn, handler),
    }
    // a peer that stopped draining its socket does not get to pin
    // server memory: cap the outbound buffer and hang up beyond it
    if conn.open && conn.enc.pending_bytes() > MAX_SESSION_SEND_BYTES {
        close_conn(conn, handler);
    }
    progressed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::ServiceId;
    use crate::rpc::{read_frame, Message, Transport};
    use std::io::Write;

    /// Echoes every frame back unchanged; counts closes.
    struct Echo {
        closes: Arc<std::sync::atomic::AtomicU64>,
    }

    impl FrameHandler for Echo {
        fn on_frame(
            &mut self,
            _conn: ConnId,
            out: &mut SessionEncoder,
            payload: &[u8],
        ) -> Action {
            out.queue_payload(payload);
            Action::Continue
        }

        fn on_close(&mut self, _conn: ConnId) {
            self.closes.fetch_add(1, Ordering::SeqCst);
        }
    }

    fn start_echo() -> (
        std::net::SocketAddr,
        Arc<AtomicBool>,
        Arc<std::sync::atomic::AtomicU64>,
        std::thread::JoinHandle<()>,
    ) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shutdown = Arc::new(AtomicBool::new(false));
        let closes = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let reactor = Reactor::new(
            listener,
            Echo {
                closes: closes.clone(),
            },
            shutdown.clone(),
        )
        .unwrap();
        let handle = reactor.spawn("test-reactor").unwrap();
        (addr, shutdown, closes, handle)
    }

    #[test]
    fn echoes_frames_from_multiple_blocking_clients() {
        let (addr, shutdown, closes, handle) = start_echo();
        let mut a = Transport::connect(addr, Duration::from_secs(5))
            .unwrap();
        let mut b = Transport::connect(addr, Duration::from_secs(5))
            .unwrap();
        for i in 0..5u32 {
            let msg = Message::Heartbeat {
                service: ServiceId(i as usize),
                busy_ns: 0,
                cache_hits: 0,
                cache_misses: 0,
                tasks_done: 0,
            };
            assert_eq!(a.request(&msg).unwrap().encode(), msg.encode());
            let msg = Message::NoTask { done: i % 2 == 0 };
            assert_eq!(b.request(&msg).unwrap().encode(), msg.encode());
        }
        drop(a);
        drop(b);
        // the reactor notices both hangups
        let deadline =
            std::time::Instant::now() + Duration::from_secs(5);
        while closes.load(Ordering::SeqCst) < 2
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(closes.load(Ordering::SeqCst), 2);
        shutdown.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    /// The tentpole property at the socket level: a client dribbling
    /// one byte at a time (split length prefix included) still gets a
    /// complete, correct reply.
    #[test]
    fn one_byte_writes_reassemble_into_frames() {
        let (addr, shutdown, _closes, handle) = start_echo();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let msg = Message::Join {
            name: "dribbler".into(),
            version: crate::rpc::PROTOCOL_VERSION,
            mem_budget: 0,
        };
        let payload = msg.encode();
        let mut wire =
            (payload.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&payload);
        for byte in &wire {
            stream.write_all(std::slice::from_ref(byte)).unwrap();
            stream.flush().unwrap();
        }
        let reply = read_frame(&mut stream).unwrap();
        assert_eq!(reply.encode(), payload);
        shutdown.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    /// Shutdown drops open connections so blocked clients unblock.
    #[test]
    fn shutdown_drops_connections() {
        let (addr, shutdown, _closes, handle) = start_echo();
        let mut c = Transport::connect(addr, Duration::from_secs(5))
            .unwrap();
        let msg = Message::LeaveAck;
        assert!(c.request(&msg).is_ok());
        shutdown.store(true, Ordering::SeqCst);
        handle.join().unwrap();
        // the next round trip fails: server gone
        assert!(c.request(&msg).is_err());
    }

    /// A corrupt length header (beyond MAX_FRAME_BYTES) gets the
    /// connection dropped, not a hung or confused server.
    #[test]
    fn oversized_header_hangs_up() {
        let (addr, shutdown, closes, handle) = start_echo();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        stream.write_all(&[0xFF, 0xFF, 0xFF, 0xFF, 0x00]).unwrap();
        // the server hangs up: the next read sees EOF/reset
        let mut sink = [0u8; 8];
        let deadline =
            std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match stream.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            assert!(
                std::time::Instant::now() < deadline,
                "server never hung up"
            );
        }
        let deadline =
            std::time::Instant::now() + Duration::from_secs(5);
        while closes.load(Ordering::SeqCst) < 1
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(closes.load(Ordering::SeqCst), 1);
        shutdown.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }
}
