//! A thin, std-only readiness shim over the kernel's `poll(2)` /
//! `epoll` interfaces (PR 8).
//!
//! The reactor in [`crate::net::reactor`] needs exactly four things
//! from the OS: "tell me when any of these sockets is readable or
//! writable", "park me until then", "let another thread un-park me",
//! and "how much CPU time has this thread burned" (for the idle-cost
//! regression proof). None of that exists in std, so this module
//! declares the handful of C entry points directly — no `libc` crate,
//! in keeping with the zero-dependency rule.
//!
//! Two backends, chosen at compile time:
//!
//! * **Linux:** `epoll` (O(ready) wakeups, interest set lives in the
//!   kernel) with an `eventfd` wakeup.
//! * **Other unixes:** classic `poll(2)` over a registration table
//!   rebuilt per wait, with a nonblocking self-pipe wakeup.
//!
//! Both backends present the same [`Poller`] API and are
//! level-triggered: an event keeps firing while the condition holds,
//! so the reactor never needs to drain a socket "just in case". The
//! wakeup fd is internal — a [`Waker::wake`] un-parks
//! [`Poller::wait`] but is never surfaced as an [`Event`].

use std::io;
use std::os::fd::RawFd;
use std::sync::Arc;
use std::time::Duration;

/// Raw C declarations. Constants are per-OS where the ABIs diverge.
mod ffi {
    use std::os::fd::RawFd;

    #[cfg(not(target_os = "linux"))]
    #[repr(C)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    extern "C" {
        pub fn read(fd: RawFd, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: RawFd, buf: *const u8, count: usize) -> isize;
        pub fn close(fd: RawFd) -> i32;
        pub fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
    }

    #[cfg(not(target_os = "linux"))]
    extern "C" {
        // `nfds_t` is `unsigned long` on Linux and `unsigned int` on
        // the BSDs; `usize` passes cleanly through the 64-bit calling
        // convention on every platform this backend compiles for.
        pub fn poll(fds: *mut PollFd, nfds: usize, timeout: i32) -> i32;
        pub fn pipe(fds: *mut RawFd) -> i32;
        pub fn fcntl(fd: RawFd, cmd: i32, arg: i32) -> i32;
    }

    // epoll_event is packed on x86-64 (a kernel ABI quirk); fields
    // must only ever be read by value, never by reference.
    #[cfg(target_os = "linux")]
    #[derive(Clone, Copy)]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    #[cfg(target_os = "linux")]
    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: RawFd, op: i32, fd: RawFd, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(
            epfd: RawFd,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout: i32,
        ) -> i32;
        pub fn eventfd(initval: u32, flags: i32) -> i32;
    }
}

#[cfg(target_os = "linux")]
mod consts {
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EFD_NONBLOCK: i32 = 0o4000;
    pub const EFD_CLOEXEC: i32 = 0o2000000;
    pub const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    /// epoll_wait can't report more than this many events per call;
    /// anything beyond it surfaces on the next call (level-triggered).
    pub const MAX_EVENTS: usize = 256;
}

#[cfg(not(target_os = "linux"))]
mod consts {
    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;
    pub const F_GETFL: i32 = 3;
    pub const F_SETFL: i32 = 4;
    /// macOS / BSD value (this backend never compiles on Linux).
    pub const O_NONBLOCK: i32 = 0x0004;
    /// `CLOCK_THREAD_CPUTIME_ID` on macOS.
    pub const CLOCK_THREAD_CPUTIME_ID: i32 = 16;
}

use consts::*;

/// Internal token for the wakeup fd; [`Poller::register`] rejects it.
const WAKER_TOKEN: u64 = u64::MAX;

fn cvt(rc: i32) -> io::Result<i32> {
    if rc < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(rc)
    }
}

fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) if d.is_zero() => 0,
        // clamp sub-millisecond timeouts *up* so a 100µs deadline
        // can't degenerate into a zero-timeout spin loop
        Some(d) => d.as_millis().clamp(1, i32::MAX as u128) as i32,
    }
}

// ---------------------------------------------------------------- waker

/// An fd that closes itself when the last clone drops.
struct WakeFd(RawFd);

impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe {
            ffi::close(self.0);
        }
    }
}

/// Un-parks a [`Poller::wait`] from any thread.
///
/// Cheap to clone and safe to fire at any time: waking an idle poller
/// makes its next `wait` return immediately with no events, waking a
/// busy one is a no-op. This replaces the PR 3 contract of "no poke
/// needed, the loop polls" — a parked reactor *must* be poked when a
/// shutdown flag flips.
#[derive(Clone)]
pub struct Waker {
    fd: Arc<WakeFd>,
}

// The wrapped fd is only ever written to (wake) or read from (drain);
// both are safe concurrently.
unsafe impl Send for Waker {}
unsafe impl Sync for Waker {}

impl Waker {
    /// Make the paired [`Poller::wait`] return now (or immediately,
    /// if it is not currently parked). Errors are ignored: a full
    /// pipe / saturated eventfd already has a wakeup pending.
    pub fn wake(&self) {
        // 8 bytes covers both backends: eventfd requires a u64
        // counter increment, a pipe just needs any byte in it
        let one: u64 = 1;
        let buf = one.to_ne_bytes();
        unsafe {
            ffi::write(self.fd.0, buf.as_ptr(), buf.len());
        }
    }
}

/// Drain a nonblocking wakeup fd until it would block.
fn drain_wake_fd(fd: RawFd) {
    let mut buf = [0u8; 64];
    loop {
        let n = unsafe { ffi::read(fd, buf.as_mut_ptr(), buf.len()) };
        // <= 0 means EAGAIN (drained), EOF, or error — nothing left
        // to read either way; a short read means the pipe is empty too
        if n <= 0 || (n as usize) < buf.len() {
            break;
        }
    }
}

// ---------------------------------------------------------------- event

/// One readiness notification from [`Poller::wait`].
///
/// Error/hangup conditions set *both* flags: whichever direction the
/// owner services next observes the failure from the socket itself (a
/// zero-length read, a broken-pipe write).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

// ---------------------------------------------------------------- poller

/// Readiness multiplexer: register fds under integer tokens, then
/// park in [`Poller::wait`] until the kernel reports one ready (or a
/// [`Waker`] fires, or the timeout lapses).
pub struct Poller {
    #[cfg(target_os = "linux")]
    epfd: WakeFd, // reuses the close-on-drop wrapper
    #[cfg(target_os = "linux")]
    ready: Vec<ffi::EpollEvent>,
    #[cfg(not(target_os = "linux"))]
    regs: Vec<Reg>,
    /// Drain side of the wakeup primitive (eventfd: the same fd the
    /// waker writes; pipe: the read end).
    wake_rx: Arc<WakeFd>,
    waker: Waker,
}

#[cfg(not(target_os = "linux"))]
struct Reg {
    fd: RawFd,
    token: u64,
    readable: bool,
    writable: bool,
}

impl Poller {
    /// A waker bound to this poller; clone freely across threads.
    pub fn waker(&self) -> Waker {
        self.waker.clone()
    }
}

#[cfg(target_os = "linux")]
impl Poller {
    pub fn new() -> io::Result<Poller> {
        let epfd = WakeFd(cvt(unsafe { ffi::epoll_create1(EPOLL_CLOEXEC) })?);
        let evfd = cvt(unsafe { ffi::eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        let wake_rx = Arc::new(WakeFd(evfd));
        let mut ev = ffi::EpollEvent { events: EPOLLIN, data: WAKER_TOKEN };
        cvt(unsafe { ffi::epoll_ctl(epfd.0, EPOLL_CTL_ADD, evfd, &mut ev) })?;
        Ok(Poller {
            epfd,
            ready: vec![ffi::EpollEvent { events: 0, data: 0 }; MAX_EVENTS],
            waker: Waker { fd: wake_rx.clone() },
            wake_rx,
        })
    }

    fn ctl(&mut self, op: i32, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
        let mut events = 0u32;
        if read {
            events |= EPOLLIN;
        }
        if write {
            events |= EPOLLOUT;
        }
        let mut ev = ffi::EpollEvent { events, data: token };
        cvt(unsafe { ffi::epoll_ctl(self.epfd.0, op, fd, &mut ev) })?;
        Ok(())
    }

    /// Start watching `fd` under `token`.
    pub fn register(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
        assert_ne!(token, WAKER_TOKEN, "token reserved for the waker");
        self.ctl(EPOLL_CTL_ADD, fd, token, read, write)
    }

    /// Change the interest set of an already-registered `fd`.
    pub fn modify(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, token, read, write)
    }

    /// Stop watching `fd`. Must happen before the fd is closed.
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        // pre-2.6.9 kernels reject a null event pointer for DEL
        let mut dummy = ffi::EpollEvent { events: 0, data: 0 };
        cvt(unsafe { ffi::epoll_ctl(self.epfd.0, EPOLL_CTL_DEL, fd, &mut dummy) })?;
        Ok(())
    }

    /// Park until readiness, wakeup, or timeout (`None` = forever).
    /// Fills `out` with ready tokens; empty on timeout/wakeup/EINTR.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let n = unsafe {
            ffi::epoll_wait(
                self.epfd.0,
                self.ready.as_mut_ptr(),
                self.ready.len() as i32,
                timeout_ms(timeout),
            )
        };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(err);
        }
        for slot in &self.ready[..n as usize] {
            // copy packed fields by value — never by reference
            let bits = { slot.events };
            let token = { slot.data };
            if token == WAKER_TOKEN {
                drain_wake_fd(self.wake_rx.0);
                continue;
            }
            let failed = bits & (EPOLLERR | EPOLLHUP) != 0;
            out.push(Event {
                token,
                readable: failed || bits & EPOLLIN != 0,
                writable: failed || bits & EPOLLOUT != 0,
            });
        }
        Ok(())
    }
}

#[cfg(not(target_os = "linux"))]
impl Poller {
    pub fn new() -> io::Result<Poller> {
        let mut fds = [0 as RawFd; 2];
        cvt(unsafe { ffi::pipe(fds.as_mut_ptr()) })?;
        let (rx, tx) = (WakeFd(fds[0]), WakeFd(fds[1]));
        for fd in [rx.0, tx.0] {
            let flags = cvt(unsafe { ffi::fcntl(fd, F_GETFL, 0) })?;
            cvt(unsafe { ffi::fcntl(fd, F_SETFL, flags | O_NONBLOCK) })?;
        }
        Ok(Poller {
            regs: Vec::new(),
            wake_rx: Arc::new(rx),
            waker: Waker { fd: Arc::new(tx) },
        })
    }

    /// Start watching `fd` under `token`.
    pub fn register(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
        assert_ne!(token, WAKER_TOKEN, "token reserved for the waker");
        if self.regs.iter().any(|r| r.fd == fd) {
            return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd already registered"));
        }
        self.regs.push(Reg { fd, token, readable: read, writable: write });
        Ok(())
    }

    /// Change the interest set of an already-registered `fd`.
    pub fn modify(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
        let reg = self
            .regs
            .iter_mut()
            .find(|r| r.fd == fd)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "fd not registered"))?;
        reg.token = token;
        reg.readable = read;
        reg.writable = write;
        Ok(())
    }

    /// Stop watching `fd`. Must happen before the fd is closed.
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        let before = self.regs.len();
        self.regs.retain(|r| r.fd != fd);
        if self.regs.len() == before {
            return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
        }
        Ok(())
    }

    /// Park until readiness, wakeup, or timeout (`None` = forever).
    /// Fills `out` with ready tokens; empty on timeout/wakeup/EINTR.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let mut fds = Vec::with_capacity(self.regs.len() + 1);
        fds.push(ffi::PollFd { fd: self.wake_rx.0, events: POLLIN, revents: 0 });
        for r in &self.regs {
            let mut events = 0i16;
            if r.readable {
                events |= POLLIN;
            }
            if r.writable {
                events |= POLLOUT;
            }
            fds.push(ffi::PollFd { fd: r.fd, events, revents: 0 });
        }
        let n = unsafe { ffi::poll(fds.as_mut_ptr(), fds.len(), timeout_ms(timeout)) };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(err);
        }
        if fds[0].revents & POLLIN != 0 {
            drain_wake_fd(self.wake_rx.0);
        }
        for (slot, r) in fds[1..].iter().zip(&self.regs) {
            let bits = slot.revents;
            if bits == 0 {
                continue;
            }
            let failed = bits & (POLLERR | POLLHUP | POLLNVAL) != 0;
            out.push(Event {
                token: r.token,
                readable: failed || bits & POLLIN != 0,
                writable: failed || bits & POLLOUT != 0,
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- clock

/// Cumulative CPU time of the *calling thread*, in nanoseconds
/// (`CLOCK_THREAD_CPUTIME_ID`). Returns 0 if the clock is
/// unavailable. A thread parked in [`Poller::wait`] accumulates
/// essentially none of it — the basis of the idle-cost regression
/// proof in the reactor tests and `benches/dist_overhead.rs`.
pub fn thread_cpu_time_ns() -> u64 {
    let mut ts = ffi::Timespec { tv_sec: 0, tv_nsec: 0 };
    let rc = unsafe { ffi::clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    if rc != 0 {
        return 0;
    }
    (ts.tv_sec as u64)
        .saturating_mul(1_000_000_000)
        .saturating_add(ts.tv_nsec as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::time::Instant;

    fn socket_pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[test]
    fn waker_interrupts_a_parked_wait() {
        let mut p = Poller::new().unwrap();
        let w = p.waker();
        let fired = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w.wake();
        });
        let mut events = Vec::new();
        let start = Instant::now();
        p.wait(&mut events, Some(Duration::from_secs(30))).unwrap();
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "waker failed to un-park the poller"
        );
        assert!(events.is_empty(), "wakeup must not surface as an event");
        fired.join().unwrap();
    }

    #[test]
    fn wakes_are_coalesced_and_drained() {
        let mut p = Poller::new().unwrap();
        let w = p.waker();
        for _ in 0..100 {
            w.wake();
        }
        let mut events = Vec::new();
        p.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.is_empty());
        // drained: a zero-timeout wait now sees nothing pending
        p.wait(&mut events, Some(Duration::ZERO)).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn readable_socket_surfaces_its_token() {
        let (mut a, b) = socket_pair();
        b.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.register(b.as_raw_fd(), 7, true, false).unwrap();
        a.write_all(b"ping").unwrap();
        let mut events = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            p.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                break;
            }
            assert!(Instant::now() < deadline, "readable event never arrived");
        }
        p.deregister(b.as_raw_fd()).unwrap();
        // after deregistering, pending bytes no longer produce events
        p.wait(&mut events, Some(Duration::ZERO)).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn write_interest_fires_for_an_idle_socket() {
        let (_a, b) = socket_pair();
        b.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.register(b.as_raw_fd(), 9, true, true).unwrap();
        let mut events = Vec::new();
        p.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        assert!(
            events.iter().any(|e| e.token == 9 && e.writable),
            "a fresh socket's send buffer is empty, so write interest must fire immediately"
        );
        // dropping write interest silences the idle socket again
        p.modify(b.as_raw_fd(), 9, true, false).unwrap();
        p.wait(&mut events, Some(Duration::ZERO)).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn thread_cpu_clock_advances_under_load() {
        let start = thread_cpu_time_ns();
        let mut acc = 0u64;
        let mut spins = 0u64;
        while thread_cpu_time_ns() < start + 10_000_000 {
            acc = std::hint::black_box(acc.wrapping_mul(0x9e37_79b9).wrapping_add(spins));
            spins += 1;
            assert!(spins < 200_000_000, "thread CPU clock never advanced");
        }
        assert!(thread_cpu_time_ns() >= start + 10_000_000);
        let _ = acc;
    }
}
