//! Blocking-based partitioning with partition tuning (paper §3.2).
//!
//! Blocking output blocks can differ wildly in size (Zipf-skewed keys),
//! which would make one-task-per-block parallelism useless: huge blocks
//! dominate execution time and exceed memory, tiny blocks drown the
//! system in scheduling overhead.  *Partition tuning* fixes both:
//!
//! 1. blocks larger than the memory-restricted maximum `max_size` are
//!    **split** into equally-sized sub-partitions (which must later be
//!    matched against each other — handled by [`super::task_gen`]);
//! 2. blocks smaller than `min_size` are **aggregated** into combined
//!    partitions of at most `max_size` (fewer tasks, at the cost of some
//!    unnecessary comparisons — the Fig. 7 trade-off);
//! 3. the *misc* block is carried over (split if oversized); its
//!    sub-partitions are matched against every other partition.

use super::{PartitionKind, PartitionSet};
use crate::blocking::Blocks;
use crate::model::EntityId;
use crate::util::div_ceil;

/// Tuning parameters: the §3.1 memory-restricted max plus the minimum
/// aggregation threshold ("size below some fraction of the maximal
/// partition size").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TuningConfig {
    pub max_size: usize,
    pub min_size: usize,
}

impl TuningConfig {
    pub fn new(max_size: usize, min_size: usize) -> TuningConfig {
        assert!(max_size >= 1, "max_size must be >= 1");
        assert!(
            min_size <= max_size,
            "min_size {min_size} > max_size {max_size}"
        );
        TuningConfig { max_size, min_size }
    }
}

/// Split one oversized id list into equally-sized chunks <= max.
fn split_evenly(ids: &[EntityId], max: usize) -> Vec<Vec<EntityId>> {
    let n = ids.len();
    let k = div_ceil(n, max);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut offset = 0;
    for i in 0..k {
        let size = base + usize::from(i < extra);
        out.push(ids[offset..offset + size].to_vec());
        offset += size;
    }
    out
}

/// Run partition tuning over blocking output.
pub fn tune(blocks: &Blocks, cfg: TuningConfig) -> PartitionSet {
    tune_split(blocks, cfg, cfg.max_size)
}

/// Partition tuning with a separate **split threshold** — the shared
/// core of [`tune`] and the BlockSplit strategy
/// ([`super::strategy::BlockSplit`]): blocks larger than `split_at`
/// are split into even sub-blocks of at most `split_at` entities
/// (and the misc block is sliced likewise), while aggregation of
/// undersized blocks still packs to `cfg.max_size`.  With
/// `split_at == cfg.max_size` this is exactly §3.2 tuning; a smaller
/// `split_at` reshapes the oversized blocks' tasks without changing
/// *which* blocks aggregate — so the covered pair set is identical.
/// Requires `cfg.min_size <= split_at <= cfg.max_size`.
pub(crate) fn tune_split(
    blocks: &Blocks,
    cfg: TuningConfig,
    split_at: usize,
) -> PartitionSet {
    debug_assert!(
        cfg.min_size <= split_at && split_at <= cfg.max_size,
        "split_at {split_at} outside [{}, {}]",
        cfg.min_size,
        cfg.max_size
    );
    let mut out = PartitionSet::new();

    // Pass 1: normal blocks — split the oversized, queue the undersized.
    let mut small: Vec<(&str, &[EntityId])> = Vec::new();
    for (key, ids) in blocks.iter() {
        if ids.is_empty() {
            continue;
        }
        if ids.len() > split_at {
            let parts = split_evenly(ids, split_at);
            let count = parts.len();
            for (index, chunk) in parts.into_iter().enumerate() {
                out.push(
                    PartitionKind::SubBlock {
                        key: key.to_string(),
                        index,
                        count,
                    },
                    chunk,
                );
            }
        } else if ids.len() < cfg.min_size {
            small.push((key, ids));
        } else {
            out.push(
                PartitionKind::Block {
                    key: key.to_string(),
                },
                ids.to_vec(),
            );
        }
    }

    // Pass 2: aggregate undersized blocks, first-fit over ascending size,
    // never exceeding max_size per aggregate.
    small.sort_by_key(|(key, ids)| (ids.len(), key.to_string()));
    let mut agg_ids: Vec<EntityId> = Vec::new();
    let mut agg_keys: Vec<String> = Vec::new();
    let flush = |out: &mut PartitionSet,
                 agg_ids: &mut Vec<EntityId>,
                 agg_keys: &mut Vec<String>| {
        if agg_ids.is_empty() {
            return;
        }
        if agg_keys.len() == 1 {
            // a lone small block stays a normal block
            out.push(
                PartitionKind::Block {
                    key: agg_keys[0].clone(),
                },
                std::mem::take(agg_ids),
            );
        } else {
            out.push(
                PartitionKind::Aggregate {
                    keys: std::mem::take(agg_keys),
                },
                std::mem::take(agg_ids),
            );
        }
        agg_keys.clear();
    };
    for (key, ids) in small {
        if agg_ids.len() + ids.len() > cfg.max_size {
            flush(&mut out, &mut agg_ids, &mut agg_keys);
        }
        agg_ids.extend_from_slice(ids);
        agg_keys.push(key.to_string());
        // an aggregate that reached min_size could also be closed here;
        // packing to max_size gives fewer tasks (paper favors fewer).
    }
    flush(&mut out, &mut agg_ids, &mut agg_keys);

    // Pass 3: misc block — carried over, split when oversized.
    let misc = blocks.misc();
    if !misc.is_empty() {
        let parts = if misc.len() > split_at {
            split_evenly(misc, split_at)
        } else {
            vec![misc.to_vec()]
        };
        let count = parts.len();
        for (index, chunk) in parts.into_iter().enumerate() {
            out.push(PartitionKind::Misc { index, count }, chunk);
        }
    }

    out
}

/// Partition tuning for **two sources** under the same blocking
/// (paper §3.3): the split/aggregate decisions are taken on the
/// *combined* block sizes and applied identically to both sides, so
/// corresponding partitions keep corresponding keys (an aggregate on
/// side A covers exactly the same key set as its counterpart on side B
/// — otherwise cross-source task generation could not align them).
pub fn tune_paired(
    blocks_a: &Blocks,
    blocks_b: &Blocks,
    cfg: TuningConfig,
) -> (PartitionSet, PartitionSet) {
    use std::collections::BTreeMap;
    // combined sizes per key
    let mut combined: BTreeMap<&str, usize> = BTreeMap::new();
    for (k, ids) in blocks_a.iter() {
        *combined.entry(k).or_default() += ids.len();
    }
    for (k, ids) in blocks_b.iter() {
        *combined.entry(k).or_default() += ids.len();
    }

    // grouping decision on combined sizes: small keys are packed into
    // shared aggregates (first-fit over ascending combined size)
    let mut small: Vec<(&str, usize)> = combined
        .iter()
        .filter(|(_, &s)| s < cfg.min_size)
        .map(|(&k, &s)| (k, s))
        .collect();
    small.sort_by_key(|&(k, s)| (s, k.to_string()));
    let mut groups: Vec<Vec<String>> = Vec::new();
    let mut cur: Vec<String> = Vec::new();
    let mut cur_size = 0usize;
    for (k, s) in small {
        if cur_size + s > cfg.max_size && !cur.is_empty() {
            groups.push(std::mem::take(&mut cur));
            cur_size = 0;
        }
        cur.push(k.to_string());
        cur_size += s;
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    let group_of: std::collections::HashMap<&str, usize> = groups
        .iter()
        .enumerate()
        .flat_map(|(gi, ks)| ks.iter().map(move |k| (k.as_str(), gi)))
        .collect();

    let build = |blocks: &Blocks| -> PartitionSet {
        let mut out = PartitionSet::new();
        let mut agg_members: Vec<Vec<EntityId>> =
            vec![Vec::new(); groups.len()];
        for (key, ids) in blocks.iter() {
            if ids.is_empty() {
                continue;
            }
            if let Some(&gi) = group_of.get(key) {
                agg_members[gi].extend_from_slice(ids);
            } else if ids.len() > cfg.max_size {
                let parts = split_evenly(ids, cfg.max_size);
                let count = parts.len();
                for (index, chunk) in parts.into_iter().enumerate() {
                    out.push(
                        PartitionKind::SubBlock {
                            key: key.to_string(),
                            index,
                            count,
                        },
                        chunk,
                    );
                }
            } else {
                out.push(
                    PartitionKind::Block {
                        key: key.to_string(),
                    },
                    ids.to_vec(),
                );
            }
        }
        for (gi, ids) in agg_members.into_iter().enumerate() {
            if ids.is_empty() {
                continue;
            }
            let mut keys = groups[gi].clone();
            keys.sort();
            out.push(PartitionKind::Aggregate { keys }, ids);
        }
        // misc per side, split when oversized
        let misc = blocks.misc();
        if !misc.is_empty() {
            let parts = if misc.len() > cfg.max_size {
                split_evenly(misc, cfg.max_size)
            } else {
                vec![misc.to_vec()]
            };
            let count = parts.len();
            for (index, chunk) in parts.into_iter().enumerate() {
                out.push(PartitionKind::Misc { index, count }, chunk);
            }
        }
        out
    };

    (build(blocks_a), build(blocks_b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::Blocks;
    use crate::model::EntityId;
    use crate::util::proptest::forall;

    /// Build Blocks with the given (key, size) pairs + misc size.
    fn make_blocks(sizes: &[(&str, usize)], misc: usize) -> Blocks {
        let mut b = Blocks::new();
        let mut next = 0u32;
        for (key, n) in sizes {
            for _ in 0..*n {
                b.add(key, EntityId(next));
                next += 1;
            }
        }
        for _ in 0..misc {
            b.add_misc(EntityId(next));
            next += 1;
        }
        b
    }

    /// The Figure 3 example: Drives & Storage, 3,600 products.
    /// Blocks: 3½=1300, 2½=700, DVD-RW=400, Blu-ray=200, HD-DVD=200,
    /// CD-RW=200; misc=600.  max=700, min=210 →
    /// split 3½ into 2×650; aggregate the three 200s into 600;
    /// keep 2½, DVD-RW; misc stays whole → 6 partitions.
    #[test]
    fn figure3_example() {
        let blocks = make_blocks(
            &[
                ("3.5-drive", 1300),
                ("2.5-drive", 700),
                ("dvd-rw", 400),
                ("blu-ray", 200),
                ("hd-dvd", 200),
                ("cd-rw", 200),
            ],
            600,
        );
        assert_eq!(blocks.total_entities(), 3600);
        let ps = tune(&blocks, TuningConfig::new(700, 210));
        assert_eq!(ps.len(), 6, "{:?}", ps.iter().map(|p| (&p.kind, p.len())).collect::<Vec<_>>());
        assert_eq!(ps.total_entities(), 3600);
        // split block: two sub-partitions of 650
        let subs: Vec<_> = ps
            .iter()
            .filter(|p| matches!(&p.kind, PartitionKind::SubBlock { key, .. } if key == "3.5-drive"))
            .collect();
        assert_eq!(subs.len(), 2);
        assert!(subs.iter().all(|p| p.len() == 650));
        // aggregate of the three smallest
        let aggs: Vec<_> = ps
            .iter()
            .filter(|p| matches!(p.kind, PartitionKind::Aggregate { .. }))
            .collect();
        assert_eq!(aggs.len(), 1);
        assert_eq!(aggs[0].len(), 600);
        if let PartitionKind::Aggregate { keys } = &aggs[0].kind {
            let mut k = keys.clone();
            k.sort();
            assert_eq!(k, vec!["blu-ray", "cd-rw", "hd-dvd"]);
        }
        // misc stays one partition of 600
        assert_eq!(ps.n_misc(), 1);
        assert_eq!(ps.get(ps.misc_ids()[0]).len(), 600);
    }

    #[test]
    fn no_tuning_when_everything_fits() {
        let blocks = make_blocks(&[("a", 300), ("b", 400)], 0);
        let ps = tune(&blocks, TuningConfig::new(700, 100));
        assert_eq!(ps.len(), 2);
        assert!(ps
            .iter()
            .all(|p| matches!(p.kind, PartitionKind::Block { .. })));
    }

    #[test]
    fn min_size_one_disables_aggregation() {
        // min_size = 1 → "no merging of small partitions" (Fig 7 x=1)
        let blocks = make_blocks(&[("a", 5), ("b", 3), ("c", 700)], 0);
        let ps = tune(&blocks, TuningConfig::new(700, 1));
        assert_eq!(ps.len(), 3);
        assert!(ps
            .iter()
            .all(|p| matches!(p.kind, PartitionKind::Block { .. })));
    }

    #[test]
    fn oversized_misc_is_split() {
        let blocks = make_blocks(&[("a", 100)], 1500);
        let ps = tune(&blocks, TuningConfig::new(700, 10));
        assert_eq!(ps.n_misc(), 3); // 1500 → 3 × 500
        for id in ps.misc_ids() {
            assert!(ps.get(id).len() <= 700);
        }
    }

    #[test]
    fn lone_small_block_stays_block() {
        let blocks = make_blocks(&[("tiny", 5), ("big", 500)], 0);
        let ps = tune(&blocks, TuningConfig::new(700, 210));
        // "tiny" has no aggregation partner; it must remain a Block, not
        // a 1-key Aggregate
        assert!(ps.iter().all(|p| !matches!(
            &p.kind,
            PartitionKind::Aggregate { keys } if keys.len() < 2
        )));
        assert_eq!(ps.len(), 2);
    }

    #[test]
    fn prop_tuning_preserves_entities_and_respects_max() {
        forall("tuning-invariants", 120, |rng| {
            // random block structure
            let n_blocks = 1 + rng.gen_range(20);
            let mut sizes = Vec::new();
            let names: Vec<String> =
                (0..n_blocks).map(|i| format!("b{i}")).collect();
            for name in &names {
                sizes.push((name.as_str(), 1 + rng.gen_range(1500)));
            }
            let misc = rng.gen_range(900);
            let blocks = make_blocks(&sizes, misc);
            let max_size = 50 + rng.gen_range(1000);
            let min_size = rng.gen_range(max_size / 2);
            let ps = tune(&blocks, TuningConfig::new(max_size, min_size));

            // entity preservation: exact same id multiset
            let mut got: Vec<u32> = ps
                .iter()
                .flat_map(|p| p.entities.iter().map(|e| e.0))
                .collect();
            got.sort_unstable();
            let expect: Vec<u32> =
                (0..blocks.total_entities() as u32).collect();
            assert_eq!(got, expect, "entities lost or duplicated");

            // max size respected by every partition
            assert!(ps.max_size() <= max_size);

            // sub-partitions of one key are balanced (±1)
            use std::collections::HashMap;
            let mut by_key: HashMap<&str, Vec<usize>> = HashMap::new();
            for p in ps.iter() {
                if let PartitionKind::SubBlock { key, .. } = &p.kind {
                    by_key.entry(key).or_default().push(p.len());
                }
            }
            for (k, sizes) in by_key {
                let (mn, mx) = (
                    *sizes.iter().min().unwrap(),
                    *sizes.iter().max().unwrap(),
                );
                assert!(mx - mn <= 1, "unbalanced split of {k}: {sizes:?}");
            }

            // entities from the same original block never split across
            // *aggregates* (only SubBlock splits are allowed)
            // — verified structurally: each key appears in exactly one
            // Block/Aggregate OR >=2 SubBlocks.
            let mut seen: HashMap<String, usize> = HashMap::new();
            for p in ps.iter() {
                match &p.kind {
                    PartitionKind::Block { key } => {
                        *seen.entry(key.clone()).or_default() += 1
                    }
                    PartitionKind::Aggregate { keys } => {
                        for k in keys {
                            *seen.entry(k.clone()).or_default() += 1;
                        }
                    }
                    _ => {}
                }
            }
            for (k, count) in seen {
                assert_eq!(count, 1, "key {k} in {count} partitions");
            }
        });
    }

    #[test]
    fn aggregates_never_exceed_max() {
        forall("agg-max", 60, |rng| {
            let n_blocks = 2 + rng.gen_range(30);
            let names: Vec<String> =
                (0..n_blocks).map(|i| format!("s{i}")).collect();
            let sizes: Vec<(&str, usize)> = names
                .iter()
                .map(|n| (n.as_str(), 1 + rng.gen_range(100)))
                .collect();
            let blocks = make_blocks(&sizes, 0);
            let ps = tune(&blocks, TuningConfig::new(150, 120));
            for p in ps.iter() {
                assert!(p.len() <= 150);
            }
        });
    }

    #[test]
    #[should_panic]
    fn invalid_config_rejected() {
        TuningConfig::new(100, 200);
    }

    #[test]
    fn paired_tuning_aligns_aggregates() {
        // sides with different per-key sizes must still aggregate the
        // SAME key groups
        let a = make_blocks(&[("x", 30), ("y", 10), ("z", 250)], 5);
        let b = make_blocks(&[("x", 5), ("y", 45), ("z", 240)], 0);
        let (pa, pb) = tune_paired(&a, &b, TuningConfig::new(300, 100));
        let agg_keys = |ps: &PartitionSet| -> Vec<Vec<String>> {
            ps.iter()
                .filter_map(|p| match &p.kind {
                    PartitionKind::Aggregate { keys } => {
                        let mut k = keys.clone();
                        k.sort();
                        Some(k)
                    }
                    _ => None,
                })
                .collect()
        };
        let (ka, kb) = (agg_keys(&pa), agg_keys(&pb));
        assert_eq!(ka, kb, "aggregate key groups must align");
        // combined x+y = 90 < min 100 → one shared aggregate {x, y}
        assert_eq!(ka, vec![vec!["x".to_string(), "y".to_string()]]);
        // z (combined 490) stays a block on both sides
        assert!(pa.iter().any(
            |p| matches!(&p.kind, PartitionKind::Block { key } if key == "z")
        ));
        // entity preservation per side
        assert_eq!(pa.total_entities(), a.total_entities());
        assert_eq!(pb.total_entities(), b.total_entities());
        assert_eq!(pa.n_misc(), 1);
        assert_eq!(pb.n_misc(), 0);
    }

    #[test]
    fn paired_tuning_splits_oversized_sides() {
        let a = make_blocks(&[("big", 900)], 0);
        let b = make_blocks(&[("big", 200)], 0);
        let (pa, pb) = tune_paired(&a, &b, TuningConfig::new(300, 50));
        // side A splits into 3; side B stays a single block; key-based
        // task generation pairs every A-sub with the B block
        assert_eq!(pa.len(), 3);
        assert_eq!(pb.len(), 1);
        assert!(pa.iter().all(
            |p| matches!(&p.kind, PartitionKind::SubBlock { key, .. } if key == "big")
        ));
    }

    #[test]
    fn prop_paired_tuning_preserves_and_aligns() {
        forall("paired-tuning", 60, |rng| {
            let nk = 1 + rng.gen_range(12);
            let names: Vec<String> =
                (0..nk).map(|i| format!("k{i}")).collect();
            let mk = |rng: &mut crate::util::Rng, names: &[String]| {
                let mut sizes: Vec<(&str, usize)> = Vec::new();
                for n in names {
                    if rng.gen_bool(0.8) {
                        sizes.push((n.as_str(), 1 + rng.gen_range(200)));
                    }
                }
                make_blocks(&sizes, rng.gen_range(50))
            };
            let a = mk(rng, &names);
            let b = mk(rng, &names);
            let max = 60 + rng.gen_range(300);
            let min = rng.gen_range(max / 2);
            let (pa, pb) =
                tune_paired(&a, &b, TuningConfig::new(max, min));
            assert_eq!(pa.total_entities(), a.total_entities());
            assert_eq!(pb.total_entities(), b.total_entities());
            assert!(pa.max_size() <= max && pb.max_size() <= max);
            // every aggregate key-set on one side exists on the other
            // side too (or that side simply has no entities for it)
            let sets = |ps: &PartitionSet| -> std::collections::HashSet<Vec<String>> {
                ps.iter()
                    .filter_map(|p| match &p.kind {
                        PartitionKind::Aggregate { keys } => {
                            let mut k = keys.clone();
                            k.sort();
                            Some(k)
                        }
                        _ => None,
                    })
                    .collect()
            };
            for ks in sets(&pa).intersection(&sets(&pb)) {
                assert!(!ks.is_empty());
            }
        });
    }
}
