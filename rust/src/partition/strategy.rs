//! Open partitioning strategies: the plan half of the plan/execute
//! split.
//!
//! The paper's Figure-1 workflow treats partitioning as a swappable
//! stage.  [`PartitionStrategy`] makes that literal: a strategy turns a
//! dataset into a [`PartitionSet`] and the matching [`MatchTask`] list,
//! under the §3.1 memory model carried by [`PlanContext`].  The two
//! paper strategies ([`SizeBased`], [`BlockingBased`]) are impls rather
//! than enum arms, so new strategies plug in without touching the
//! workflow layer — proven by [`SortedNeighborhood`], which ports the
//! sorted-neighborhood blocking of Kolb et al. (*Parallel Sorted
//! Neighborhood Blocking with MapReduce*) onto the partition/task
//! machinery: entities are sorted by a key, sliced into consecutive
//! window partitions, and adjacent windows get an extra overlap task so
//! no near-neighbor pair is lost at a partition boundary.  The fourth
//! strategy, [`BlockSplit`] (Kolb et al., *Load Balancing for
//! MapReduce-based Entity Resolution*), re-slices oversized blocks by
//! their **pair space** so the generated tasks are balanced around a
//! target comparison count — same covered pairs as [`BlockingBased`],
//! strictly lower task skew on Zipf-distributed blocking keys.
//!
//! Strategies are object-safe (`Box<dyn PartitionStrategy>`), and the
//! [`crate::coordinator::Workflow`] builder consumes them to produce an
//! inspectable [`crate::coordinator::MatchPlan`] before any execution
//! happens.

use super::blocking_based::tune_split;
use super::task_gen::generate_tasks;
use super::{
    max_partition_size, partition_size_based, tune, MatchTask,
    PartitionKind, PartitionSet, TuningConfig,
};
use crate::blocking::BlockingMethod;
use crate::cluster::ComputingEnv;
use crate::features::normalize;
use crate::matching::StrategyKind;
use crate::model::{Dataset, EntityId};
use anyhow::{bail, Result};
use std::fmt;

/// The paper's favorable maximum partition sizes (Fig 6): 1,000 for WAM,
/// 500 for LRM.
pub fn default_max_size(kind: StrategyKind) -> usize {
    match kind {
        StrategyKind::Wam => 1000,
        StrategyKind::Lrm => 500,
    }
}

/// The paper's favorable minimum partition sizes (Fig 7): 200 for WAM,
/// 100 for LRM.
pub fn default_min_size(kind: StrategyKind) -> usize {
    match kind {
        StrategyKind::Wam => 200,
        StrategyKind::Lrm => 100,
    }
}

/// Everything a strategy may consult while planning: the computing
/// environment (for the §3.1 memory-restricted partition size) and the
/// match strategy whose per-pair memory cost `c_ms` drives it.
#[derive(Clone, Copy, Debug)]
pub struct PlanContext<'a> {
    /// The computing environment the plan targets.
    pub ce: &'a ComputingEnv,
    /// Match strategy (WAM or LRM) that will execute the tasks.
    pub match_kind: StrategyKind,
}

impl PlanContext<'_> {
    /// The automatic maximum partition size: the §3.1 memory model
    /// `m ≤ √(max_mem / (#cores · c_ms))`, clamped to the strategy's
    /// empirically favorable size (Fig 6).  An explicit `max_size` on a
    /// strategy overrides this — experiments like Fig 6 sweep past the
    /// memory-restricted size on purpose, paying the paging penalty.
    pub fn auto_max_size(&self) -> usize {
        let mem_cap = max_partition_size(self.ce, self.match_kind);
        default_max_size(self.match_kind).min(mem_cap.max(1))
    }
}

/// A partitioning strategy: the pluggable first stage of a match plan.
///
/// Object-safe on purpose — the workflow builder holds a
/// `Box<dyn PartitionStrategy>`, so downstream crates (and tests) can
/// supply their own strategies without touching this crate's enums.
pub trait PartitionStrategy: fmt::Debug + Send + Sync {
    /// Short stable identifier recorded in plan provenance
    /// (e.g. `"size_based"`).
    fn name(&self) -> &'static str;

    /// Stable human-readable parameter string recorded in plan
    /// provenance (part of the serialized plan, so keep it
    /// deterministic).
    fn params(&self) -> String;

    /// Build the partition set for `dataset` under `ctx`'s memory
    /// model.
    fn partition(
        &self,
        dataset: &Dataset,
        ctx: &PlanContext<'_>,
    ) -> Result<PartitionSet>;

    /// Generate the match tasks for a partition set this strategy
    /// built.  The default is the §3.1/§3.2 generator, which already
    /// understands every [`PartitionKind`]; override only for task
    /// structures the kinds cannot express.
    fn tasks(&self, parts: &PartitionSet) -> Vec<MatchTask> {
        generate_tasks(parts)
    }
}

/// §3.1 — Cartesian product evaluation with equally-sized partitions.
#[derive(Clone, Debug, Default)]
pub struct SizeBased {
    /// Maximum partition size; `None` derives `m` from the memory
    /// model ([`PlanContext::auto_max_size`]).
    pub max_size: Option<usize>,
}

impl SizeBased {
    /// Derive the partition size from the memory model.
    pub fn auto() -> SizeBased {
        SizeBased { max_size: None }
    }

    /// Fix the partition size explicitly.
    pub fn with_max_size(m: usize) -> SizeBased {
        SizeBased { max_size: Some(m) }
    }
}

impl PartitionStrategy for SizeBased {
    fn name(&self) -> &'static str {
        "size_based"
    }

    fn params(&self) -> String {
        match self.max_size {
            Some(m) => format!("max_size={m}"),
            None => "max_size=auto".to_string(),
        }
    }

    fn partition(
        &self,
        dataset: &Dataset,
        ctx: &PlanContext<'_>,
    ) -> Result<PartitionSet> {
        let m = self.max_size.unwrap_or_else(|| ctx.auto_max_size());
        if m == 0 {
            bail!("size-based partitioning needs max_size >= 1");
        }
        let ids: Vec<EntityId> =
            dataset.entities.iter().map(|e| e.id).collect();
        Ok(partition_size_based(&ids, m))
    }
}

/// §3.2 — blocking followed by partition tuning (split oversized
/// blocks, aggregate undersized ones, route the misc block).
#[derive(Clone, Debug)]
pub struct BlockingBased {
    /// Blocking method (e.g. by product type or manufacturer).
    pub method: BlockingMethod,
    /// Maximum partition size; `None` derives `m` from the memory
    /// model.
    pub max_size: Option<usize>,
    /// Minimum partition size for aggregating small blocks; `None`
    /// uses the paper's favorable size ([`default_min_size`]).
    pub min_size: Option<usize>,
}

impl BlockingBased {
    /// Blocking by product type with automatic tuning bounds — the
    /// paper's primary configuration.
    pub fn product_type() -> BlockingBased {
        BlockingBased::new(BlockingMethod::product_type())
    }

    /// Blocking with `method` and automatic tuning bounds.
    pub fn new(method: BlockingMethod) -> BlockingBased {
        BlockingBased {
            method,
            max_size: None,
            min_size: None,
        }
    }

    /// Fix the tuning bounds explicitly (builder style).
    pub fn with_bounds(mut self, max_size: usize, min_size: usize) -> Self {
        self.max_size = Some(max_size);
        self.min_size = Some(min_size);
        self
    }
}

impl PartitionStrategy for BlockingBased {
    fn name(&self) -> &'static str {
        "blocking_based"
    }

    fn params(&self) -> String {
        let bounds = |v: Option<usize>| match v {
            Some(x) => x.to_string(),
            None => "auto".to_string(),
        };
        format!(
            "method={:?} max_size={} min_size={}",
            self.method,
            bounds(self.max_size),
            bounds(self.min_size)
        )
    }

    fn partition(
        &self,
        dataset: &Dataset,
        ctx: &PlanContext<'_>,
    ) -> Result<PartitionSet> {
        let m = self.max_size.unwrap_or_else(|| ctx.auto_max_size());
        let min = self
            .min_size
            .unwrap_or_else(|| default_min_size(ctx.match_kind));
        if min > m {
            bail!("min_size {min} exceeds max partition size {m}");
        }
        let blocks = self.method.run(dataset);
        Ok(tune(&blocks, TuningConfig::new(m, min)))
    }
}

/// **BlockSplit** (Kolb, Thor & Rahm, *Load Balancing for
/// MapReduce-based Entity Resolution*) as a partition strategy: §3.2
/// blocking, but oversized blocks are split by their **comparison
/// space** instead of the entity-count bound alone.
///
/// Every block whose pair space would exceed `target_pairs` is sliced
/// into even sub-blocks of at most `√target_pairs` entities, so the
/// resulting match tasks — the intra-sub-block triangles and
/// cross-sub-block rectangles of [`super::task_gen`]'s case 2 — stay
/// balanced near the target instead of inheriting the Zipf skew of
/// the blocking keys.  Aggregation of undersized blocks is
/// *identical* to [`BlockingBased`] with the same bounds (same
/// `min_size` cut, same first-fit packing to `max_size`), and the
/// misc block keeps its misc routing, so the strategy covers
/// **exactly the same comparison pairs** as [`BlockingBased`]
/// (property-tested) while its max-task/mean-task skew ratio is
/// strictly lower whenever any block's pair space exceeds the target.
///
/// The slice width is clamped to `[min_size, max_size]`: never above
/// the §3.1 memory bound, and never below the aggregation cut (which
/// would change *which* blocks aggregate and thereby the pair set).
#[derive(Clone, Debug)]
pub struct BlockSplit {
    /// Blocking method (e.g. by product type or manufacturer).
    pub method: BlockingMethod,
    /// Maximum partition size; `None` derives `m` from the memory
    /// model.
    pub max_size: Option<usize>,
    /// Minimum partition size for aggregating small blocks; `None`
    /// uses the paper's favorable size ([`default_min_size`]).
    pub min_size: Option<usize>,
    /// Target pair comparisons per task.  `None` derives `(m/2)²`
    /// from the max partition size `m` — splitting any block above
    /// half the §3.1 size bound.
    pub target_pairs: Option<u64>,
}

impl BlockSplit {
    /// Blocking by product type with automatic bounds and target —
    /// the paper's primary configuration, load-balanced.
    pub fn product_type() -> BlockSplit {
        BlockSplit::new(BlockingMethod::product_type())
    }

    /// Blocking with `method`, automatic bounds and target.
    pub fn new(method: BlockingMethod) -> BlockSplit {
        BlockSplit {
            method,
            max_size: None,
            min_size: None,
            target_pairs: None,
        }
    }

    /// Fix the tuning bounds explicitly (builder style).
    pub fn with_bounds(mut self, max_size: usize, min_size: usize) -> Self {
        self.max_size = Some(max_size);
        self.min_size = Some(min_size);
        self
    }

    /// Fix the per-task pair target explicitly (builder style).
    pub fn with_target_pairs(mut self, target: u64) -> Self {
        self.target_pairs = Some(target);
        self
    }
}

impl PartitionStrategy for BlockSplit {
    fn name(&self) -> &'static str {
        "block_split"
    }

    fn params(&self) -> String {
        let bounds = |v: Option<usize>| match v {
            Some(x) => x.to_string(),
            None => "auto".to_string(),
        };
        format!(
            "method={:?} max_size={} min_size={} target_pairs={}",
            self.method,
            bounds(self.max_size),
            bounds(self.min_size),
            match self.target_pairs {
                Some(t) => t.to_string(),
                None => "auto".to_string(),
            }
        )
    }

    fn partition(
        &self,
        dataset: &Dataset,
        ctx: &PlanContext<'_>,
    ) -> Result<PartitionSet> {
        let m = self.max_size.unwrap_or_else(|| ctx.auto_max_size());
        if m == 0 {
            bail!("block-split partitioning needs max_size >= 1");
        }
        let min = self
            .min_size
            .unwrap_or_else(|| default_min_size(ctx.match_kind));
        if min > m {
            bail!("min_size {min} exceeds max partition size {m}");
        }
        let target = self.target_pairs.unwrap_or_else(|| {
            let half = (m / 2).max(1) as u64;
            (half * half).max(1)
        });
        // slice width: the cross-sub-block rectangles (s² pairs) are
        // the heaviest split tasks, so s = √target keeps them at or
        // under the target; clamped to [min, m] — see the type docs.
        // Aggregation inside tune_split still packs to `m`, exactly
        // like BlockingBased, so the covered pair set is identical.
        let s = ((target as f64).sqrt().floor() as usize)
            .clamp(min.max(1), m);
        let blocks = self.method.run(dataset);
        Ok(tune_split(&blocks, TuningConfig::new(m, min), s))
    }
}

/// Sorted-neighborhood partitioning (Hernández/Stolfo windowing on the
/// partition level, after Kolb et al.'s MapReduce formulation).
///
/// Entities are sorted by the normalized value of `attribute`, sliced
/// into consecutive partitions of `max_size` entities
/// ([`PartitionKind::Window`]), and matched within each window plus
/// across each *adjacent* window pair (the overlap tasks the task
/// generator emits for `Window` kinds).  Because every window holds at
/// least `window` entities (the partition size is clamped to the
/// window), any two entities within `window` positions of each other
/// in sort order land in the same or in adjacent partitions — the
/// classic sliding-window guarantee, expressed as §3.2-style match
/// tasks.  Entities with a missing key go to misc partitions and are
/// matched against everything, exactly like §3.2's misc block.
#[derive(Clone, Debug)]
pub struct SortedNeighborhood {
    /// Attribute whose normalized value is the sort key.
    pub attribute: String,
    /// Sliding-window size `w`: any two entities within `w` positions
    /// in sort order are guaranteed to be compared.  Must be ≥ 2.
    pub window: usize,
    /// Partition (window-slice) size; `None` derives it from the
    /// memory model.  Clamped to at least `window` so the overlap
    /// guarantee holds.
    pub max_size: Option<usize>,
}

impl SortedNeighborhood {
    /// Sort by `attribute` with window `w`, partition size from the
    /// memory model.
    pub fn new<S: Into<String>>(attribute: S, window: usize) -> Self {
        SortedNeighborhood {
            attribute: attribute.into(),
            window,
            max_size: None,
        }
    }

    /// Sort by title — the default key for product offers.
    pub fn by_title(window: usize) -> Self {
        SortedNeighborhood::new(crate::model::ATTR_TITLE, window)
    }

    /// Fix the partition size explicitly (builder style).
    pub fn with_max_size(mut self, m: usize) -> Self {
        self.max_size = Some(m);
        self
    }
}

impl PartitionStrategy for SortedNeighborhood {
    fn name(&self) -> &'static str {
        "sorted_neighborhood"
    }

    fn params(&self) -> String {
        format!(
            "attribute={} window={} max_size={}",
            self.attribute,
            self.window,
            match self.max_size {
                Some(m) => m.to_string(),
                None => "auto".to_string(),
            }
        )
    }

    fn partition(
        &self,
        dataset: &Dataset,
        ctx: &PlanContext<'_>,
    ) -> Result<PartitionSet> {
        if self.window < 2 {
            bail!("sorted-neighborhood window must be >= 2");
        }
        // the window guarantee needs every partition to span at least
        // `window` sort positions, so the slice size is clamped up
        let m = self
            .max_size
            .unwrap_or_else(|| ctx.auto_max_size())
            .max(self.window);
        let mut keyed: Vec<(String, EntityId)> = Vec::new();
        let mut missing: Vec<EntityId> = Vec::new();
        for e in &dataset.entities {
            match e.get(&dataset.schema, &self.attribute) {
                Some(v) if !v.trim().is_empty() => {
                    keyed.push((normalize(v), e.id));
                }
                _ => missing.push(e.id),
            }
        }
        // deterministic total order: (normalized key, entity id)
        keyed.sort();
        let mut out = PartitionSet::new();
        // exact-size slices (last one may be short): every non-tail
        // window spans >= `window` positions, so a pair at sort
        // distance < `window` is intra-window or in adjacent windows —
        // never further apart.  Balanced slicing would break this
        // (three slices of ~2m/3 leave < m gaps uncovered).
        let count = keyed.len().div_ceil(m);
        for (index, chunk) in keyed.chunks(m).enumerate() {
            out.push(
                PartitionKind::Window { index, count },
                chunk.iter().map(|(_, id)| *id).collect(),
            );
        }
        if !missing.is_empty() {
            let mcount = missing.len().div_ceil(m);
            for (index, chunk) in missing.chunks(m).enumerate() {
                out.push(
                    PartitionKind::Misc {
                        index,
                        count: mcount,
                    },
                    chunk.to_vec(),
                );
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::model::ATTR_TITLE;
    use crate::util::proptest::forall;
    use crate::util::GIB;
    use std::collections::HashSet;

    fn ctx_in(ce: &ComputingEnv) -> PlanContext<'_> {
        PlanContext {
            ce,
            match_kind: StrategyKind::Wam,
        }
    }

    /// Every unordered entity pair some task of `parts` compares.
    fn covered_pairs(parts: &PartitionSet) -> HashSet<(u32, u32)> {
        let mut covered = HashSet::new();
        for t in &generate_tasks(parts) {
            let l = &parts.get(t.left).entities;
            let r = &parts.get(t.right).entities;
            if t.left == t.right {
                for i in 0..l.len() {
                    for j in (i + 1)..l.len() {
                        covered.insert((
                            l[i].0.min(l[j].0),
                            l[i].0.max(l[j].0),
                        ));
                    }
                }
            } else {
                for &a in l {
                    for &b in r {
                        if a != b {
                            covered
                                .insert((a.0.min(b.0), a.0.max(b.0)));
                        }
                    }
                }
            }
        }
        covered
    }

    #[test]
    fn size_based_strategy_matches_direct_call() {
        let data = GeneratorConfig::tiny().with_entities(500).generate();
        let ce = ComputingEnv::new(1, 2, GIB);
        let s = SizeBased::with_max_size(100);
        let parts = s.partition(&data.dataset, &ctx_in(&ce)).unwrap();
        assert_eq!(parts.len(), 5);
        assert_eq!(parts.total_entities(), 500);
        let tasks = s.tasks(&parts);
        assert_eq!(tasks.len(), 5 + 5 * 4 / 2);
    }

    #[test]
    fn blocking_based_strategy_rejects_inverted_bounds() {
        let data = GeneratorConfig::tiny().generate();
        let ce = ComputingEnv::new(1, 2, GIB);
        let s = BlockingBased::product_type().with_bounds(100, 5_000);
        assert!(s.partition(&data.dataset, &ctx_in(&ce)).is_err());
    }

    /// The tentpole property: BlockSplit covers **exactly** the same
    /// comparison pairs as BlockingBased with the same bounds — the
    /// pair-space splitting reshapes tasks, never coverage.
    #[test]
    fn prop_block_split_preserves_blocking_pair_set() {
        forall("blocksplit-pairs", 10, |rng| {
            let n = 150 + rng.gen_range(350);
            let seed = rng.gen_range(10_000) as u64;
            let data = GeneratorConfig::tiny()
                .with_entities(n)
                .with_seed(seed)
                .generate();
            let ce = ComputingEnv::new(1, 2, GIB);
            let ctx = ctx_in(&ce);
            let max = 40 + rng.gen_range(120);
            let min = (1 + rng.gen_range(30)).min(max);
            let target = 4 + rng.gen_range(4000) as u64;
            let bb = BlockingBased::product_type()
                .with_bounds(max, min)
                .partition(&data.dataset, &ctx)
                .unwrap();
            let bs = BlockSplit::product_type()
                .with_bounds(max, min)
                .with_target_pairs(target)
                .partition(&data.dataset, &ctx)
                .unwrap();
            assert_eq!(bs.total_entities(), bb.total_entities());
            assert_eq!(
                covered_pairs(&bs),
                covered_pairs(&bb),
                "pair sets differ \
                 (n={n} max={max} min={min} target={target})"
            );
        });
    }

    /// The load-balance claim on a skewed catalog — one giant
    /// blocking key plus a few mid-size ones: BlockSplit's
    /// max-task/mean-task pair ratio is strictly lower than
    /// BlockingBased's, at an unchanged total comparison count, and
    /// no split task exceeds the pair target.
    #[test]
    fn block_split_lowers_skew_on_skewed_catalog() {
        use crate::model::{
            Dataset, Entity, EntityId, Schema, ATTR_PRODUCT_TYPE,
        };
        let schema = Schema::new(vec![ATTR_TITLE, ATTR_PRODUCT_TYPE]);
        let mut ds = Dataset::new(schema.clone());
        let mut next = 0u32;
        let mut add = |ds: &mut Dataset, ptype: Option<&str>, n: usize| {
            for _ in 0..n {
                let mut e = Entity::new(EntityId(next), &schema);
                e.set(&schema, ATTR_TITLE, format!("offer {next}"));
                if let Some(p) = ptype {
                    e.set(&schema, ATTR_PRODUCT_TYPE, p.to_string());
                }
                ds.push(e);
                next += 1;
            }
        };
        add(&mut ds, Some("disk"), 1500); // the Zipf head
        add(&mut ds, Some("tv"), 200);
        add(&mut ds, Some("cam"), 200);
        add(&mut ds, Some("gps"), 200);
        add(&mut ds, None, 50); // misc
        let ce = ComputingEnv::new(1, 2, GIB);
        let ctx = ctx_in(&ce);
        let target = 10_000u64;
        let bb = BlockingBased::product_type()
            .with_bounds(500, 20)
            .partition(&ds, &ctx)
            .unwrap();
        let bs = BlockSplit::product_type()
            .with_bounds(500, 20)
            .with_target_pairs(target)
            .partition(&ds, &ctx)
            .unwrap();
        let skew = |parts: &PartitionSet| -> (f64, u64, u64) {
            let tasks = generate_tasks(parts);
            let pairs: Vec<u64> =
                tasks.iter().map(|t| t.n_pairs(parts)).collect();
            let total: u64 = pairs.iter().sum();
            let max = *pairs.iter().max().unwrap();
            let mean = total as f64 / pairs.len() as f64;
            (max as f64 / mean, max, total)
        };
        let (ratio_bb, max_bb, total_bb) = skew(&bb);
        let (ratio_bs, max_bs, total_bs) = skew(&bs);
        assert_eq!(total_bb, total_bs, "comparison work unchanged");
        assert!(
            ratio_bs < ratio_bb,
            "block_split ratio {ratio_bs:.2} must be strictly below \
             blocking_based {ratio_bb:.2}"
        );
        assert!(max_bs < max_bb, "heaviest task shrank");
        assert!(
            max_bs <= target,
            "split task of {max_bs} pairs exceeds target {target}"
        );
    }

    #[test]
    fn block_split_equals_blocking_when_target_not_binding() {
        // a huge target never splits beyond the §3.1 bound: the
        // partition sets coincide exactly with BlockingBased's
        let data = GeneratorConfig::tiny().with_entities(500).generate();
        let ce = ComputingEnv::new(1, 2, GIB);
        let ctx = ctx_in(&ce);
        let bb = BlockingBased::product_type()
            .with_bounds(150, 30)
            .partition(&data.dataset, &ctx)
            .unwrap();
        let bs = BlockSplit::product_type()
            .with_bounds(150, 30)
            .with_target_pairs(u64::MAX)
            .partition(&data.dataset, &ctx)
            .unwrap();
        assert_eq!(bs.len(), bb.len());
        for (a, b) in bs.iter().zip(bb.iter()) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.entities, b.entities);
        }
    }

    #[test]
    fn sorted_neighborhood_windows_cover_all_entities_in_order() {
        let data = GeneratorConfig::tiny().with_entities(700).generate();
        let ce = ComputingEnv::new(1, 2, GIB);
        let s = SortedNeighborhood::by_title(40).with_max_size(100);
        let parts = s.partition(&data.dataset, &ctx_in(&ce)).unwrap();
        assert_eq!(parts.total_entities(), 700);
        // windows are exact slices of the sorted order; every window
        // except possibly the last tail holds the full slice size
        let windows: Vec<_> = parts
            .iter()
            .filter(|p| {
                matches!(p.kind, PartitionKind::Window { .. })
            })
            .collect();
        assert!(!windows.is_empty());
        for w in &windows[..windows.len() - 1] {
            assert_eq!(w.len(), 100);
        }
        for (i, w) in windows.iter().enumerate() {
            match &w.kind {
                PartitionKind::Window { index, count } => {
                    assert_eq!(*index, i);
                    assert_eq!(*count, windows.len());
                }
                other => panic!("unexpected kind {other:?}"),
            }
        }
    }

    #[test]
    fn sorted_neighborhood_partition_size_clamped_to_window() {
        let data = GeneratorConfig::tiny().with_entities(300).generate();
        let ce = ComputingEnv::new(1, 2, GIB);
        // max_size 10 below window 50: slices are clamped up to 50
        let s = SortedNeighborhood::by_title(50).with_max_size(10);
        let parts = s.partition(&data.dataset, &ctx_in(&ce)).unwrap();
        for p in parts.iter() {
            if let PartitionKind::Window { count, .. } = &p.kind {
                if p.id.0 as usize + 1 < *count {
                    assert!(p.len() >= 50, "window below w: {}", p.len());
                }
            }
        }
    }

    #[test]
    fn sorted_neighborhood_missing_keys_go_to_misc() {
        use crate::model::{Dataset, Entity, EntityId, Schema};
        let schema = Schema::new(vec![ATTR_TITLE]);
        let mut ds = Dataset::new(schema.clone());
        for i in 0..10u32 {
            let mut e = Entity::new(EntityId(i), &schema);
            if i % 3 != 0 {
                e.set(&schema, ATTR_TITLE, format!("title {i}"));
            }
            ds.push(e);
        }
        let ce = ComputingEnv::new(1, 2, GIB);
        let s = SortedNeighborhood::by_title(2).with_max_size(4);
        let parts = s.partition(&ds, &ctx_in(&ce)).unwrap();
        assert_eq!(parts.total_entities(), 10);
        let misc: usize = parts
            .iter()
            .filter(|p| p.kind.is_misc())
            .map(|p| p.len())
            .sum();
        assert_eq!(misc, 4, "ids 0,3,6,9 have no title");
    }

    #[test]
    fn sorted_neighborhood_rejects_tiny_window() {
        let data = GeneratorConfig::tiny().with_entities(50).generate();
        let ce = ComputingEnv::new(1, 2, GIB);
        let s = SortedNeighborhood::by_title(1);
        assert!(s.partition(&data.dataset, &ctx_in(&ce)).is_err());
    }

    #[test]
    fn strategy_params_are_deterministic() {
        let a = SortedNeighborhood::by_title(64);
        let b = SortedNeighborhood::by_title(64);
        assert_eq!(a.params(), b.params());
        assert_eq!(
            SizeBased::auto().params(),
            SizeBased { max_size: None }.params()
        );
    }
}
