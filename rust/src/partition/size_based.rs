//! Size-based partitioning (paper §3.1).
//!
//! Split the input entities into `p = ⌈n/m⌉` equally-sized partitions for
//! Cartesian-product evaluation.  Match task generation then compares
//! every partition with itself and with every other partition —
//! `p + p(p−1)/2` tasks (see [`super::task_gen`]).

use super::{PartitionKind, PartitionSet};
use crate::model::EntityId;
use crate::util::div_ceil;

/// Partition `entities` into chunks of at most `m`.
///
/// Sizes are balanced: instead of `p−1` full partitions plus a remainder
/// (which could be as small as 1 and would create skewed match tasks),
/// the n entities are spread as evenly as possible — sizes differ by at
/// most one.
pub fn partition_size_based(entities: &[EntityId], m: usize) -> PartitionSet {
    assert!(m >= 1, "partition size must be >= 1");
    let n = entities.len();
    let mut out = PartitionSet::new();
    if n == 0 {
        return out;
    }
    let p = div_ceil(n, m);
    let base = n / p;
    let extra = n % p; // first `extra` partitions get one more
    let mut offset = 0;
    for i in 0..p {
        let size = base + usize::from(i < extra);
        out.push(
            PartitionKind::SizeBased,
            entities[offset..offset + size].to_vec(),
        );
        offset += size;
    }
    debug_assert_eq!(offset, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn ids(n: usize) -> Vec<EntityId> {
        (0..n as u32).map(EntityId).collect()
    }

    #[test]
    fn exact_division() {
        let ps = partition_size_based(&ids(1000), 500);
        assert_eq!(ps.len(), 2);
        assert!(ps.iter().all(|p| p.len() == 500));
    }

    #[test]
    fn balanced_remainder() {
        // 1001 entities, m=500 → 3 partitions of 334/334/333, not 500/500/1
        let ps = partition_size_based(&ids(1001), 500);
        assert_eq!(ps.len(), 3);
        let sizes: Vec<usize> = ps.iter().map(|p| p.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 1001);
        assert!(sizes.iter().all(|&s| s == 333 || s == 334));
    }

    #[test]
    fn paper_counts() {
        // small problem: 20,000 entities at m=500 → 40 partitions
        let ps = partition_size_based(&ids(20_000), 500);
        assert_eq!(ps.len(), 40);
        // → 40 + 40*39/2 = 820 match tasks (checked in task_gen tests)
    }

    #[test]
    fn empty_and_tiny() {
        assert_eq!(partition_size_based(&[], 10).len(), 0);
        let ps = partition_size_based(&ids(3), 10);
        assert_eq!(ps.len(), 1);
        assert_eq!(ps.get(super::super::PartitionId(0)).len(), 3);
    }

    #[test]
    fn prop_partitions_preserve_entities_in_order() {
        forall("size-based-cover", 100, |rng| {
            let n = rng.gen_range(5000);
            let m = 1 + rng.gen_range(700);
            let input = ids(n);
            let ps = partition_size_based(&input, m);
            // concatenation of partitions == input
            let cat: Vec<EntityId> = ps
                .iter()
                .flat_map(|p| p.entities.iter().copied())
                .collect();
            assert_eq!(cat, input);
            // every partition within max size, sizes differ by <= 1
            if n > 0 {
                let sizes: Vec<usize> = ps.iter().map(|p| p.len()).collect();
                assert!(sizes.iter().all(|&s| s <= m && s >= 1));
                let (mn, mx) = (
                    *sizes.iter().min().unwrap(),
                    *sizes.iter().max().unwrap(),
                );
                assert!(mx - mn <= 1, "unbalanced: {mn}..{mx}");
            }
        });
    }

    #[test]
    #[should_panic]
    fn zero_partition_size_panics() {
        partition_size_based(&ids(10), 0);
    }
}
