//! Memory-restricted partition sizing (paper §3.1).
//!
//! Entity matching runs in main memory: a match task over two partitions
//! of size `m` holds O(m²) intermediate correspondences, at an average of
//! `c_ms` bytes per pair for match strategy `ms`.  With `max_mem` shared
//! by `#cores` parallel threads per node, the partition size is bounded by
//!
//! ```text
//! m ≤ √( max_mem / (#cores · c_ms) )
//! ```
//!
//! The paper's worked examples: at `max_mem = 2 GB`, `#cores = 4`
//! (→ 500 MB per task), a memory-efficient strategy with `c_ms = 20 B`
//! allows `m = 5,000`; a learner-based strategy with `c_ms = 1 kB` only
//! `m ≈ 700`.

use crate::cluster::ComputingEnv;
use crate::matching::StrategyKind;

/// Memory available to a single match task (per parallel thread).
pub fn mem_per_task(ce: &ComputingEnv) -> u64 {
    ce.max_mem / ce.cores_per_node as u64
}

/// The memory-restricted maximum partition size `m` for a strategy.
pub fn max_partition_size(ce: &ComputingEnv, strategy: StrategyKind) -> usize {
    let per_task = mem_per_task(ce) as f64;
    let c_ms = strategy.memory_per_pair() as f64;
    (per_task / c_ms).sqrt().floor() as usize
}

/// Estimated memory requirement of a match task comparing partitions of
/// `m1` and `m2` entities: `c_ms · m1 · m2` (paper: `c_ms · m²`).
pub fn task_memory_bytes(m1: usize, m2: usize, strategy: StrategyKind) -> u64 {
    strategy.memory_per_pair() * m1 as u64 * m2 as u64
}

/// Does a task comparing `m1 × m2` fit the per-task budget?
pub fn task_fits(ce: &ComputingEnv, m1: usize, m2: usize, strategy: StrategyKind) -> bool {
    task_memory_bytes(m1, m2, strategy) <= mem_per_task(ce)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ComputingEnv;
    use crate::util::{GIB, MIB};

    /// The paper's §3.1 worked example: 2 GB, 4 cores → 500 MB per task;
    /// c_ms = 20 B → m = 5,000; c_ms = 1 kB → m ≈ 700.
    #[test]
    fn paper_worked_example() {
        let ce = ComputingEnv::new(1, 4, 2 * GIB);
        assert_eq!(mem_per_task(&ce), 512 * MIB);
        // the paper rounds 500 MB; with exact 512 MiB / 20 B: √(26843545.6)
        let m_wam = max_partition_size(&ce, StrategyKind::Wam);
        assert!((5000..=5200).contains(&m_wam), "m_wam = {m_wam}");
        let m_lrm = max_partition_size(&ce, StrategyKind::Lrm);
        assert!((700..=740).contains(&m_lrm), "m_lrm = {m_lrm}");
    }

    #[test]
    fn more_cores_smaller_partitions() {
        let ce4 = ComputingEnv::new(1, 4, 2 * GIB);
        let ce8 = ComputingEnv::new(1, 8, 2 * GIB);
        assert!(
            max_partition_size(&ce8, StrategyKind::Wam)
                < max_partition_size(&ce4, StrategyKind::Wam)
        );
    }

    #[test]
    fn task_memory_quadratic() {
        assert_eq!(
            task_memory_bytes(100, 100, StrategyKind::Wam),
            20 * 100 * 100
        );
        assert_eq!(
            task_memory_bytes(500, 200, StrategyKind::Lrm),
            1024 * 500 * 200
        );
    }

    #[test]
    fn fits_is_consistent_with_max_size() {
        let ce = ComputingEnv::new(1, 4, 2 * GIB);
        for strategy in [StrategyKind::Wam, StrategyKind::Lrm] {
            let m = max_partition_size(&ce, strategy);
            assert!(task_fits(&ce, m, m, strategy));
            assert!(!task_fits(&ce, m + 64, m + 64, strategy));
        }
    }
}
