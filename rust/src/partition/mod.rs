//! Partitioning strategies and match-task generation (paper §3).
//!
//! The input to parallel matching is partitioned so that independent
//! *match tasks* — each comparing two partitions — can be executed in
//! parallel:
//!
//! * [`size_based`] (§3.1): split the input into equally-sized partitions
//!   and match every pair of partitions (Cartesian product evaluation);
//! * [`blocking_based`] (§3.2): take the output of a blocking operator
//!   and run **partition tuning** — split blocks whose memory demand
//!   exceeds the per-core budget, aggregate tiny blocks, and route the
//!   *misc* block against everything;
//! * [`task_gen`]: generate match tasks for the three §3.2 cases plus the
//!   multi-source variants of §3.3;
//! * [`memory`]: the `m ≤ √(max_mem / (#cores · c_ms))` sizing model;
//! * [`strategy`]: the open [`PartitionStrategy`] trait — the plan half
//!   of the plan/execute split — with the two paper strategies,
//!   [`strategy::SortedNeighborhood`] windowing and the
//!   load-balancing [`strategy::BlockSplit`] (Kolb et al.) as impls.

pub mod blocking_based;
pub mod memory;
pub mod size_based;
pub mod strategy;
pub mod task_gen;

pub use blocking_based::{tune, TuningConfig};
pub use memory::{max_partition_size, task_memory_bytes};
pub use size_based::partition_size_based;
pub use strategy::{
    BlockSplit, BlockingBased, PartitionStrategy, PlanContext,
    SizeBased, SortedNeighborhood,
};
pub use task_gen::{
    generate_tasks, generate_tasks_two_sources_blocked,
    generate_tasks_two_sources_cartesian,
};

use crate::model::EntityId;
use std::fmt;

/// Identifier of a partition within a [`PartitionSet`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct PartitionId(pub u32);

impl fmt::Display for PartitionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "P{}", self.0)
    }
}

/// Why a partition exists — determines match-task generation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PartitionKind {
    /// Equal slice of the input for Cartesian evaluation (§3.1).
    SizeBased,
    /// An untouched blocking output block: matched only within itself.
    Block { key: String },
    /// Sub-partition `index` (of `count`) of an oversized block that was
    /// split: matched with itself and all sibling sub-partitions.
    SubBlock {
        key: String,
        index: usize,
        count: usize,
    },
    /// Aggregate of several undersized blocks: matched within itself.
    Aggregate { keys: Vec<String> },
    /// Sub-partition of the misc block: matched with *everything*.
    Misc { index: usize, count: usize },
    /// Window `index` (of `count`) of a sorted-neighborhood run:
    /// matched with itself and with the *adjacent* window
    /// (`index + 1`), recovering the sliding-window overlap at the
    /// partition boundary ([`strategy::SortedNeighborhood`]).
    Window { index: usize, count: usize },
}

impl PartitionKind {
    pub fn is_misc(&self) -> bool {
        matches!(self, PartitionKind::Misc { .. })
    }
}

/// A concrete partition: an ordered set of entity ids.
#[derive(Clone, Debug)]
pub struct Partition {
    pub id: PartitionId,
    pub kind: PartitionKind,
    pub entities: Vec<EntityId>,
}

impl Partition {
    pub fn len(&self) -> usize {
        self.entities.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entities.is_empty()
    }
}

/// The partitions produced by one partitioning strategy.
#[derive(Clone, Debug, Default)]
pub struct PartitionSet {
    partitions: Vec<Partition>,
}

impl PartitionSet {
    pub fn new() -> PartitionSet {
        PartitionSet::default()
    }

    pub fn push(&mut self, kind: PartitionKind, entities: Vec<EntityId>) -> PartitionId {
        let id = PartitionId(self.partitions.len() as u32);
        self.partitions.push(Partition { id, kind, entities });
        id
    }

    pub fn len(&self) -> usize {
        self.partitions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.partitions.is_empty()
    }

    pub fn get(&self, id: PartitionId) -> &Partition {
        &self.partitions[id.0 as usize]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Partition> {
        self.partitions.iter()
    }

    pub fn misc_ids(&self) -> Vec<PartitionId> {
        self.partitions
            .iter()
            .filter(|p| p.kind.is_misc())
            .map(|p| p.id)
            .collect()
    }

    pub fn n_misc(&self) -> usize {
        self.partitions.iter().filter(|p| p.kind.is_misc()).count()
    }

    /// Total entities across partitions.
    pub fn total_entities(&self) -> usize {
        self.partitions.iter().map(Partition::len).sum()
    }

    /// Largest partition size (must respect the tuning max).
    pub fn max_size(&self) -> usize {
        self.partitions.iter().map(Partition::len).max().unwrap_or(0)
    }
}

/// A match task: compare all entity pairs of `left` × `right`
/// (`left == right` means intra-partition matching, which compares the
/// partition's unordered pairs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatchTask {
    pub id: u32,
    pub left: PartitionId,
    pub right: PartitionId,
}

/// A contiguous rectangle of a match task's pair space, used by
/// **runtime task splitting** (the scheduler's answer to a task no
/// live node's §3.1 budget fits): half-open entity-index ranges into
/// the task's left and right partitions that a sub-task compares
/// instead of the full partitions.
///
/// On an intra-partition task (`task.left == task.right`), a span with
/// `left == right` marks a *triangle* sub-task (unordered pairs within
/// the range); any other combination — two distinct ranges of the same
/// partition, or ranges of two different partitions — is a plain
/// rectangle compared as a cross task.  The splitter tiles the parent
/// pair space exactly (triangles along the diagonal plus the
/// rectangles between chunks), so the union of the sub-tasks covers
/// every parent pair exactly once.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskSpan {
    /// Half-open index range `[start, end)` into the left partition.
    pub left: (u32, u32),
    /// Half-open index range `[start, end)` into the right partition.
    pub right: (u32, u32),
}

impl TaskSpan {
    /// Entities selected from the left partition.
    pub fn left_len(&self) -> u32 {
        self.left.1.saturating_sub(self.left.0)
    }

    /// Entities selected from the right partition.
    pub fn right_len(&self) -> u32 {
        self.right.1.saturating_sub(self.right.0)
    }
}

impl MatchTask {
    /// Number of entity-pair comparisons this task performs.
    pub fn n_pairs(&self, parts: &PartitionSet) -> u64 {
        let l = parts.get(self.left).len() as u64;
        if self.left == self.right {
            l * (l.saturating_sub(1)) / 2
        } else {
            l * parts.get(self.right).len() as u64
        }
    }

    /// The partitions this task needs fetched (1 or 2).
    pub fn needed_partitions(&self) -> Vec<PartitionId> {
        if self.left == self.right {
            vec![self.left]
        } else {
            vec![self.left, self.right]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(range: std::ops::Range<u32>) -> Vec<EntityId> {
        range.map(EntityId).collect()
    }

    #[test]
    fn partition_set_basics() {
        let mut ps = PartitionSet::new();
        let a = ps.push(PartitionKind::SizeBased, ids(0..500));
        let b = ps.push(
            PartitionKind::Misc { index: 0, count: 1 },
            ids(500..600),
        );
        assert_eq!(ps.len(), 2);
        assert_eq!(ps.get(a).len(), 500);
        assert_eq!(ps.total_entities(), 600);
        assert_eq!(ps.max_size(), 500);
        assert_eq!(ps.misc_ids(), vec![b]);
        assert_eq!(ps.n_misc(), 1);
    }

    #[test]
    fn task_pair_counts() {
        let mut ps = PartitionSet::new();
        let a = ps.push(PartitionKind::SizeBased, ids(0..10));
        let b = ps.push(PartitionKind::SizeBased, ids(10..15));
        let intra = MatchTask { id: 0, left: a, right: a };
        let cross = MatchTask { id: 1, left: a, right: b };
        assert_eq!(intra.n_pairs(&ps), 45); // 10*9/2
        assert_eq!(cross.n_pairs(&ps), 50); // 10*5
        assert_eq!(intra.needed_partitions(), vec![a]);
        assert_eq!(cross.needed_partitions(), vec![a, b]);
    }

    #[test]
    fn task_span_lengths() {
        let s = TaskSpan {
            left: (10, 25),
            right: (0, 40),
        };
        assert_eq!(s.left_len(), 15);
        assert_eq!(s.right_len(), 40);
        // malformed (inverted) ranges saturate instead of wrapping
        let bad = TaskSpan {
            left: (5, 2),
            right: (0, 0),
        };
        assert_eq!(bad.left_len(), 0);
        assert_eq!(bad.right_len(), 0);
    }

    #[test]
    fn misc_kind_flag() {
        assert!(PartitionKind::Misc { index: 0, count: 2 }.is_misc());
        assert!(!PartitionKind::SizeBased.is_misc());
        assert!(!PartitionKind::Block { key: "x".into() }.is_misc());
    }
}
