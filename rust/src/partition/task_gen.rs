//! Match-task generation (paper §3.1, §3.2 and the §3.3 multi-source
//! variants).
//!
//! The three §3.2 cases:
//!
//! 1. untouched / aggregated blocks → one intra-partition task;
//! 2. a block split into `k` sub-partitions → `k + k(k−1)/2` tasks
//!    (every sub-partition with itself and with each sibling);
//! 3. misc (sub-)partitions → matched with **all** (sub-)partitions.
//!
//! Size-based partitioning is the degenerate case where every partition
//! pairs with every other: `p + p(p−1)/2` tasks.

use super::{MatchTask, PartitionKind, PartitionSet};

/// Generate the match tasks for a partition set produced by either
/// partitioning strategy.
pub fn generate_tasks(parts: &PartitionSet) -> Vec<MatchTask> {
    let mut tasks = Vec::new();
    let mut next_id = 0u32;
    let mut push = |tasks: &mut Vec<MatchTask>, left, right| {
        tasks.push(MatchTask {
            id: next_id,
            left,
            right,
        });
        next_id += 1;
    };

    let all: Vec<&super::Partition> = parts.iter().collect();
    for (i, p) in all.iter().enumerate() {
        match &p.kind {
            // Cartesian evaluation: pair with self and every later one.
            PartitionKind::SizeBased => {
                push(&mut tasks, p.id, p.id);
                for q in all.iter().skip(i + 1) {
                    debug_assert!(matches!(q.kind, PartitionKind::SizeBased));
                    push(&mut tasks, p.id, q.id);
                }
            }
            // Case 1: single task within the partition.
            PartitionKind::Block { .. } | PartitionKind::Aggregate { .. } => {
                push(&mut tasks, p.id, p.id);
            }
            // Case 2: self + later siblings of the same split block.
            PartitionKind::SubBlock { key, .. } => {
                push(&mut tasks, p.id, p.id);
                for q in all.iter().skip(i + 1) {
                    if let PartitionKind::SubBlock { key: qk, .. } = &q.kind {
                        if qk == key {
                            push(&mut tasks, p.id, q.id);
                        }
                    }
                }
            }
            // Sorted-neighborhood windows: self + the adjacent window
            // (the boundary-overlap task of the sliding-window model).
            PartitionKind::Window { index, .. } => {
                push(&mut tasks, p.id, p.id);
                for q in all.iter().skip(i + 1) {
                    if let PartitionKind::Window { index: qi, .. } = &q.kind {
                        if *qi == *index + 1 {
                            push(&mut tasks, p.id, q.id);
                            break;
                        }
                    }
                }
            }
            // Case 3: self + later misc siblings + every non-misc
            // partition (regardless of order).
            PartitionKind::Misc { .. } => {
                push(&mut tasks, p.id, p.id);
                for q in all.iter().skip(i + 1) {
                    if q.kind.is_misc() {
                        push(&mut tasks, p.id, q.id);
                    }
                }
                for q in all.iter() {
                    if !q.kind.is_misc() {
                        push(&mut tasks, p.id, q.id);
                    }
                }
            }
        }
    }
    tasks
}

/// §3.3, duplicate-free sources, Cartesian evaluation: partition each
/// source size-based and match each partition of the first source with
/// each of the second — `m·n` tasks, never within a source.
pub fn generate_tasks_two_sources_cartesian(
    parts_a: &PartitionSet,
    parts_b: &PartitionSet,
) -> Vec<(MatchTask, bool)> {
    // Returned flag: true = left id refers to parts_a (cross-set task ids
    // address two different PartitionSets; the workflow keeps them apart).
    let mut tasks = Vec::new();
    let mut id = 0u32;
    for pa in parts_a.iter() {
        for pb in parts_b.iter() {
            tasks.push((
                MatchTask {
                    id,
                    left: pa.id,
                    right: pb.id,
                },
                true,
            ));
            id += 1;
        }
    }
    tasks
}

/// §3.3, duplicate-free sources with blocking: the same blocking was
/// applied to both sources; corresponding blocks (same tuned key) are
/// matched across sources, and misc partitions of either source are
/// matched with all partitions of the *other* source.
pub fn generate_tasks_two_sources_blocked(
    parts_a: &PartitionSet,
    parts_b: &PartitionSet,
) -> Vec<(MatchTask, bool)> {
    let key_of = |k: &PartitionKind| -> Option<String> {
        match k {
            PartitionKind::Block { key } => Some(key.clone()),
            PartitionKind::SubBlock { key, .. } => Some(key.clone()),
            // aggregates pair by their sorted member keys
            PartitionKind::Aggregate { keys } => {
                let mut ks = keys.clone();
                ks.sort();
                Some(format!("agg:{}", ks.join("+")))
            }
            PartitionKind::Misc { .. }
            | PartitionKind::SizeBased
            | PartitionKind::Window { .. } => None,
        }
    };
    let mut tasks = Vec::new();
    let mut id = 0u32;
    let mut push = |tasks: &mut Vec<(MatchTask, bool)>, l, r| {
        tasks.push((
            MatchTask {
                id,
                left: l,
                right: r,
            },
            true,
        ));
        id += 1;
    };
    for pa in parts_a.iter() {
        match key_of(&pa.kind) {
            Some(ka) => {
                for pb in parts_b.iter() {
                    if key_of(&pb.kind).as_deref() == Some(ka.as_str()) {
                        push(&mut tasks, pa.id, pb.id);
                    }
                }
            }
            None if pa.kind.is_misc() => {
                // misc of A × everything of B
                for pb in parts_b.iter() {
                    push(&mut tasks, pa.id, pb.id);
                }
            }
            None => {}
        }
    }
    // misc of B × non-misc of A (misc×misc already covered above)
    for pb in parts_b.iter() {
        if pb.kind.is_misc() {
            for pa in parts_a.iter() {
                if !pa.kind.is_misc() {
                    push(&mut tasks, pa.id, pb.id);
                }
            }
        }
    }
    tasks
}

/// Expected task count for size-based partitioning: `p + p(p−1)/2`.
pub fn size_based_task_count(p: usize) -> usize {
    p + p * p.saturating_sub(1) / 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::Blocks;
    use crate::model::EntityId;
    use crate::partition::{
        partition_size_based, tune, PartitionId, TuningConfig,
    };
    use crate::util::proptest::forall;
    use std::collections::HashSet;

    fn ids(n: usize) -> Vec<EntityId> {
        (0..n as u32).map(EntityId).collect()
    }

    #[test]
    fn size_based_task_formula() {
        for (n, m, expect_p) in [(1000, 500, 2), (20_000, 500, 40), (3600, 600, 6)] {
            let ps = partition_size_based(&ids(n), m);
            assert_eq!(ps.len(), expect_p);
            let tasks = generate_tasks(&ps);
            assert_eq!(tasks.len(), size_based_task_count(expect_p));
        }
        // the paper's Fig 3 comparison: 6 partitions → 21 tasks
        assert_eq!(size_based_task_count(6), 21);
    }

    /// Pair-coverage invariant for size-based partitioning: every
    /// unordered entity pair is covered by exactly one task.
    #[test]
    fn prop_size_based_pairs_exactly_once() {
        forall("pairs-once", 40, |rng| {
            let n = 2 + rng.gen_range(120);
            let m = 1 + rng.gen_range(40);
            let ps = partition_size_based(&ids(n), m);
            let tasks = generate_tasks(&ps);
            let mut seen: HashSet<(u32, u32)> = HashSet::new();
            for t in &tasks {
                let l = &ps.get(t.left).entities;
                let r = &ps.get(t.right).entities;
                if t.left == t.right {
                    for i in 0..l.len() {
                        for j in (i + 1)..l.len() {
                            let key = (l[i].0.min(l[j].0), l[i].0.max(l[j].0));
                            assert!(seen.insert(key), "pair {key:?} twice");
                        }
                    }
                } else {
                    for &a in l {
                        for &b in r {
                            let key = (a.0.min(b.0), a.0.max(b.0));
                            assert!(seen.insert(key), "pair {key:?} twice");
                        }
                    }
                }
            }
            assert_eq!(seen.len(), n * (n - 1) / 2, "all pairs covered");
        });
    }

    fn make_blocks(sizes: &[(&str, usize)], misc: usize) -> Blocks {
        let mut b = Blocks::new();
        let mut next = 0u32;
        for (key, n) in sizes {
            for _ in 0..*n {
                b.add(key, EntityId(next));
                next += 1;
            }
        }
        for _ in 0..misc {
            b.add_misc(EntityId(next));
            next += 1;
        }
        b
    }

    /// Figure 3 (right): 12 match tasks for the tuned example.
    #[test]
    fn figure3_task_generation() {
        let blocks = make_blocks(
            &[
                ("3.5-drive", 1300),
                ("2.5-drive", 700),
                ("dvd-rw", 400),
                ("blu-ray", 200),
                ("hd-dvd", 200),
                ("cd-rw", 200),
            ],
            600,
        );
        let ps = tune(&blocks, TuningConfig::new(700, 210));
        let tasks = generate_tasks(&ps);
        // 1 (2.5) + 1 (dvd-rw) + 1 (aggregate) + 3 (split 3.5: 2 subs)
        // + 6 (misc × 5 partitions + misc itself) = 12
        assert_eq!(tasks.len(), 12);
        // no duplicate tasks
        let set: HashSet<(PartitionId, PartitionId)> = tasks
            .iter()
            .map(|t| {
                (t.left.min(t.right), t.left.max(t.right))
            })
            .collect();
        assert_eq!(set.len(), 12);
    }

    #[test]
    fn split_block_task_count() {
        // k sub-partitions → k + k(k-1)/2 tasks
        let blocks = make_blocks(&[("big", 3000)], 0);
        let ps = tune(&blocks, TuningConfig::new(700, 1));
        let k = ps.len(); // 3000/700 → 5 subs
        assert_eq!(k, 5);
        let tasks = generate_tasks(&ps);
        assert_eq!(tasks.len(), k + k * (k - 1) / 2);
    }

    /// Blocking-semantics coverage: every same-block pair and every
    /// misc×anything pair is covered at least once; nothing outside
    /// block∪aggregate∪misc relationships is compared... except pairs
    /// *introduced* by aggregation (allowed by the paper, traded in Fig 7).
    #[test]
    fn prop_blocking_pairs_covered() {
        forall("blocking-cover", 30, |rng| {
            let n_blocks = 1 + rng.gen_range(8);
            let names: Vec<String> =
                (0..n_blocks).map(|i| format!("b{i}")).collect();
            let sizes: Vec<(&str, usize)> = names
                .iter()
                .map(|n| (n.as_str(), 1 + rng.gen_range(60)))
                .collect();
            let misc = rng.gen_range(30);
            let blocks = make_blocks(&sizes, misc);
            let max_size = 10 + rng.gen_range(50);
            let min_size = rng.gen_range(max_size);
            let ps = tune(&blocks, TuningConfig::new(max_size, min_size));
            let tasks = generate_tasks(&ps);

            // pairs covered by the generated tasks (dedupe across tasks —
            // misc×sibling overlaps cannot occur, checked below)
            let mut covered: HashSet<(u32, u32)> = HashSet::new();
            let mut task_keys: HashSet<(PartitionId, PartitionId)> =
                HashSet::new();
            for t in &tasks {
                assert!(
                    task_keys.insert((
                        t.left.min(t.right),
                        t.left.max(t.right)
                    )),
                    "duplicate task"
                );
                let l = &ps.get(t.left).entities;
                let r = &ps.get(t.right).entities;
                if t.left == t.right {
                    for i in 0..l.len() {
                        for j in (i + 1)..l.len() {
                            covered.insert((
                                l[i].0.min(l[j].0),
                                l[i].0.max(l[j].0),
                            ));
                        }
                    }
                } else {
                    for &a in l {
                        for &b in r {
                            assert_ne!(a, b, "entity paired with itself");
                            covered.insert((a.0.min(b.0), a.0.max(b.0)));
                        }
                    }
                }
            }

            // required: same-original-block pairs
            for (_, ids) in blocks.iter() {
                for i in 0..ids.len() {
                    for j in (i + 1)..ids.len() {
                        let key =
                            (ids[i].0.min(ids[j].0), ids[i].0.max(ids[j].0));
                        assert!(
                            covered.contains(&key),
                            "same-block pair lost"
                        );
                    }
                }
            }
            // required: misc × everything
            let all_ids: Vec<u32> = (0..blocks.total_entities() as u32).collect();
            for &m in blocks.misc() {
                for &other in &all_ids {
                    if other == m.0 {
                        continue;
                    }
                    let key = (m.0.min(other), m.0.max(other));
                    assert!(covered.contains(&key), "misc pair lost");
                }
            }
        });
    }

    #[test]
    fn two_sources_cartesian_counts() {
        let a = partition_size_based(&ids(1000), 500); // 2 parts
        let b = partition_size_based(&ids(1500), 500); // 3 parts
        let tasks = generate_tasks_two_sources_cartesian(&a, &b);
        assert_eq!(tasks.len(), 6); // m*n, vs (m+n)(m+n-1)/2+5=15 combined
    }

    #[test]
    fn two_sources_blocked_matches_corresponding() {
        let blocks_a = make_blocks(&[("x", 50), ("y", 30)], 10);
        let blocks_b = make_blocks(&[("x", 40), ("z", 20)], 5);
        let pa = tune(&blocks_a, TuningConfig::new(100, 1));
        let pb = tune(&blocks_b, TuningConfig::new(100, 1));
        let tasks = generate_tasks_two_sources_blocked(&pa, &pb);
        // x↔x (1) + miscA×all B (3) + miscB×non-misc A (2) = 6
        assert_eq!(tasks.len(), 6);
    }

    /// Sorted-neighborhood windows: `k` windows → `k` intra tasks +
    /// `k−1` adjacent-overlap tasks, and misc partitions still pair
    /// with every window.
    #[test]
    fn window_task_generation_counts() {
        let mut ps = PartitionSet::new();
        for index in 0..4usize {
            let members: Vec<EntityId> = (index * 10..(index + 1) * 10)
                .map(|i| EntityId(i as u32))
                .collect();
            ps.push(
                crate::partition::PartitionKind::Window { index, count: 4 },
                members,
            );
        }
        let tasks = generate_tasks(&ps);
        assert_eq!(tasks.len(), 4 + 3, "4 intra + 3 adjacent overlaps");
        // adjacency only: no window skips its neighbor
        for t in &tasks {
            if t.left != t.right {
                assert_eq!(t.right.0, t.left.0 + 1);
            }
        }
        // with a misc partition, misc × every window is added
        ps.push(
            crate::partition::PartitionKind::Misc { index: 0, count: 1 },
            (40..45u32).map(EntityId).collect(),
        );
        let tasks = generate_tasks(&ps);
        assert_eq!(tasks.len(), 7 + 1 + 4, "+ misc intra + misc × windows");
    }

    /// The sliding-window guarantee: every pair of entities within
    /// `w` positions of each other in sort order is covered by some
    /// task, for any slice size ≥ w.
    #[test]
    fn prop_window_pairs_within_w_covered() {
        forall("window-cover", 40, |rng| {
            let n = 2 + rng.gen_range(300);
            let w = 2 + rng.gen_range(40);
            let m = w + rng.gen_range(60); // slice size >= window
            let all: Vec<EntityId> = ids(n);
            let mut ps = PartitionSet::new();
            let count = n.div_ceil(m);
            for (index, chunk) in all.chunks(m).enumerate() {
                ps.push(
                    crate::partition::PartitionKind::Window { index, count },
                    chunk.to_vec(),
                );
            }
            let tasks = generate_tasks(&ps);
            let mut covered: HashSet<(u32, u32)> = HashSet::new();
            for t in &tasks {
                let l = &ps.get(t.left).entities;
                let r = &ps.get(t.right).entities;
                if t.left == t.right {
                    for i in 0..l.len() {
                        for j in (i + 1)..l.len() {
                            covered.insert((l[i].0, l[j].0));
                        }
                    }
                } else {
                    for &a in l {
                        for &b in r {
                            covered.insert((a.0.min(b.0), a.0.max(b.0)));
                        }
                    }
                }
            }
            // entity ids are the sort positions here
            for a in 0..n as u32 {
                for b in (a + 1)..((a as usize + w).min(n) as u32) {
                    assert!(
                        covered.contains(&(a, b)),
                        "pair ({a},{b}) within w={w} lost (m={m})"
                    );
                }
            }
        });
    }

    #[test]
    fn misc_sub_partitions_pair_with_each_other() {
        let blocks = make_blocks(&[("a", 100)], 1500);
        let ps = tune(&blocks, TuningConfig::new(700, 1));
        let tasks = generate_tasks(&ps);
        // partitions: a + 3 misc subs. tasks: 1 (a) + misc: each self (3)
        // + misc-misc pairs (3) + each misc × a (3) = 10
        assert_eq!(tasks.len(), 10);
    }
}
