//! The paper's §4 services as **real network endpoints**.
//!
//! The seed modeled the workflow / data / match services of the paper's
//! distributed infrastructure as in-process objects plus a communication
//! *cost model* ([`crate::net`]).  This module makes them actual TCP
//! servers speaking the [`crate::rpc`] wire protocol, one blocking OS
//! thread per connection — the same architecture as the paper's RMI
//! deployment:
//!
//! * [`WorkflowServiceServer`] — owns the central task list and the
//!   *same* [`crate::coordinator::Scheduler`] the in-process engines
//!   use (FIFO + affinity policies), hands out tasks pull-style, merges
//!   completion reports, tracks membership (join/leave) and fails
//!   services whose heartbeats stop arriving, re-queueing their
//!   in-flight tasks;
//! * [`DataServiceServer`] — serves [`crate::store::PartitionData`]
//!   payloads over TCP, with per-fetch accounting of the **actual bytes
//!   on the wire** feeding a [`crate::net::TrafficStats`];
//! * [`MatchServiceNode`] ([`match_node`]) — runs the existing
//!   [`crate::worker::TaskExecutor`] + [`crate::worker::PartitionCache`]
//!   behind socket clients: join → pull task → fetch partitions → match
//!   → report completion with piggybacked cache status → repeat.
//!
//! The services compose three ways: in one process via
//! [`crate::engine::dist`] (threads with real sockets on localhost),
//! or across processes/machines via the `pem serve` (workflow + data)
//! and `pem distmatch` (match node) CLI subcommands.

pub mod data;
pub mod match_node;
pub mod workflow;

pub use data::DataServiceServer;
pub use match_node::{run_match_node, MatchNodeConfig, NodeReport};
pub use workflow::{
    WorkflowReport, WorkflowServerConfig, WorkflowServiceServer,
};

/// Convenience: a match-service node handle (config + entry point) —
/// see [`match_node`].
pub use match_node::MatchServiceNode;
