//! The paper's §4 services as **real network endpoints**.
//!
//! The seed modeled the workflow / data / match services of the paper's
//! distributed infrastructure as in-process objects plus a communication
//! *cost model* ([`crate::net`]).  This module makes them actual TCP
//! servers speaking the [`crate::rpc`] wire protocol.  Since PR 3 both
//! servers run on the readiness-driven [`crate::net::reactor`] — one
//! thread per *server* over nonblocking sockets, frames decoded
//! incrementally by [`crate::rpc::session`] — instead of the paper-era
//! one-blocking-thread-per-connection model, so a coordinator scales
//! past a few dozen match workers; and task assignment is **batched**
//! (protocol v3): a node pulls up to `batch` tasks per round trip with
//! its completion reports piggybacked on the same frame:
//!
//! * [`WorkflowServiceServer`] — owns the central task list and the
//!   *same* [`crate::coordinator::Scheduler`] the in-process engines
//!   use (FIFO + affinity policies), hands out tasks pull-style, merges
//!   completion reports, tracks membership (join/leave) and fails
//!   services whose heartbeats stop arriving, re-queueing their
//!   in-flight tasks;
//! * [`DataServiceServer`] — serves [`crate::store::PartitionData`]
//!   payloads over TCP, with per-fetch accounting of the **actual bytes
//!   on the wire** feeding a [`crate::net::TrafficStats`].  Runs either
//!   as the authoritative **primary** or as a **replica** that holds
//!   push-synced encoded partition frames and redirects misses
//!   ([`data`]) — the replicated data plane removes the single data
//!   server as both bandwidth bottleneck and single point of failure;
//! * [`MatchServiceNode`] ([`match_node`]) — runs the existing
//!   [`crate::worker::TaskExecutor`] + [`crate::worker::PartitionCache`]
//!   behind socket clients: join → pull task → fetch partitions → match
//!   → report completion with piggybacked cache status → repeat.
//!   Partition fetches pick a replica via [`ReplicaSelector`]
//!   (cached-locality first, then least-outstanding-fetches) and fail
//!   over to the next replica on connection errors.
//!
//! The services compose three ways: in one process via
//! [`crate::engine::dist`] (threads with real sockets on localhost),
//! or across processes/machines via the `pem serve` (workflow + data,
//! or `--role data` for a standalone replica) and `pem distmatch`
//! (match node) CLI subcommands.  `docs/ARCHITECTURE.md` has the full
//! layer map and data-flow diagrams.
//!
//! Since protocol v7 the workflow server can also run **resident and
//! multi-tenant** ([`TenantHostConfig`]): many clients submit
//! serialized match plans over the wire (`pem submit`), admission is
//! checked against the cluster's aggregate §3.1 budget
//! ([`AdmissionDenied`]), and admitted plans are fair-scheduled side
//! by side with isolated result channels.

#![warn(missing_docs)]

pub mod data;
pub mod match_node;
pub mod replica;
pub mod workflow;

pub use data::DataServiceServer;
pub use match_node::{run_match_node, MatchNodeConfig, NodeReport};
pub use replica::{announce_replica, ReplicaSelector};
pub use workflow::{
    AdmissionDenied, TenantHostConfig, WaitStatus, WorkflowReport,
    WorkflowServerConfig, WorkflowServiceServer, TENANT_ABORTED,
    TENANT_DONE, TENANT_FAILED, TENANT_RUNNING,
};

/// Convenience: a match-service node handle (config + entry point) —
/// see [`match_node`].
pub use match_node::MatchServiceNode;
