//! The workflow service as a TCP endpoint (paper §4).
//!
//! Owns the central task list behind the *same* [`Scheduler`] the
//! in-process engines use, and serves it pull-style over the wire:
//!
//! * `Join` → protocol-version check, then membership + a fresh
//!   [`ServiceId`] + the data-plane replica directory (mismatched
//!   versions are rejected with a clear `Error`, paper-era RMI would
//!   have deserialization-failed instead);
//! * `ReplicaAnnounce` → a data server (primary or replica) registers
//!   its address and partition list; the directory is handed to every
//!   joining match service and the partition list feeds replica-aware
//!   affinity scheduling ([`Scheduler::add_replica_coverage`]);
//! * `TaskRequest` / `Complete` → next assignment (`TaskAssign`, or
//!   `NoTask {done}` when the open list is empty), with completion
//!   reports carrying the piggybacked cache status that feeds
//!   affinity-based scheduling;
//! * `Heartbeat` → liveness; a monitor thread fails services whose
//!   heartbeats stop arriving within the configured timeout and
//!   re-queues their in-flight tasks (paper §4 failure handling);
//! * `Leave` → graceful departure (in-flight tasks re-queued).
//!
//! Stale completions — a service presumed dead that reports anyway —
//! are dropped via [`Scheduler::try_report_complete`] instead of
//! crashing the coordinator.

use crate::coordinator::scheduler::{Policy, Scheduler, ServiceId};
use crate::model::Correspondence;
use crate::net::TrafficStats;
use crate::partition::MatchTask;
use crate::rpc::{Message, Transport, PROTOCOL_VERSION};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Workflow-server tuning.
#[derive(Clone, Copy, Debug)]
pub struct WorkflowServerConfig {
    /// Scheduling policy for the central task list.
    pub policy: Policy,
    /// A service that has not been heard from for this long is failed
    /// and its in-flight tasks re-queued.
    pub heartbeat_timeout: Duration,
}

impl Default for WorkflowServerConfig {
    fn default() -> Self {
        WorkflowServerConfig {
            policy: Policy::Affinity,
            heartbeat_timeout: Duration::from_secs(2),
        }
    }
}

struct Member {
    name: String,
    last_seen: Instant,
}

struct WfShared {
    sched: Mutex<Scheduler>,
    results: Mutex<Vec<Correspondence>>,
    members: Mutex<HashMap<usize, Member>>,
    next_service: AtomicUsize,
    comparisons: AtomicU64,
    /// Control-plane frames received (assignments are counted on send
    /// inside the reply to the same frame, so this ≈ the paper's
    /// "2 messages per task" plus heartbeats and membership).
    control_messages: AtomicU64,
    /// Control-plane wire bytes sent (replies).
    traffic: TrafficStats,
    requeued_tasks: AtomicU64,
    stale_completions: AtomicU64,
    /// Peers rejected for speaking a different protocol version.
    version_rejections: AtomicU64,
    /// Data-plane replica directory, announcement order, deduplicated.
    replicas: Mutex<Vec<String>>,
    shutdown: AtomicBool,
    heartbeat_timeout: Duration,
}

impl WfShared {
    fn touch(&self, service: ServiceId) {
        let mut members = self.members.lock().unwrap();
        members
            .entry(service.0)
            .and_modify(|m| m.last_seen = Instant::now())
            .or_insert_with(|| Member {
                name: format!("service-{}(rejoined)", service.0),
                last_seen: Instant::now(),
            });
    }

    /// Reply to a pull (TaskRequest or Complete): the next assignment.
    fn next_assignment(&self, service: ServiceId) -> Message {
        let mut sched = self.sched.lock().unwrap();
        match sched.next_task(service) {
            Some(task) => Message::TaskAssign { task },
            None => Message::NoTask {
                done: sched.is_done(),
            },
        }
    }
}

/// Final statistics of a workflow run, extracted by
/// [`WorkflowServiceServer::finish`].
#[derive(Debug)]
pub struct WorkflowReport {
    /// Merged per-task match output in completion order.
    pub correspondences: Vec<Correspondence>,
    /// Tasks completed (exactly once each).
    pub completed_tasks: usize,
    /// Tasks the workflow started with.
    pub total_tasks: usize,
    /// Total pair comparisons reported by match services.
    pub comparisons: u64,
    /// Control-plane frames received.
    pub control_messages: u64,
    /// Control-plane bytes sent over sockets.
    pub control_wire_bytes: u64,
    /// Assignments that hit at least one cached partition.
    pub affinity_assignments: u64,
    /// Tasks re-queued because their service failed or left.
    pub requeued_tasks: u64,
    /// Completion reports dropped as stale (service presumed dead).
    pub stale_completions: u64,
    /// Services that ever joined.
    pub services_joined: usize,
    /// Peers rejected at join/announce for a protocol-version mismatch.
    pub version_rejections: u64,
    /// Data-plane replica directory at the end of the run.
    pub data_replicas: Vec<String>,
}

/// A running workflow-service endpoint.
pub struct WorkflowServiceServer {
    addr: SocketAddr,
    shared: Arc<WfShared>,
}

impl WorkflowServiceServer {
    /// Seed the central task list and start serving on `bind`
    /// (`"127.0.0.1:0"` for an ephemeral port).
    pub fn start(
        tasks: Vec<MatchTask>,
        cfg: WorkflowServerConfig,
        bind: &str,
    ) -> anyhow::Result<WorkflowServiceServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(WfShared {
            sched: Mutex::new(Scheduler::new(tasks, cfg.policy)),
            results: Mutex::new(Vec::new()),
            members: Mutex::new(HashMap::new()),
            next_service: AtomicUsize::new(0),
            comparisons: AtomicU64::new(0),
            control_messages: AtomicU64::new(0),
            traffic: TrafficStats::new(),
            requeued_tasks: AtomicU64::new(0),
            stale_completions: AtomicU64::new(0),
            version_rejections: AtomicU64::new(0),
            replicas: Mutex::new(Vec::new()),
            shutdown: AtomicBool::new(false),
            heartbeat_timeout: cfg.heartbeat_timeout,
        });
        let accept_shared = shared.clone();
        std::thread::Builder::new()
            .name("pem-workflow-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))?;
        let monitor_shared = shared.clone();
        std::thread::Builder::new()
            .name("pem-workflow-monitor".into())
            .spawn(move || monitor_loop(monitor_shared))?;
        Ok(WorkflowServiceServer { addr, shared })
    }

    /// The bound address (for clients).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Tasks completed so far (for progress displays).
    pub fn completed(&self) -> usize {
        self.shared.sched.lock().unwrap().completed()
    }

    /// Block until every task has completed, polling the scheduler.
    /// Returns `false` on timeout.
    pub fn wait_done(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            if self.shared.sched.lock().unwrap().is_done() {
                return true;
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Tear the server down without consuming the handle: stops the
    /// accept and monitor loops and makes every connection handler drop
    /// its connection at the next received frame, so match services
    /// unblock with an I/O error even when the workflow never finished
    /// (run-timeout path).  Idempotent.
    pub fn abort(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect_timeout(
            &self.addr,
            Duration::from_millis(200),
        );
    }

    /// Stop the accept and monitor loops and extract the final report.
    /// Call after [`Self::wait_done`]; open connections drain when the
    /// match services disconnect.
    pub fn finish(self) -> WorkflowReport {
        self.abort();
        let sched = self.shared.sched.lock().unwrap();
        WorkflowReport {
            correspondences: std::mem::take(
                &mut *self.shared.results.lock().unwrap(),
            ),
            completed_tasks: sched.completed(),
            total_tasks: sched.total(),
            comparisons: self.shared.comparisons.load(Ordering::Relaxed),
            control_messages: self
                .shared
                .control_messages
                .load(Ordering::Relaxed),
            control_wire_bytes: self.shared.traffic.total_bytes(),
            affinity_assignments: sched.affinity_assignments,
            requeued_tasks: self
                .shared
                .requeued_tasks
                .load(Ordering::Relaxed),
            stale_completions: self
                .shared
                .stale_completions
                .load(Ordering::Relaxed),
            services_joined: self.shared.next_service.load(Ordering::Relaxed),
            version_rejections: self
                .shared
                .version_rejections
                .load(Ordering::Relaxed),
            data_replicas: self.shared.replicas.lock().unwrap().clone(),
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<WfShared>) {
    loop {
        let Ok((stream, _)) = listener.accept() else { break };
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let conn_shared = shared.clone();
        let _ = std::thread::Builder::new()
            .name("pem-workflow-conn".into())
            .spawn(move || handle_conn(stream, conn_shared));
    }
}

/// Detect dead services: no message within the heartbeat timeout →
/// fail the service, re-queue its in-flight tasks (paper §4).
fn monitor_loop(shared: Arc<WfShared>) {
    let tick = (shared.heartbeat_timeout / 4).max(Duration::from_millis(5));
    while !shared.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        let now = Instant::now();
        let expired: Vec<(usize, String)> = {
            let mut members = shared.members.lock().unwrap();
            let dead: Vec<usize> = members
                .iter()
                .filter(|(_, m)| {
                    now.duration_since(m.last_seen)
                        > shared.heartbeat_timeout
                })
                .map(|(&id, _)| id)
                .collect();
            dead.into_iter()
                .map(|id| (id, members.remove(&id).expect("listed").name))
                .collect()
        };
        for (id, name) in expired {
            let reopened = shared
                .sched
                .lock()
                .unwrap()
                .fail_service(ServiceId(id));
            shared
                .requeued_tasks
                .fetch_add(reopened as u64, Ordering::Relaxed);
            eprintln!(
                "workflow service: match service {id} ({name}) missed \
                 heartbeats; re-queued {reopened} in-flight task(s)"
            );
        }
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<WfShared>) {
    let Ok(mut t) = Transport::from_stream(stream) else {
        return;
    };
    while let Ok(msg) = t.recv() {
        if shared.shutdown.load(Ordering::SeqCst) {
            // aborted server: drop the connection instead of answering,
            // so clients stuck in poll loops error out and exit
            break;
        }
        shared.control_messages.fetch_add(1, Ordering::Relaxed);
        let reply = match msg {
            Message::Join { name, version } => {
                if version != PROTOCOL_VERSION {
                    shared
                        .version_rejections
                        .fetch_add(1, Ordering::Relaxed);
                    Message::Error {
                        message: format!(
                            "protocol version mismatch: match service \
                             {name:?} speaks v{version}, this \
                             coordinator speaks v{PROTOCOL_VERSION} — \
                             upgrade the older side"
                        ),
                    }
                } else {
                    let id =
                        shared.next_service.fetch_add(1, Ordering::SeqCst);
                    shared.members.lock().unwrap().insert(
                        id,
                        Member {
                            name,
                            last_seen: Instant::now(),
                        },
                    );
                    shared.sched.lock().unwrap().add_service(ServiceId(id));
                    Message::JoinAck {
                        service: ServiceId(id),
                        version: PROTOCOL_VERSION,
                        replicas: shared.replicas.lock().unwrap().clone(),
                    }
                }
            }
            Message::ReplicaAnnounce {
                addr,
                version,
                partitions,
            } => {
                if version != PROTOCOL_VERSION {
                    shared
                        .version_rejections
                        .fetch_add(1, Ordering::Relaxed);
                    Message::Error {
                        message: format!(
                            "protocol version mismatch: data replica \
                             {addr} speaks v{version}, this coordinator \
                             speaks v{PROTOCOL_VERSION} — upgrade the \
                             older side"
                        ),
                    }
                } else {
                    let directory = {
                        let mut dir = shared.replicas.lock().unwrap();
                        let fresh = !dir.contains(&addr);
                        if fresh {
                            dir.push(addr);
                        }
                        (fresh, dir.clone())
                    };
                    // count coverage only on first announcement, so a
                    // replica re-announcing (reconnect) does not inflate
                    // the per-partition replica counts
                    if directory.0 {
                        shared
                            .sched
                            .lock()
                            .unwrap()
                            .add_replica_coverage(&partitions);
                    }
                    Message::ReplicaDirectory {
                        replicas: directory.1,
                    }
                }
            }
            Message::Leave { service } => {
                shared.members.lock().unwrap().remove(&service.0);
                let reopened = shared
                    .sched
                    .lock()
                    .unwrap()
                    .fail_service(service);
                shared
                    .requeued_tasks
                    .fetch_add(reopened as u64, Ordering::Relaxed);
                Message::LeaveAck
            }
            Message::TaskRequest { service } => {
                shared.touch(service);
                shared.next_assignment(service)
            }
            Message::Complete {
                service,
                task_id,
                comparisons,
                cached,
                matches,
            } => {
                shared.touch(service);
                {
                    // hold the scheduler lock across the result append:
                    // `is_done()` must never be observable as true while
                    // this task's output is not yet in `results`, or a
                    // wait_done() → finish() sequence could drain the
                    // results missing the final task's matches.  Lock
                    // order is sched → results here and in finish().
                    let mut sched = shared.sched.lock().unwrap();
                    if sched.try_report_complete(service, task_id, cached)
                    {
                        shared
                            .comparisons
                            .fetch_add(comparisons, Ordering::Relaxed);
                        shared.results.lock().unwrap().extend(matches);
                    } else {
                        // straggler from a service presumed dead: the
                        // task was re-queued, its output arrives again
                        shared
                            .stale_completions
                            .fetch_add(1, Ordering::Relaxed);
                    }
                }
                shared.next_assignment(service)
            }
            Message::Heartbeat { service } => {
                shared.touch(service);
                Message::HeartbeatAck
            }
            other => Message::Error {
                message: format!(
                    "workflow service got unexpected {}",
                    other.kind()
                ),
            },
        };
        match t.send(&reply) {
            Ok(n) => shared.traffic.record(n),
            Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::PartitionId;

    fn task(id: u32, l: u32, r: u32) -> MatchTask {
        MatchTask {
            id,
            left: PartitionId(l),
            right: PartitionId(r),
        }
    }

    fn client(addr: SocketAddr) -> Transport {
        Transport::connect(addr, Duration::from_secs(5)).unwrap()
    }

    fn join(t: &mut Transport, name: &str) -> ServiceId {
        match t
            .request(&Message::Join {
                name: name.into(),
                version: PROTOCOL_VERSION,
            })
            .unwrap()
        {
            Message::JoinAck { service, .. } => service,
            other => panic!("expected JoinAck, got {}", other.kind()),
        }
    }

    #[test]
    fn full_pull_protocol_round() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 1), task(1, 2, 3)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let svc = join(&mut c, "test-node");

        // initial pull
        let Message::TaskAssign { task: t0 } =
            c.request(&Message::TaskRequest { service: svc }).unwrap()
        else {
            panic!("expected assignment");
        };
        // completion piggybacks the next pull
        let reply = c
            .request(&Message::Complete {
                service: svc,
                task_id: t0.id,
                comparisons: 10,
                cached: vec![t0.left, t0.right],
                matches: vec![Correspondence {
                    e1: crate::model::EntityId(1),
                    e2: crate::model::EntityId(2),
                    sim: 0.9,
                }],
            })
            .unwrap();
        let Message::TaskAssign { task: t1 } = reply else {
            panic!("expected second assignment, got {}", reply.kind());
        };
        assert_ne!(t0.id, t1.id);
        let reply = c
            .request(&Message::Complete {
                service: svc,
                task_id: t1.id,
                comparisons: 5,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(matches!(reply, Message::NoTask { done: true }));

        assert!(srv.wait_done(Duration::from_secs(1)));
        let _ = c.request(&Message::Leave { service: svc });
        let report = srv.finish();
        assert_eq!(report.completed_tasks, 2);
        assert_eq!(report.total_tasks, 2);
        assert_eq!(report.comparisons, 15);
        assert_eq!(report.correspondences.len(), 1);
        assert!(report.control_messages >= 4);
        assert!(report.control_wire_bytes > 0);
        assert_eq!(report.services_joined, 1);
    }

    /// The ROADMAP bugfix: frames used to carry no protocol version, so
    /// a mismatched peer would fail with a confusing decode error deep
    /// into a run.  Now a `Join` or `ReplicaAnnounce` from the wrong
    /// version is rejected up front with a clear message, and the peer
    /// is never admitted.
    #[test]
    fn version_mismatch_rejected_with_clear_error() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 0)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let reply = c
            .request(&Message::Join {
                name: "time-traveler".into(),
                version: PROTOCOL_VERSION + 1,
            })
            .unwrap();
        let Message::Error { message } = reply else {
            panic!("v{} join must be rejected", PROTOCOL_VERSION + 1);
        };
        assert!(
            message.contains("version mismatch"),
            "unclear rejection: {message}"
        );
        assert!(message.contains(&format!("v{}", PROTOCOL_VERSION + 1)));
        assert!(message.contains(&format!("v{PROTOCOL_VERSION}")));

        let reply = c
            .request(&Message::ReplicaAnnounce {
                addr: "10.0.0.9:7402".into(),
                version: 0,
                partitions: vec![PartitionId(0)],
            })
            .unwrap();
        assert!(matches!(reply, Message::Error { .. }));

        // a correct-version peer still joins, and no service id was
        // burned on the rejected one
        let svc = join(&mut c, "contemporary");
        assert_eq!(svc, ServiceId(0));
        let report = srv.finish();
        assert_eq!(report.version_rejections, 2);
        assert_eq!(report.services_joined, 1);
        assert!(report.data_replicas.is_empty());
    }

    /// Announced replicas accumulate in the directory and are handed to
    /// every subsequently joining match service; re-announcement is
    /// idempotent.
    #[test]
    fn replica_directory_grows_and_reaches_joiners() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 0)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let announce = |c: &mut Transport, addr: &str| {
            match c
                .request(&Message::ReplicaAnnounce {
                    addr: addr.into(),
                    version: PROTOCOL_VERSION,
                    partitions: vec![PartitionId(0), PartitionId(1)],
                })
                .unwrap()
            {
                Message::ReplicaDirectory { replicas } => replicas,
                other => panic!("expected directory, got {}", other.kind()),
            }
        };
        assert_eq!(announce(&mut c, "10.0.0.1:7402"), vec!["10.0.0.1:7402"]);
        let dir = announce(&mut c, "10.0.0.2:7402");
        assert_eq!(dir, vec!["10.0.0.1:7402", "10.0.0.2:7402"]);
        // idempotent re-announce (e.g. after a replica reconnects)
        assert_eq!(announce(&mut c, "10.0.0.1:7402"), dir);

        let reply = c
            .request(&Message::Join {
                name: "late-joiner".into(),
                version: PROTOCOL_VERSION,
            })
            .unwrap();
        let Message::JoinAck { replicas, .. } = reply else {
            panic!("expected JoinAck, got {}", reply.kind());
        };
        assert_eq!(replicas, dir, "directory delivered at join");
        let report = srv.finish();
        assert_eq!(report.data_replicas, dir);
        assert_eq!(report.version_rejections, 0);
    }

    #[test]
    fn missed_heartbeats_requeue_in_flight_tasks() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 0)],
            WorkflowServerConfig {
                policy: Policy::Fifo,
                heartbeat_timeout: Duration::from_millis(80),
            },
            "127.0.0.1:0",
        )
        .unwrap();
        // node A joins, takes the task, then goes silent
        let mut a = client(srv.addr());
        let svc_a = join(&mut a, "doomed");
        let Message::TaskAssign { task: t } = a
            .request(&Message::TaskRequest { service: svc_a })
            .unwrap()
        else {
            panic!("expected assignment");
        };
        std::thread::sleep(Duration::from_millis(300));

        // node B joins and receives the re-queued task
        let mut b = client(srv.addr());
        let svc_b = join(&mut b, "survivor");
        let Message::TaskAssign { task: re } = b
            .request(&Message::TaskRequest { service: svc_b })
            .unwrap()
        else {
            panic!("re-queued task not offered");
        };
        assert_eq!(re.id, t.id);

        // the doomed node's stale completion is dropped…
        let stale = a
            .request(&Message::Complete {
                service: svc_a,
                task_id: t.id,
                comparisons: 1,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(matches!(stale, Message::NoTask { .. }));
        // …and does not mark the workflow done
        assert!(!srv.wait_done(Duration::from_millis(50)));

        // the survivor's completion does
        let done = b
            .request(&Message::Complete {
                service: svc_b,
                task_id: re.id,
                comparisons: 1,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(matches!(done, Message::NoTask { done: true }));
        assert!(srv.wait_done(Duration::from_secs(1)));
        let report = srv.finish();
        assert_eq!(report.completed_tasks, 1);
        assert_eq!(report.requeued_tasks, 1);
        assert_eq!(report.stale_completions, 1);
    }
}
