//! The workflow service as a TCP endpoint (paper §4).
//!
//! Owns the central task list behind the *same* [`Scheduler`] the
//! in-process engines use, and serves it pull-style over the wire:
//!
//! * `Join` → protocol-version check, then membership + a fresh
//!   [`ServiceId`] + the data-plane replica directory (mismatched
//!   versions are rejected with a clear `Error`, paper-era RMI would
//!   have deserialization-failed instead);
//! * `ReplicaAnnounce` → a data server (primary or replica) registers
//!   its address and partition list; the directory is handed to every
//!   joining match service and the partition list feeds replica-aware
//!   affinity scheduling ([`Scheduler::add_replica_coverage`]);
//! * `TaskRequest` / `Complete` → next assignment (`TaskAssign`, or
//!   `NoTask {done}` when the open list is empty), with completion
//!   reports carrying the piggybacked cache status that feeds
//!   affinity-based scheduling;
//! * `TaskRequestBatch` (protocol v3) → up to `max` assignments in one
//!   `TaskAssignBatch` reply, with every completion since the node's
//!   last pull piggybacked on the request — one control round trip per
//!   batch instead of per task;
//! * `Heartbeat` → liveness; a monitor thread fails services whose
//!   heartbeats stop arriving within the configured timeout and
//!   re-queues their in-flight tasks (paper §4 failure handling);
//! * `Leave` → graceful departure (in-flight tasks re-queued).
//!
//! Since PR 3 the server runs on the readiness-driven
//! [`crate::net::reactor`]: **one thread serves every connection**,
//! decoding frames incrementally from arbitrary read chunks
//! ([`crate::rpc::session`]) instead of burning one blocking OS thread
//! per match worker.  Since PR 8 that thread parks in the kernel
//! (`epoll`/`poll(2)`) between frames, [`WorkflowServiceServer::abort`]
//! wakes it through a [`crate::net::poll::Waker`], and the server can
//! be co-hosted with the data service on one shared reactor
//! ([`WorkflowServiceServer::start_on`]).
//!
//! A service the failure detector has declared dead is *fenced*: its
//! pulls, completions and heartbeats are answered with `Error` (the
//! node treats that as fatal and must re-join for a fresh
//! [`ServiceId`]), and [`Scheduler::try_report_complete`]'s generation
//! check drops its stragglers — a resurrected zombie can no longer
//! double-complete a re-queued task.
//!
//! Since protocol v5 the server also drives **runtime task
//! splitting**: joins report each node's §3.1 budget, `TaskRejected`
//! feeds [`Scheduler::reject_task`], and once every live node has
//! rejected a task the scheduler reshapes it — subsequent assignments
//! carry the sub-tasks' pair-space spans, and their completions merge
//! back into the plan task exactly once.  A task that cannot be split
//! surfaces the typed [`PlanMisfit`] through
//! [`WorkflowServiceServer::wait_outcome`] / the final report, so
//! callers fail fast instead of idling to their run timeout.
//!
//! Since protocol v7 the server can run **resident and multi-tenant**:
//! configured with a [`TenantHostConfig`], it accepts `PlanSubmit`
//! frames carrying a serialized [`MatchPlan`] from any number of
//! client connections.  Each admitted plan becomes a *tenant* — a row
//! in the tenant table with its own task-id and partition-id range,
//! isolated result channel, and lifecycle state machine (running →
//! done / aborted / failed) — and its tasks are fair-scheduled against
//! every other tenant's by the scheduler's deficit round-robin.
//! Admission is checked up front against the aggregate of the live
//! nodes' v5 join-time budgets: a plan whose §3.1 footprint the
//! cluster can never hold is refused with the typed
//! [`AdmissionDenied`] numbers instead of queue-and-hang.  Clients
//! poll `PlanStatus` for progress and collect the terminal
//! `PlanResult`; a client connection that drops mid-run aborts its
//! running plans and drains their tasks, so surviving tenants get the
//! cluster back.  In resident mode `NoTask`/`TaskAssignBatch` replies
//! never report `done`, so match nodes stay attached between plans.

use crate::coordinator::plan::MatchPlan;
use crate::coordinator::scheduler::{
    PlanMisfit, Policy, Scheduler, ServiceId,
};
use crate::model::{Correspondence, Dataset};
use crate::net::poll::Waker;
use crate::net::reactor::{Action, ConnId, FrameHandler, Reactor};
use crate::net::TrafficStats;
use crate::obs::{
    system_clock, Clock, Counter, MetricsSnapshot, Registry, Stopwatch,
    Tracer,
};
use crate::partition::{MatchTask, PartitionId};
use crate::store::DataService;
use crate::util::lock_poisonless;
use crate::rpc::session::SessionEncoder;
use crate::rpc::{AssignedTask, CompletedTask, Message, PROTOCOL_VERSION};
use std::collections::{HashMap, HashSet};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Server-side cap on one batch assignment, whatever the node asks
/// for (a hostile `max` must not drain the whole open list into one
/// slow worker).
const MAX_ASSIGN_BATCH: usize = 256;

/// Tenant lifecycle states as they travel on the wire
/// (`PlanStatusReport.state` / `PlanResult.state`, protocol v7).
/// `RUNNING` is the only non-terminal state; the terminal ones are
/// answered with an idempotent `PlanResult`.
pub const TENANT_RUNNING: u8 = 1;
/// Terminal: every task of the plan completed; `PlanResult` carries
/// the tenant's merged correspondences.
pub const TENANT_DONE: u8 = 2;
/// Terminal: the submitting client's connection closed while the plan
/// was running; its tasks were drained.
pub const TENANT_ABORTED: u8 = 3;
/// Terminal: a task of the plan was rejected by every live node and
/// could not be split (the per-tenant [`PlanMisfit`]); the plan's
/// remaining tasks were drained.
pub const TENANT_FAILED: u8 = 4;

/// The typed admission-control refusal (protocol v7): the submitted
/// plan's aggregate §3.1 footprint exceeds what the live cluster's
/// join-time budgets can ever hold, so the plan is refused *at
/// submission* — in milliseconds, with the numbers — instead of
/// queueing tasks that would be rejected by every node and burn the
/// client's run timeout.  Travels as `PlanRejected { required,
/// available }`; `pem submit` rebuilds it client-side so callers can
/// downcast just like for [`PlanMisfit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdmissionDenied {
    /// Aggregate §3.1 footprint of the submitted plan, bytes.
    pub required: u64,
    /// Aggregate join-time budget of the live match nodes, bytes, at
    /// the moment of submission (0 = no live node).
    pub available: u64,
}

impl std::fmt::Display for AdmissionDenied {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "admission denied: the plan's aggregate §3.1 footprint is \
             {} but the live cluster's total budget is {} — add nodes \
             (or memory) and re-submit",
            crate::util::fmt_bytes(self.required),
            crate::util::fmt_bytes(self.available)
        )
    }
}

impl std::error::Error for AdmissionDenied {}

/// Host-side resources that make the workflow server *resident and
/// multi-tenant* (protocol v7): with this configured it accepts
/// `PlanSubmit` frames at run time, loads each admitted plan's tuned
/// partitions into the shared data service under a fresh id range,
/// and keeps match nodes attached between plans (`NoTask` /
/// `TaskAssignBatch` replies never report `done`).
#[derive(Clone)]
pub struct TenantHostConfig {
    /// The resident dataset every submitted plan must have been built
    /// for — checked via the plan's provenance fingerprint; a plan
    /// built against different data is refused at submission.
    pub dataset: Arc<Dataset>,
    /// The coordinator's primary data service: an admitted plan's
    /// partitions are re-materialized into it (ids offset into a
    /// fresh range) so match nodes fetch them exactly like seed
    /// partitions, and replicas pick them up via anti-entropy sync.
    pub store: Arc<DataService>,
    /// Per-tenant in-flight cap for the scheduler's deficit
    /// round-robin: at most this many of one tenant's tasks may be
    /// assigned-and-unreported at once, so a huge plan cannot starve
    /// a small one.  `None` = uncapped (fairness then rests on the
    /// round-robin alone).
    pub per_tenant_inflight: Option<usize>,
}

impl std::fmt::Debug for TenantHostConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TenantHostConfig")
            .field("dataset_entities", &self.dataset.entities.len())
            .field("store_partitions", &self.store.n_partitions())
            .field("per_tenant_inflight", &self.per_tenant_inflight)
            .finish()
    }
}

/// One row of the tenant table: an admitted plan's lifecycle record.
struct Tenant {
    /// Client-supplied label (diagnostics and `pem stats` rows).
    name: String,
    /// The control connection that submitted the plan: if it closes
    /// while the plan is running, the plan is aborted and its tasks
    /// drained ([`WfHandler::on_close`]).
    conn: ConnId,
    /// One of the `TENANT_*` states.
    state: u8,
    /// The tenant's isolated result channel — completions of its
    /// tasks land here, never in the seed workflow's `results`.
    results: Vec<Correspondence>,
    /// Pair comparisons reported for this tenant's tasks.
    comparisons: u64,
    /// Human-readable terminal detail (abort/failure reason).
    detail: String,
}

/// Workflow-server tuning.
#[derive(Clone, Debug)]
pub struct WorkflowServerConfig {
    /// Scheduling policy for the central task list.
    pub policy: Policy,
    /// A service that has not been heard from for this long is failed
    /// and its in-flight tasks re-queued.
    pub heartbeat_timeout: Duration,
    /// §3.1 memory footprint per task id (from the match plan),
    /// attached to every assignment (protocol v4) so nodes can reject
    /// work that exceeds their budget.  Tasks without an entry are
    /// assigned with footprint 0 (never rejected).
    pub task_mem: HashMap<u32, u64>,
    /// `(left, right)` partition entity counts per task id (from the
    /// match plan): the split metadata that lets the scheduler
    /// reshape a task every live node has rejected (protocol v5
    /// runtime splitting).  Empty disables splitting — an
    /// all-rejected task then fails fast with [`PlanMisfit`].
    pub task_sizes: HashMap<u32, (u32, u32)>,
    /// Match services expected to join: splitting (and the misfit
    /// verdict) waits until this many have, so a fast first node
    /// cannot declare a task unplaceable while its roomier peers are
    /// still connecting.  The dist engine sets its node count; an
    /// elastic `pem serve` keeps the default 1.
    pub expected_services: usize,
    /// Lifecycle tracer handed to the scheduler: every scheduling
    /// decision (assignment, rejection, splitting, requeueing,
    /// completion) is recorded for `--trace` dumps and the
    /// exactly-once replay verifier.  `None` disables tracing.
    pub tracer: Option<Arc<Tracer>>,
    /// Protocol v7 multi-tenancy: when set, the server is *resident*
    /// — it accepts `PlanSubmit` frames against this dataset/store
    /// and keeps match nodes attached between plans.  `None` (the
    /// default) keeps the one-shot behaviour: submissions are
    /// refused and the server reports `done` when the seed workflow
    /// drains.
    pub tenancy: Option<TenantHostConfig>,
}

impl Default for WorkflowServerConfig {
    fn default() -> Self {
        WorkflowServerConfig {
            policy: Policy::Affinity,
            heartbeat_timeout: Duration::from_secs(2),
            task_mem: HashMap::new(),
            task_sizes: HashMap::new(),
            expected_services: 1,
            tracer: None,
            tenancy: None,
        }
    }
}

struct Member {
    name: String,
    /// [`Clock`] timestamp (ns) of the last frame from this service.
    last_seen: u64,
}

struct WfShared {
    sched: Mutex<Scheduler>,
    results: Mutex<Vec<Correspondence>>,
    members: Mutex<HashMap<usize, Member>>,
    next_service: AtomicUsize,
    /// Metrics registry behind every counter below; snapshotted for
    /// `StatsReport` replies and the final report.  The counters are
    /// registry handles (one relaxed atomic each), so the hot paths
    /// pay no name lookups.
    registry: Arc<Registry>,
    comparisons: Arc<Counter>,
    /// Control-plane frames received (assignments are counted on send
    /// inside the reply to the same frame, so this ≈ the paper's
    /// "2 messages per task" plus heartbeats and membership).
    control_messages: Arc<Counter>,
    /// Heartbeat frames received (subset of `control_messages`;
    /// subtracting them isolates the per-task coordination cost).
    heartbeats: Arc<Counter>,
    /// v3 batch pulls received ([`Message::TaskRequestBatch`]).
    batch_requests: Arc<Counter>,
    /// Pulls that carried no completion report (initial requests and
    /// drain-time polls) — the round trips whose *only* purpose was
    /// obtaining work.  With completion piggybacking these are the
    /// marginal assignment cost, near zero per task.
    assignment_pulls: Arc<Counter>,
    /// Control-plane wire bytes sent (replies).
    traffic: TrafficStats,
    requeued_tasks: Arc<Counter>,
    stale_completions: Arc<Counter>,
    /// Fresh oversize rejections (`TaskRejected`, v4) — tasks handed
    /// back because their §3.1 footprint exceeded a node's budget.
    oversize_rejections: Arc<Counter>,
    /// Services whose first oversize rejection was already logged
    /// (the reactor thread must not write one stderr line per
    /// rejected task; rejections are counted, not narrated).
    oversize_logged: Mutex<HashSet<usize>>,
    /// Peers rejected for speaking a different protocol version.
    version_rejections: Arc<Counter>,
    /// v7 multi-tenancy host resources (`None` = one-shot server:
    /// `PlanSubmit` is refused, `done` reported when the seed drains).
    tenancy: Option<TenantHostConfig>,
    /// The tenant table: plan id → lifecycle record.  Plan ids start
    /// at 1; 0 is the seed workflow.  Only the single reactor thread
    /// mutates rows; other threads read for stats.
    tenants: Mutex<HashMap<u32, Tenant>>,
    /// Next plan id.
    next_tenant: AtomicUsize,
    /// Next free partition id for renumbering an admitted plan's
    /// partitions into the shared data service (seeded past the seed
    /// workflow's partitions).
    next_partition_id: AtomicUsize,
    /// `PlanSubmit` frames received (admitted or not).
    plans_submitted: Arc<Counter>,
    /// Submissions refused (admission control, bad plan, wrong
    /// dataset, or a non-resident server).
    plans_rejected: Arc<Counter>,
    /// Tenants that reached `TENANT_DONE`.
    plans_completed: Arc<Counter>,
    /// Tenants aborted because their client connection dropped.
    plans_aborted: Arc<Counter>,
    /// Tenants failed on a per-tenant §3.1 misfit.
    plans_failed: Arc<Counter>,
    /// Data-plane replica directory, announcement order, deduplicated.
    replicas: Mutex<Vec<String>>,
    shutdown: Arc<AtomicBool>,
    /// Pokes the (possibly shared) reactor out of its kernel wait so
    /// an abort is observed immediately.
    waker: Waker,
    heartbeat_timeout: Duration,
    /// Monotonic clock behind the liveness timestamps (injectable via
    /// [`crate::obs::Clock`]; production uses the system clock).
    clock: Arc<dyn Clock>,
}

impl WfShared {
    /// Refresh the liveness timestamp of a *member*.  Returns `false`
    /// for services that are not members (never joined, failed by the
    /// monitor, or departed) — unlike the pre-PR-3 code this never
    /// resurrects a membership, so a zombie cannot silently rejoin.
    fn touch(&self, service: ServiceId) -> bool {
        match lock_poisonless(&self.members).get_mut(&service.0) {
            Some(m) => {
                m.last_seen = self.clock.now_ns();
                true
            }
            None => false,
        }
    }

    /// The §3.1 footprint attached to an assignment of `task_id`
    /// (scheduler-owned since runtime splitting: sub-task footprints
    /// are computed at split time).
    fn mem_of(&self, task_id: u32) -> u64 {
        lock_poisonless(&self.sched).mem_of(task_id)
    }

    /// The `done` flag for `NoTask` / `TaskAssignBatch` replies.  A
    /// *resident* server (v7 tenancy) never reports `done`: an empty
    /// open list just means "between plans", and nodes must stay
    /// attached for the next submission.
    fn done_flag(&self, sched: &Scheduler) -> bool {
        sched.is_done() && self.tenancy.is_none()
    }

    /// Reply to a pull (TaskRequest, Complete or TaskRejected): the
    /// next assignment with its memory footprint and — for a
    /// runtime-split sub-task — its pair-space span.
    fn next_assignment(&self, service: ServiceId) -> Message {
        let mut sched = lock_poisonless(&self.sched);
        match sched.next_task(service) {
            Some(task) => Message::TaskAssign {
                task,
                mem_bytes: sched.mem_of(task.id),
                span: sched.span_of(task.id),
            },
            None => Message::NoTask {
                done: self.done_flag(&sched),
            },
        }
    }

    /// Refresh the scheduler-derived gauges and snapshot the registry
    /// (the `StatsRequest` reply and the final report's stats).
    fn stats_snapshot(&self) -> MetricsSnapshot {
        {
            let sched = lock_poisonless(&self.sched);
            self.registry
                .gauge("queue_depth")
                .set(sched.queue_depth() as u64);
            self.registry
                .gauge("in_flight")
                .set(sched.in_flight() as u64);
            self.registry
                .gauge("tasks_completed")
                .set(sched.completed() as u64);
            self.registry.gauge("tasks_total").set(sched.total() as u64);
            self.registry
                .gauge("runtime_splits")
                .set(sched.runtime_splits());
            self.registry
                .gauge("affinity_assignments")
                .set(sched.affinity_assignments);
        }
        // v7: one gauge row per tenant, so a `pem stats` scrape shows
        // every submitted plan's state and progress.  The two tables
        // are locked *sequentially* (never nested) to keep the lock
        // order free of cycles with the reactor thread.
        let tenant_rows: Vec<(u32, u8)> = {
            let tenants = lock_poisonless(&self.tenants);
            self.registry.gauge("tenants_active").set(
                tenants
                    .values()
                    .filter(|t| t.state == TENANT_RUNNING)
                    .count() as u64,
            );
            tenants.iter().map(|(&id, t)| (id, t.state)).collect()
        };
        if !tenant_rows.is_empty() {
            let sched = lock_poisonless(&self.sched);
            for (id, state) in tenant_rows {
                let (done, total) = sched.tenant_progress(id);
                let reg = &self.registry;
                reg.gauge(&crate::obs::tenant_gauge(id, "state"))
                    .set(state as u64);
                reg.gauge(&crate::obs::tenant_gauge(id, "tasks_completed"))
                    .set(done as u64);
                reg.gauge(&crate::obs::tenant_gauge(id, "tasks_total"))
                    .set(total as u64);
            }
        }
        self.registry
            .gauge("services_joined")
            .set(self.next_service.load(Ordering::Relaxed) as u64);
        self.registry
            .gauge("live_members")
            .set(lock_poisonless(&self.members).len() as u64);
        self.registry
            .gauge("control_wire_bytes")
            .set(self.traffic.total_bytes());
        self.registry.snapshot()
    }

    /// Reply to a fenced (non-member) service: a clear error telling
    /// it to re-join.  Nodes treat workflow `Error`s as fatal.
    fn fenced(&self, service: ServiceId) -> Message {
        Message::Error {
            message: format!(
                "service {} is not a member (failed by the heartbeat \
                 monitor or never joined); re-join for a fresh id",
                service.0
            ),
        }
    }
}

/// Final statistics of a workflow run, extracted by
/// [`WorkflowServiceServer::finish`].
#[derive(Debug)]
pub struct WorkflowReport {
    /// Merged per-task match output in completion order.
    pub correspondences: Vec<Correspondence>,
    /// Tasks completed (exactly once each).
    pub completed_tasks: usize,
    /// Tasks the workflow started with.
    pub total_tasks: usize,
    /// Total pair comparisons reported by match services.
    pub comparisons: u64,
    /// Control-plane frames received.
    pub control_messages: u64,
    /// Heartbeat frames received (subset of `control_messages`).
    pub heartbeats: u64,
    /// v3 batch pulls received.
    pub batch_requests: u64,
    /// Pulls (any version) that carried no completion report — the
    /// dedicated assignment round trips.
    pub assignment_pulls: u64,
    /// Control-plane bytes sent over sockets.
    pub control_wire_bytes: u64,
    /// Assignments that hit at least one cached partition.
    pub affinity_assignments: u64,
    /// Tasks re-queued because their service failed or left.
    pub requeued_tasks: u64,
    /// Oversize rejections (v4): assignments handed back because the
    /// task's §3.1 footprint exceeded the node's budget, re-queued
    /// marked oversize instead of lost.
    pub oversize_rejections: u64,
    /// Completion reports dropped as stale (service presumed dead, or
    /// task no longer in flight at that service/generation).
    pub stale_completions: u64,
    /// Tasks the scheduler split at run time because every live node
    /// rejected them (protocol v5; sub-task results were merged back
    /// into their plan task exactly once).
    pub runtime_splits: u64,
    /// The terminal §3.1 misfit, when the run failed fast because a
    /// task was rejected by every live node and could not be split.
    pub plan_misfit: Option<PlanMisfit>,
    /// Services that ever joined.
    pub services_joined: usize,
    /// Peers rejected at join/announce for a protocol-version mismatch.
    pub version_rejections: u64,
    /// Data-plane replica directory at the end of the run.
    pub data_replicas: Vec<String>,
    /// Final metrics snapshot (the same registry a live `pem stats`
    /// scrape reads; every counter above is also in here by name).
    pub stats: MetricsSnapshot,
}

/// Why [`WorkflowServiceServer::wait_outcome`] returned.
#[derive(Clone, Debug)]
pub enum WaitStatus {
    /// Every task completed.
    Done,
    /// The typed fail-fast error: a task was rejected by every live
    /// node and cannot be split further — the run can never complete
    /// on this cluster, so the caller should tear down *now* instead
    /// of burning its timeout.
    Misfit(PlanMisfit),
    /// The timeout elapsed with tasks still outstanding.
    Timeout,
}

/// A running workflow-service endpoint.
pub struct WorkflowServiceServer {
    addr: SocketAddr,
    shared: Arc<WfShared>,
}

impl WorkflowServiceServer {
    /// Seed the central task list and start serving on `bind`
    /// (`"127.0.0.1:0"` for an ephemeral port) on a dedicated reactor
    /// thread.
    pub fn start(
        tasks: Vec<MatchTask>,
        cfg: WorkflowServerConfig,
        bind: &str,
    ) -> anyhow::Result<WorkflowServiceServer> {
        let mut reactor = Reactor::build()?;
        let srv = Self::start_on(&mut reactor, tasks, cfg, bind)?;
        reactor.spawn("pem-workflow-reactor")?;
        Ok(srv)
    }

    /// Like [`WorkflowServiceServer::start`], but registers the server
    /// on a caller-owned [`Reactor`] instead of spawning a dedicated
    /// one — the dist engine co-hosts the workflow and data services
    /// on a single reactor thread this way.  The caller spawns (or
    /// runs) the reactor afterwards; the heartbeat-monitor thread is
    /// still spawned here.
    pub fn start_on(
        reactor: &mut Reactor,
        tasks: Vec<MatchTask>,
        cfg: WorkflowServerConfig,
        bind: &str,
    ) -> anyhow::Result<WorkflowServiceServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut sched = Scheduler::new(tasks, cfg.policy);
        sched.set_task_meta(cfg.task_mem, cfg.task_sizes);
        sched.set_min_split_services(cfg.expected_services);
        if let Some(tracer) = cfg.tracer {
            sched.set_tracer(tracer);
        }
        let registry = Arc::new(Registry::new());
        registry.set_label("role", "workflow");
        registry.set_label("addr", &addr.to_string());
        let shared = Arc::new(WfShared {
            sched: Mutex::new(sched),
            results: Mutex::new(Vec::new()),
            members: Mutex::new(HashMap::new()),
            next_service: AtomicUsize::new(0),
            comparisons: registry.counter("comparisons"),
            control_messages: registry.counter("control_messages"),
            heartbeats: registry.counter("heartbeats"),
            batch_requests: registry.counter("batch_requests"),
            assignment_pulls: registry.counter("assignment_pulls"),
            traffic: TrafficStats::new(),
            requeued_tasks: registry.counter("requeued_tasks"),
            stale_completions: registry.counter("stale_completions"),
            oversize_rejections: registry.counter("oversize_rejections"),
            oversize_logged: Mutex::new(HashSet::new()),
            version_rejections: registry.counter("version_rejections"),
            tenants: Mutex::new(HashMap::new()),
            next_tenant: AtomicUsize::new(1),
            // tenant partitions are renumbered above everything the
            // seed store already holds
            next_partition_id: AtomicUsize::new(
                cfg.tenancy
                    .as_ref()
                    .and_then(|t| t.store.max_partition_id())
                    .map_or(0, |m| m as usize + 1),
            ),
            plans_submitted: registry.counter("plans_submitted"),
            plans_rejected: registry.counter("plans_rejected"),
            plans_completed: registry.counter("plans_completed"),
            plans_aborted: registry.counter("plans_aborted"),
            plans_failed: registry.counter("plans_failed"),
            tenancy: cfg.tenancy,
            replicas: Mutex::new(Vec::new()),
            shutdown: shutdown.clone(),
            waker: reactor.waker(),
            heartbeat_timeout: cfg.heartbeat_timeout,
            clock: system_clock(),
            registry: registry.clone(),
        });
        reactor.add_server(
            listener,
            Box::new(WfHandler {
                shared: shared.clone(),
            }),
            shutdown,
            &registry,
        )?;
        let monitor_shared = shared.clone();
        std::thread::Builder::new()
            .name("pem-workflow-monitor".into())
            .spawn(move || monitor_loop(monitor_shared))?;
        Ok(WorkflowServiceServer { addr, shared })
    }

    /// The bound address (for clients).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Tasks completed so far (for progress displays).
    pub fn completed(&self) -> usize {
        lock_poisonless(&self.shared.sched).completed()
    }

    /// Block until every task has completed, polling the scheduler.
    /// Returns `false` on timeout — or immediately when the scheduler
    /// declares the terminal §3.1 misfit (use [`Self::wait_outcome`]
    /// to distinguish the two).
    pub fn wait_done(&self, timeout: Duration) -> bool {
        matches!(self.wait_outcome(timeout), WaitStatus::Done)
    }

    /// Like [`Self::wait_done`] but tells the caller *why* the wait
    /// ended: completion, the typed fail-fast misfit, or the timeout.
    pub fn wait_outcome(&self, timeout: Duration) -> WaitStatus {
        let waited = Stopwatch::start();
        loop {
            {
                let sched = lock_poisonless(&self.shared.sched);
                if sched.is_done() {
                    return WaitStatus::Done;
                }
                if let Some(m) = sched.misfit() {
                    return WaitStatus::Misfit(m.clone());
                }
            }
            if waited.elapsed() >= timeout {
                return WaitStatus::Timeout;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// The terminal §3.1 misfit, once the scheduler has declared one
    /// (see [`PlanMisfit`]).
    pub fn misfit(&self) -> Option<PlanMisfit> {
        lock_poisonless(&self.shared.sched).misfit().cloned()
    }

    /// Tear the server down without consuming the handle: wakes the
    /// reactor out of its kernel wait (dropping every open connection,
    /// so match services unblock with an I/O error even when the
    /// workflow never finished — run-timeout path); the monitor stops
    /// at its next tick.  Co-hosted servers on a shared reactor are
    /// untouched.  Idempotent.
    pub fn abort(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.waker.wake();
    }

    /// Stop the reactor and monitor and extract the final report.
    /// Call after [`Self::wait_done`].
    pub fn finish(self) -> WorkflowReport {
        self.abort();
        let stats = self.shared.stats_snapshot();
        let sched = lock_poisonless(&self.shared.sched);
        WorkflowReport {
            correspondences: std::mem::take(
                &mut *lock_poisonless(&self.shared.results),
            ),
            completed_tasks: sched.completed(),
            total_tasks: sched.total(),
            comparisons: self.shared.comparisons.get(),
            control_messages: self.shared.control_messages.get(),
            heartbeats: self.shared.heartbeats.get(),
            batch_requests: self.shared.batch_requests.get(),
            assignment_pulls: self.shared.assignment_pulls.get(),
            control_wire_bytes: self.shared.traffic.total_bytes(),
            affinity_assignments: sched.affinity_assignments,
            requeued_tasks: self.shared.requeued_tasks.get(),
            oversize_rejections: self.shared.oversize_rejections.get(),
            stale_completions: self.shared.stale_completions.get(),
            runtime_splits: sched.runtime_splits(),
            // a misfit verdict that a late-joining roomy node overtook
            // (the run completed anyway) is not reported as terminal
            plan_misfit: if sched.is_done() {
                None
            } else {
                sched.misfit().cloned()
            },
            services_joined: self.shared.next_service.load(Ordering::Relaxed),
            version_rejections: self.shared.version_rejections.get(),
            data_replicas: lock_poisonless(&self.shared.replicas).clone(),
            stats,
        }
    }
}

/// Detect dead services: no message within the heartbeat timeout →
/// fail the service, re-queue its in-flight tasks (paper §4).
fn monitor_loop(shared: Arc<WfShared>) {
    let tick = (shared.heartbeat_timeout / 4).max(Duration::from_millis(5));
    while !shared.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        let now = shared.clock.now_ns();
        let timeout_ns = shared.heartbeat_timeout.as_nanos() as u64;
        let expired: Vec<(usize, String)> = {
            let mut members = lock_poisonless(&shared.members);
            let dead: Vec<usize> = members
                .iter()
                .filter(|(_, m)| {
                    now.saturating_sub(m.last_seen) > timeout_ns
                })
                .map(|(&id, _)| id)
                .collect();
            dead.into_iter()
                .map(|id| (id, members.remove(&id).expect("listed").name))
                .collect()
        };
        for (id, name) in expired {
            let reopened = lock_poisonless(&shared.sched)
                .fail_service(ServiceId(id));
            shared.requeued_tasks.add(reopened as u64);
            eprintln!(
                "workflow service: match service {id} ({name}) missed \
                 heartbeats; re-queued {reopened} in-flight task(s)"
            );
        }
    }
}

/// The reactor-driven connection handler: one instance serves every
/// control-plane connection.
struct WfHandler {
    shared: Arc<WfShared>,
}

impl FrameHandler for WfHandler {
    fn on_frame(
        &mut self,
        conn: ConnId,
        out: &mut SessionEncoder,
        payload: &[u8],
    ) -> Action {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            // aborted server: drop the connection instead of
            // answering, so clients stuck in poll loops error out
            return Action::Close;
        }
        let msg = match Message::decode(payload) {
            Ok(msg) => msg,
            Err(e) => {
                // a frame that does not decode means the peer is
                // corrupt or incompatible: answer once, hang up.  A
                // handshake frame from another protocol version (its
                // body layout may differ — e.g. a v4 Join has no
                // budget field) still carries a readable version
                // byte, so it gets the spec's clear mismatch error
                // rather than a generic decode failure.
                if let Some(peer) =
                    crate::rpc::foreign_handshake_version(payload)
                {
                    self.shared.version_rejections.inc();
                    out.queue_message(&Message::Error {
                        message: format!(
                            "protocol version mismatch: peer speaks \
                             v{peer}, this coordinator speaks \
                             v{PROTOCOL_VERSION} — upgrade the older \
                             side"
                        ),
                    });
                    return Action::Close;
                }
                out.queue_message(&Message::Error {
                    message: format!("undecodable frame: {e}"),
                });
                return Action::Close;
            }
        };
        self.shared.control_messages.inc();
        let reply = handle_message(&self.shared, conn, msg);
        let n = out.queue_message(&reply);
        self.shared.traffic.record(n);
        Action::Continue
    }

    /// v7 tenant-abort-on-disconnect: a client connection closing
    /// while one of its submitted plans is still running aborts that
    /// plan — its queued and in-flight tasks are drained so surviving
    /// tenants get the whole cluster back, and the terminal
    /// `TENANT_ABORTED` result stays in the table for observers.
    /// Locks are taken sequentially (tenants, then sched, then
    /// tenants again), never nested.
    fn on_close(&mut self, conn: ConnId) {
        if self.shared.tenancy.is_none() {
            return;
        }
        let doomed: Vec<u32> = {
            let tenants = lock_poisonless(&self.shared.tenants);
            tenants
                .iter()
                .filter(|(_, t)| {
                    t.conn == conn && t.state == TENANT_RUNNING
                })
                .map(|(&id, _)| id)
                .collect()
        };
        for id in doomed {
            let dropped =
                lock_poisonless(&self.shared.sched).drain_tenant(id);
            let mut tenants = lock_poisonless(&self.shared.tenants);
            let t = tenants.get_mut(&id).expect("tenant listed");
            t.state = TENANT_ABORTED;
            t.detail = format!(
                "client connection closed mid-run; plan aborted with \
                 {dropped} task(s) drained"
            );
            self.shared.plans_aborted.inc();
            eprintln!(
                "workflow service: tenant {id} ({}) lost its client; \
                 plan aborted, {dropped} task(s) drained",
                t.name
            );
        }
    }
}

/// Process one control-plane message and build its reply.  `conn`
/// identifies the client connection — only `PlanSubmit` uses it (the
/// tenant is bound to its submitter for abort-on-disconnect).
fn handle_message(
    shared: &WfShared,
    conn: ConnId,
    msg: Message,
) -> Message {
    match msg {
        Message::Join {
            name,
            version,
            mem_budget,
        } => {
            if version != PROTOCOL_VERSION {
                shared.version_rejections.inc();
                Message::Error {
                    message: format!(
                        "protocol version mismatch: match service \
                         {name:?} speaks v{version}, this \
                         coordinator speaks v{PROTOCOL_VERSION} — \
                         upgrade the older side"
                    ),
                }
            } else {
                let id =
                    shared.next_service.fetch_add(1, Ordering::SeqCst);
                lock_poisonless(&shared.members).insert(
                    id,
                    Member {
                        name,
                        last_seen: shared.clock.now_ns(),
                    },
                );
                {
                    // the budget reported at join (v5) sizes the
                    // sub-tasks of runtime splitting; 0 = unlimited
                    let mut sched = lock_poisonless(&shared.sched);
                    sched.add_service(ServiceId(id));
                    sched.set_service_budget(
                        ServiceId(id),
                        (mem_budget > 0).then_some(mem_budget),
                    );
                }
                Message::JoinAck {
                    service: ServiceId(id),
                    version: PROTOCOL_VERSION,
                    replicas: lock_poisonless(&shared.replicas).clone(),
                }
            }
        }
        Message::ReplicaAnnounce {
            addr,
            version,
            partitions,
        } => {
            if version != PROTOCOL_VERSION {
                shared.version_rejections.inc();
                Message::Error {
                    message: format!(
                        "protocol version mismatch: data replica \
                         {addr} speaks v{version}, this coordinator \
                         speaks v{PROTOCOL_VERSION} — upgrade the \
                         older side"
                    ),
                }
            } else {
                let (fresh, directory) = {
                    let mut dir = lock_poisonless(&shared.replicas);
                    let fresh = !dir.contains(&addr);
                    if fresh {
                        dir.push(addr);
                    }
                    (fresh, dir.clone())
                };
                // count coverage only on first announcement, so a
                // replica re-announcing (reconnect) does not inflate
                // the per-partition replica counts
                if fresh {
                    lock_poisonless(&shared.sched)
                        .add_replica_coverage(&partitions);
                    // label the snapshot with the directory so a
                    // `pem stats` scrape can discover and scrape the
                    // data servers too
                    shared
                        .registry
                        .set_label("data_replicas", &directory.join(","));
                }
                Message::ReplicaDirectory {
                    replicas: directory,
                }
            }
        }
        Message::Leave { service } => {
            lock_poisonless(&shared.members).remove(&service.0);
            let reopened = lock_poisonless(&shared.sched)
                .fail_service(service);
            shared.requeued_tasks.add(reopened as u64);
            Message::LeaveAck
        }
        Message::TaskRequest { service } => {
            if !shared.touch(service) {
                return shared.fenced(service);
            }
            shared.assignment_pulls.inc();
            shared.next_assignment(service)
        }
        Message::Complete {
            service,
            task_id,
            comparisons,
            cached,
            matches,
        } => {
            if !shared.touch(service) {
                // a straggler from a fenced service: its completion is
                // stale by definition — count and refuse
                shared.stale_completions.inc();
                return shared.fenced(service);
            }
            {
                // hold the scheduler lock across the result append:
                // `is_done()` must never be observable as true while
                // this task's output is not yet in `results`, or a
                // wait_done() → finish() sequence could drain the
                // results missing the final task's matches.  Lock
                // order is sched → results here and in finish().
                // The tenant is resolved *before* the report: a merge
                // completion removes the sub-task's split_parent link.
                let mut sched = lock_poisonless(&shared.sched);
                let tenant = sched.tenant_of_task(task_id);
                if sched.try_report_complete(service, task_id, cached) {
                    shared.comparisons.add(comparisons);
                    if tenant == 0 {
                        lock_poisonless(&shared.results).extend(matches);
                    } else if let Some(t) = lock_poisonless(&shared.tenants)
                        .get_mut(&tenant)
                    {
                        // isolated per-tenant result channel
                        t.comparisons += comparisons;
                        t.results.extend(matches);
                    }
                } else {
                    // straggler from a service presumed dead: the
                    // task was re-queued, its output arrives again
                    shared.stale_completions.inc();
                }
            }
            shared.next_assignment(service)
        }
        Message::TaskRequestBatch {
            service,
            max,
            cached,
            completed,
        } => {
            if !shared.touch(service) {
                shared.stale_completions.add(completed.len() as u64);
                return shared.fenced(service);
            }
            shared.batch_requests.inc();
            if completed.is_empty() {
                shared.assignment_pulls.inc();
            }
            let (tasks, done) = {
                // same lock-order contract as the Complete arm
                let mut sched = lock_poisonless(&shared.sched);
                report_batch(shared, &mut sched, service, cached, completed);
                let k = (max as usize).clamp(1, MAX_ASSIGN_BATCH);
                let tasks: Vec<AssignedTask> = sched
                    .next_tasks_for(service, k)
                    .into_iter()
                    .map(|task| AssignedTask {
                        mem_bytes: sched.mem_of(task.id),
                        span: sched.span_of(task.id),
                        task,
                    })
                    .collect();
                (tasks, shared.done_flag(&sched))
            };
            Message::TaskAssignBatch { done, tasks }
        }
        Message::TaskRejected { service, task_id } => {
            if !shared.touch(service) {
                return shared.fenced(service);
            }
            let fresh = lock_poisonless(&shared.sched)
                .reject_task(service, task_id);
            if fresh {
                shared.oversize_rejections.inc();
                // one diagnostic per service, not per task: this runs
                // on the reactor thread, and a node that fits nothing
                // rejects every open task
                if lock_poisonless(&shared.oversize_logged)
                    .insert(service.0)
                {
                    eprintln!(
                        "workflow service: service {} rejected task \
                         {task_id} as oversize ({} estimated); this \
                         and further oversize work is re-queued for \
                         other services (counted, not logged)",
                        service.0,
                        crate::util::fmt_bytes(shared.mem_of(task_id))
                    );
                }
            } else {
                shared.stale_completions.inc();
            }
            shared.next_assignment(service)
        }
        Message::Heartbeat {
            service,
            busy_ns,
            cache_hits,
            cache_misses,
            tasks_done,
        } => {
            shared.heartbeats.inc();
            if !shared.touch(service) {
                return shared.fenced(service);
            }
            // v6: the heartbeat carries the node's load counters —
            // recorded as per-node gauges so a live `pem stats`
            // scrape sees busy/idle time and cache behaviour without
            // touching the nodes themselves
            let id = service.0;
            let reg = &shared.registry;
            reg.gauge(&format!("node.{id}.busy_ns")).set(busy_ns);
            reg.gauge(&format!("node.{id}.cache_hits")).set(cache_hits);
            reg.gauge(&format!("node.{id}.cache_misses"))
                .set(cache_misses);
            reg.gauge(&format!("node.{id}.tasks_done")).set(tasks_done);
            Message::HeartbeatAck
        }
        Message::StatsRequest => Message::StatsReport {
            stats: shared.stats_snapshot().to_bytes(),
        },
        Message::PlanSubmit { name, plan } => {
            plan_submit(shared, conn, name, &plan)
        }
        Message::PlanStatus { plan } => plan_status(shared, plan),
        other => Message::Error {
            message: format!(
                "workflow service got unexpected {}",
                other.kind()
            ),
        },
    }
}

/// Shorthand for the submission refusals that carry no §3.1 numbers
/// (non-resident server, undecodable plan, wrong dataset).
fn plan_refused(shared: &WfShared, reason: String) -> Message {
    shared.plans_rejected.inc();
    Message::PlanRejected {
        required: 0,
        available: 0,
        reason,
    }
}

/// Handle a v7 `PlanSubmit`: decode, check provenance, run admission
/// control against the live cluster's aggregate budget, and — if
/// admitted — renumber the plan's partitions and tasks into fresh id
/// ranges, load the partitions into the shared data service, open the
/// tasks under a new tenant, and answer `PlanAccepted { plan }`.
fn plan_submit(
    shared: &WfShared,
    conn: ConnId,
    name: String,
    plan_bytes: &[u8],
) -> Message {
    shared.plans_submitted.inc();
    let Some(host) = &shared.tenancy else {
        return plan_refused(
            shared,
            "this workflow service runs a one-shot workflow and does \
             not accept submissions; start it resident \
             (`pem serve --resident`)"
                .into(),
        );
    };
    let plan = match MatchPlan::from_bytes(plan_bytes) {
        Ok(plan) => plan,
        Err(e) => {
            return plan_refused(
                shared,
                format!("undecodable plan payload: {e}"),
            );
        }
    };
    if !plan.matches_dataset(&host.dataset) {
        return plan_refused(
            shared,
            format!(
                "plan provenance mismatch: built for {} entities \
                 (fingerprint {:016x}), this cluster serves {} — \
                 re-plan against the resident dataset",
                plan.provenance.dataset_entities,
                plan.provenance.dataset_fingerprint,
                host.dataset.entities.len()
            ),
        );
    }
    // §3.1 admission control: the plan's aggregate footprint against
    // the aggregate of the live nodes' join-time budgets.  `None`
    // means some live node reported no budget (unlimited) — admit.
    let required: u64 = plan
        .task_mem
        .iter()
        .fold(0u64, |sum, &m| sum.saturating_add(m));
    let refused = {
        let sched = lock_poisonless(&shared.sched);
        match sched.cluster_budget() {
            Some(available) if required > available => Some(available),
            _ => None,
        }
    };
    if let Some(available) = refused {
        shared.plans_rejected.inc();
        let denied = AdmissionDenied {
            required,
            available,
        };
        return Message::PlanRejected {
            required,
            available,
            reason: denied.to_string(),
        };
    }
    // admit: partition ids are offset above everything the shared
    // store holds, task ids above everything the scheduler ever
    // issued — tenants can collide with neither the seed workflow
    // nor each other
    let part_span = plan
        .partitions
        .iter()
        .map(|p| p.id.0)
        .max()
        .map_or(0, |m| m + 1);
    let part_off = shared
        .next_partition_id
        .fetch_add(part_span as usize, Ordering::SeqCst)
        as u32;
    // a spill-backed store can fail here (disk full, I/O error) —
    // refuse the plan instead of serving partitions that don't exist
    if let Err(e) =
        host.store.extend(&host.dataset, &plan.partitions, part_off)
    {
        return plan_refused(
            shared,
            format!("storing plan partitions failed: {e}"),
        );
    }
    let tenant =
        shared.next_tenant.fetch_add(1, Ordering::SeqCst) as u32;
    let sizes_by_plan_id = plan.task_sizes();
    {
        let mut sched = lock_poisonless(&shared.sched);
        let task_span = plan
            .tasks
            .iter()
            .map(|t| t.id)
            .max()
            .map_or(0, |m| m + 1);
        let task_off = sched.reserve_task_ids(task_span);
        let mut tasks = Vec::with_capacity(plan.tasks.len());
        let mut mem = HashMap::with_capacity(plan.tasks.len());
        let mut sizes = HashMap::with_capacity(plan.tasks.len());
        for (t, &m) in plan.tasks.iter().zip(plan.task_mem.iter()) {
            let id = t.id + task_off;
            tasks.push(MatchTask {
                id,
                left: PartitionId(t.left.0 + part_off),
                right: PartitionId(t.right.0 + part_off),
            });
            mem.insert(id, m);
            if let Some(&s) = sizes_by_plan_id.get(&t.id) {
                sizes.insert(id, s);
            }
        }
        sched.add_tenant_tasks(
            tenant,
            tasks,
            mem,
            sizes,
            host.per_tenant_inflight,
        );
    }
    lock_poisonless(&shared.tenants).insert(
        tenant,
        Tenant {
            name,
            conn,
            state: TENANT_RUNNING,
            results: Vec::new(),
            comparisons: 0,
            detail: String::new(),
        },
    );
    Message::PlanAccepted { plan: tenant }
}

/// Handle a v7 `PlanStatus` poll: settle any pending lifecycle
/// transition (per-tenant misfit → failed, all tasks completed →
/// done), then answer `PlanStatusReport` while running or the
/// idempotent terminal `PlanResult`.
fn plan_status(shared: &WfShared, plan: u32) -> Message {
    let mut tenants = lock_poisonless(&shared.tenants);
    let Some(t) = tenants.get_mut(&plan) else {
        return Message::Error {
            message: format!("unknown plan id {plan}"),
        };
    };
    let mut progress = (0usize, 0usize);
    if t.state == TENANT_RUNNING {
        // the scheduler is the source of truth for the transition;
        // the tenant row is updated on this poll (reactor thread)
        let (prog, misfit) = {
            let sched = lock_poisonless(&shared.sched);
            (
                sched.tenant_progress(plan),
                sched.tenant_misfit(plan).cloned(),
            )
        };
        progress = prog;
        if let Some(misfit) = misfit {
            t.state = TENANT_FAILED;
            t.detail = format!(
                "plan misfit: task {} needs {} but the smallest live \
                 budget is {} and the task cannot be split further",
                misfit.task_id,
                crate::util::fmt_bytes(misfit.mem_bytes),
                crate::util::fmt_bytes(misfit.smallest_budget)
            );
            shared.plans_failed.inc();
        } else if progress.0 >= progress.1 {
            t.state = TENANT_DONE;
            shared.plans_completed.inc();
        }
    }
    if t.state == TENANT_RUNNING {
        Message::PlanStatusReport {
            plan,
            state: TENANT_RUNNING,
            completed: progress.0 as u32,
            total: progress.1 as u32,
            detail: String::new(),
        }
    } else {
        // terminal: idempotent — every poll gets the same result
        Message::PlanResult {
            plan,
            state: t.state,
            comparisons: t.comparisons,
            matches: t.results.clone(),
            detail: t.detail.clone(),
        }
    }
}

/// Fold a batch of completion reports into the scheduler and the
/// merged results (caller holds the scheduler lock).  The batch's
/// cache status is recorded once at the end rather than per task, and
/// the fresh tasks' matches are appended under a single results-lock
/// acquisition — this runs on the one reactor thread, so the
/// control-plane hot path stays lean.
fn report_batch(
    shared: &WfShared,
    sched: &mut Scheduler,
    service: ServiceId,
    cached: Vec<crate::partition::PartitionId>,
    completed: Vec<CompletedTask>,
) {
    let mut comparisons = 0u64;
    let mut fresh_matches: Vec<Correspondence> = Vec::new();
    // fresh results of *submitted* plans, keyed by tenant id — routed
    // to that tenant's isolated channel, never the seed results
    let mut tenant_fresh: HashMap<u32, (u64, Vec<Correspondence>)> =
        HashMap::new();
    for report in completed {
        // resolve the tenant BEFORE completion: merging a split
        // sub-task drops its parent link
        let tenant = sched.tenant_of_task(report.task_id);
        if sched.try_complete_batched(service, report.task_id) {
            if tenant == 0 {
                comparisons += report.comparisons;
                fresh_matches.extend(report.matches);
            } else {
                let slot = tenant_fresh.entry(tenant).or_default();
                slot.0 += report.comparisons;
                slot.1.extend(report.matches);
            }
        } else {
            shared.stale_completions.inc();
        }
    }
    sched.record_cache_status(service, cached);
    if !fresh_matches.is_empty() {
        lock_poisonless(&shared.results).extend(fresh_matches);
    }
    if comparisons > 0 {
        shared.comparisons.add(comparisons);
    }
    if !tenant_fresh.is_empty() {
        // reactor thread: the sched → tenants nesting matches the
        // single-task Complete arm (see the lock-order note there)
        let mut tenants = lock_poisonless(&shared.tenants);
        for (tenant, (comp, matches)) in tenant_fresh {
            shared.comparisons.add(comp);
            if let Some(t) = tenants.get_mut(&tenant) {
                t.comparisons += comp;
                t.results.extend(matches);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::PartitionId;
    use crate::rpc::Transport;
    use std::time::Instant;

    fn task(id: u32, l: u32, r: u32) -> MatchTask {
        MatchTask {
            id,
            left: PartitionId(l),
            right: PartitionId(r),
        }
    }

    fn client(addr: SocketAddr) -> Transport {
        Transport::connect(addr, Duration::from_secs(5)).unwrap()
    }

    fn join(t: &mut Transport, name: &str) -> ServiceId {
        join_with_budget(t, name, 0)
    }

    fn join_with_budget(
        t: &mut Transport,
        name: &str,
        mem_budget: u64,
    ) -> ServiceId {
        match t
            .request(&Message::Join {
                name: name.into(),
                version: PROTOCOL_VERSION,
                mem_budget,
            })
            .unwrap()
        {
            Message::JoinAck { service, .. } => service,
            other => panic!("expected JoinAck, got {}", other.kind()),
        }
    }

    #[test]
    fn full_pull_protocol_round() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 1), task(1, 2, 3)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let svc = join(&mut c, "test-node");

        // initial pull
        let Message::TaskAssign { task: t0, .. } =
            c.request(&Message::TaskRequest { service: svc }).unwrap()
        else {
            panic!("expected assignment");
        };
        // completion piggybacks the next pull
        let reply = c
            .request(&Message::Complete {
                service: svc,
                task_id: t0.id,
                comparisons: 10,
                cached: vec![t0.left, t0.right],
                matches: vec![Correspondence {
                    e1: crate::model::EntityId(1),
                    e2: crate::model::EntityId(2),
                    sim: 0.9,
                }],
            })
            .unwrap();
        let Message::TaskAssign { task: t1, .. } = reply else {
            panic!("expected second assignment, got {}", reply.kind());
        };
        assert_ne!(t0.id, t1.id);
        let reply = c
            .request(&Message::Complete {
                service: svc,
                task_id: t1.id,
                comparisons: 5,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(matches!(reply, Message::NoTask { done: true }));

        assert!(srv.wait_done(Duration::from_secs(1)));
        let _ = c.request(&Message::Leave { service: svc });
        let report = srv.finish();
        assert_eq!(report.completed_tasks, 2);
        assert_eq!(report.total_tasks, 2);
        assert_eq!(report.comparisons, 15);
        assert_eq!(report.correspondences.len(), 1);
        assert!(report.control_messages >= 4);
        assert!(report.control_wire_bytes > 0);
        assert_eq!(report.services_joined, 1);
        // exactly one pull carried no completion (the initial one)
        assert_eq!(report.assignment_pulls, 1);
        assert_eq!(report.batch_requests, 0);
    }

    /// The v3 batched pull: one round trip reports a whole batch of
    /// completions and receives the next batch of assignments.
    #[test]
    fn batched_pull_protocol_round() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 1), task(1, 2, 3), task(2, 4, 5)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let svc = join(&mut c, "batch-node");

        // initial batch pull: nothing to report yet
        let reply = c
            .request(&Message::TaskRequestBatch {
                service: svc,
                max: 2,
                cached: vec![],
                completed: vec![],
            })
            .unwrap();
        let Message::TaskAssignBatch { done, tasks } = reply else {
            panic!("expected batch assignment");
        };
        assert!(!done);
        assert_eq!(tasks.len(), 2, "asked for 2, open list has 3");

        // both completions + the next pull ride one frame
        let reply = c
            .request(&Message::TaskRequestBatch {
                service: svc,
                max: 2,
                cached: vec![tasks[0].task.left],
                completed: tasks
                    .iter()
                    .map(|a| CompletedTask {
                        task_id: a.task.id,
                        comparisons: 7,
                        matches: vec![],
                    })
                    .collect(),
            })
            .unwrap();
        let Message::TaskAssignBatch { done, tasks } = reply else {
            panic!("expected second batch");
        };
        assert!(!done);
        assert_eq!(tasks.len(), 1, "one task left");

        // final completion: empty assignment, workflow done
        let reply = c
            .request(&Message::TaskRequestBatch {
                service: svc,
                max: 2,
                cached: vec![],
                completed: vec![CompletedTask {
                    task_id: tasks[0].task.id,
                    comparisons: 7,
                    matches: vec![],
                }],
            })
            .unwrap();
        let Message::TaskAssignBatch { done, tasks } = reply else {
            panic!("expected final batch reply");
        };
        assert!(done);
        assert!(tasks.is_empty());

        assert!(srv.wait_done(Duration::from_secs(1)));
        let report = srv.finish();
        assert_eq!(report.completed_tasks, 3);
        assert_eq!(report.comparisons, 21);
        assert_eq!(report.batch_requests, 3);
        // only the initial pull carried no completions
        assert_eq!(report.assignment_pulls, 1);
        assert_eq!(report.stale_completions, 0);
    }

    /// The ROADMAP bugfix: frames used to carry no protocol version, so
    /// a mismatched peer would fail with a confusing decode error deep
    /// into a run.  Now a `Join` or `ReplicaAnnounce` from the wrong
    /// version is rejected up front with a clear message, and the peer
    /// is never admitted.
    #[test]
    fn version_mismatch_rejected_with_clear_error() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 0)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let reply = c
            .request(&Message::Join {
                name: "time-traveler".into(),
                version: PROTOCOL_VERSION + 1,
                mem_budget: 0,
            })
            .unwrap();
        let Message::Error { message } = reply else {
            panic!("v{} join must be rejected", PROTOCOL_VERSION + 1);
        };
        assert!(
            message.contains("version mismatch"),
            "unclear rejection: {message}"
        );
        assert!(message.contains(&format!("v{}", PROTOCOL_VERSION + 1)));
        assert!(message.contains(&format!("v{PROTOCOL_VERSION}")));

        let reply = c
            .request(&Message::ReplicaAnnounce {
                addr: "10.0.0.9:7402".into(),
                version: 0,
                partitions: vec![PartitionId(0)],
            })
            .unwrap();
        assert!(matches!(reply, Message::Error { .. }));

        // a correct-version peer still joins, and no service id was
        // burned on the rejected one
        let svc = join(&mut c, "contemporary");
        assert_eq!(svc, ServiceId(0));
        let report = srv.finish();
        assert_eq!(report.version_rejections, 2);
        assert_eq!(report.services_joined, 1);
        assert!(report.data_replicas.is_empty());
    }

    /// A *v4-era* `Join` — whose body layout predates the v5 budget
    /// field and therefore no longer decodes — still gets the spec's
    /// clear version-mismatch error, not a generic "undecodable
    /// frame": the version byte right after the tag is salvaged.
    #[test]
    fn legacy_join_layout_gets_version_mismatch_not_decode_error() {
        use std::io::Write;
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 0)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        // hand-craft the v4 frame: tag, version byte, name — no budget
        let mut payload = vec![1u8, PROTOCOL_VERSION - 1];
        crate::rpc::put_str(&mut payload, "museum-piece");
        assert!(
            Message::decode(&payload).is_err(),
            "premise: the legacy layout must no longer decode"
        );
        let mut stream =
            std::net::TcpStream::connect(srv.addr()).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut wire = (payload.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&payload);
        stream.write_all(&wire).unwrap();
        let reply = crate::rpc::read_frame(&mut stream).unwrap();
        let Message::Error { message } = reply else {
            panic!("expected Error, got {}", reply.kind());
        };
        assert!(
            message.contains("version mismatch"),
            "unclear rejection: {message}"
        );
        assert!(message.contains(&format!("v{}", PROTOCOL_VERSION - 1)));
        let report = srv.finish();
        assert_eq!(report.version_rejections, 1);
        assert_eq!(report.services_joined, 0);
    }

    /// Announced replicas accumulate in the directory and are handed to
    /// every subsequently joining match service; re-announcement is
    /// idempotent.
    #[test]
    fn replica_directory_grows_and_reaches_joiners() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 0)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let announce = |c: &mut Transport, addr: &str| {
            match c
                .request(&Message::ReplicaAnnounce {
                    addr: addr.into(),
                    version: PROTOCOL_VERSION,
                    partitions: vec![PartitionId(0), PartitionId(1)],
                })
                .unwrap()
            {
                Message::ReplicaDirectory { replicas } => replicas,
                other => panic!("expected directory, got {}", other.kind()),
            }
        };
        assert_eq!(announce(&mut c, "10.0.0.1:7402"), vec!["10.0.0.1:7402"]);
        let dir = announce(&mut c, "10.0.0.2:7402");
        assert_eq!(dir, vec!["10.0.0.1:7402", "10.0.0.2:7402"]);
        // idempotent re-announce (e.g. after a replica reconnects)
        assert_eq!(announce(&mut c, "10.0.0.1:7402"), dir);

        let reply = c
            .request(&Message::Join {
                name: "late-joiner".into(),
                version: PROTOCOL_VERSION,
                mem_budget: 0,
            })
            .unwrap();
        let Message::JoinAck { replicas, .. } = reply else {
            panic!("expected JoinAck, got {}", reply.kind());
        };
        assert_eq!(replicas, dir, "directory delivered at join");
        let report = srv.finish();
        assert_eq!(report.data_replicas, dir);
        assert_eq!(report.version_rejections, 0);
    }

    /// §3.1 memory-model parity over the wire: footprints travel on
    /// assignments, a `TaskRejected` re-queues the task marked
    /// oversize (never re-offered to the rejector), and another node
    /// completes it — nothing is lost.
    #[test]
    fn oversize_rejection_is_requeued_not_lost() {
        let mut task_mem = HashMap::new();
        task_mem.insert(0u32, 1_000_000u64);
        task_mem.insert(1u32, 10u64);
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 1), task(1, 2, 3)],
            WorkflowServerConfig {
                policy: Policy::Fifo,
                task_mem,
                ..WorkflowServerConfig::default()
            },
            "127.0.0.1:0",
        )
        .unwrap();
        let mut a = client(srv.addr());
        let svc_a = join(&mut a, "small-node");
        let Message::TaskAssign {
            task: t, mem_bytes, ..
        } = a
            .request(&Message::TaskRequest { service: svc_a })
            .unwrap()
        else {
            panic!("expected assignment");
        };
        assert_eq!(t.id, 0);
        assert_eq!(mem_bytes, 1_000_000, "footprint attached");
        // node rejects; the reply is the next (fitting) assignment
        let reply = a
            .request(&Message::TaskRejected {
                service: svc_a,
                task_id: t.id,
            })
            .unwrap();
        let Message::TaskAssign {
            task: t1,
            mem_bytes,
            ..
        } = reply
        else {
            panic!("expected follow-up assignment");
        };
        assert_eq!(t1.id, 1);
        assert_eq!(mem_bytes, 10);
        // after completing the small task, the oversize one is NOT
        // re-offered to its rejector
        let reply = a
            .request(&Message::Complete {
                service: svc_a,
                task_id: t1.id,
                comparisons: 1,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(
            matches!(reply, Message::NoTask { done: false }),
            "rejector must not see the oversize task again"
        );
        // a second node receives the re-queued task and completes it
        let mut b = client(srv.addr());
        let svc_b = join(&mut b, "big-node");
        let Message::TaskAssign {
            task: re, mem_bytes, ..
        } = b
            .request(&Message::TaskRequest { service: svc_b })
            .unwrap()
        else {
            panic!("re-queued oversize task not offered");
        };
        assert_eq!(re.id, 0);
        assert_eq!(mem_bytes, 1_000_000);
        let done = b
            .request(&Message::Complete {
                service: svc_b,
                task_id: re.id,
                comparisons: 1,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(matches!(done, Message::NoTask { done: true }));
        assert!(srv.wait_done(Duration::from_secs(1)));
        let report = srv.finish();
        assert_eq!(report.completed_tasks, 2);
        assert_eq!(report.oversize_rejections, 1);
        assert_eq!(report.requeued_tasks, 0, "rejection is not a failure");
        assert_eq!(report.stale_completions, 0);
    }

    /// The tentpole over the wire: a task every joined node has
    /// rejected comes back *reshaped* — split into spanned sub-tasks
    /// sized to the smallest reported budget — and completing all
    /// sub-tasks counts the plan task as completed exactly once.
    #[test]
    fn all_nodes_rejecting_splits_task_into_spanned_subtasks() {
        // one intra task over a 20-entity partition at 20 B per pair
        let srv = WorkflowServiceServer::start(
            vec![task(0, 5, 5)],
            WorkflowServerConfig {
                policy: Policy::Fifo,
                task_mem: [(0u32, 20u64 * 20 * 20)]
                    .into_iter()
                    .collect(),
                task_sizes: [(0u32, (20u32, 20u32))]
                    .into_iter()
                    .collect(),
                ..WorkflowServerConfig::default()
            },
            "127.0.0.1:0",
        )
        .unwrap();
        let budget = 20u64 * 10 * 10; // half the entities fit
        let mut a = client(srv.addr());
        let svc_a = join_with_budget(&mut a, "small-a", budget);
        let mut b = client(srv.addr());
        let svc_b = join_with_budget(&mut b, "small-b", budget);

        // both nodes reject the plan task
        let Message::TaskAssign { task: t, span, .. } = a
            .request(&Message::TaskRequest { service: svc_a })
            .unwrap()
        else {
            panic!("expected assignment");
        };
        assert_eq!(t.id, 0);
        assert_eq!(span, None, "plan tasks carry no span");
        let reply = a
            .request(&Message::TaskRejected {
                service: svc_a,
                task_id: t.id,
            })
            .unwrap();
        assert!(
            matches!(reply, Message::NoTask { done: false }),
            "sole rejector sees nothing until another node weighs in"
        );
        let Message::TaskAssign { task: t, .. } = b
            .request(&Message::TaskRequest { service: svc_b })
            .unwrap()
        else {
            panic!("expected assignment at node b");
        };
        assert_eq!(t.id, 0);
        // b's rejection completes the all-rejected condition; the
        // reply already carries the first sub-task
        let reply = b
            .request(&Message::TaskRejected {
                service: svc_b,
                task_id: t.id,
            })
            .unwrap();
        let Message::TaskAssign {
            task: first,
            mem_bytes,
            span,
        } = reply
        else {
            panic!("expected a sub-task, got {}", reply.kind());
        };
        assert!(first.id >= 1, "sub-task ids sit above the plan's");
        assert!(mem_bytes <= budget, "sub-task fits the budget");
        let mut spans = vec![span.expect("sub-tasks carry spans")];
        let complete = |t: &mut Transport,
                        svc: ServiceId,
                        task_id: u32|
         -> Message {
            t.request(&Message::Complete {
                service: svc,
                task_id,
                comparisons: 1,
                cached: vec![],
                matches: vec![],
            })
            .unwrap()
        };
        // 2 chunks of 10 → 2 triangles + 1 rectangle; both nodes share
        // the drain
        let mut outstanding = first.id;
        loop {
            match complete(&mut b, svc_b, outstanding) {
                Message::TaskAssign {
                    task,
                    mem_bytes,
                    span,
                } => {
                    assert!(mem_bytes <= budget);
                    spans.push(span.expect("sub-tasks carry spans"));
                    outstanding = task.id;
                }
                Message::NoTask { done } => {
                    assert!(done, "all sub-tasks drained");
                    break;
                }
                other => panic!("unexpected {}", other.kind()),
            }
        }
        assert_eq!(spans.len(), 3, "2 triangles + 1 rectangle");
        assert!(spans.contains(&crate::partition::TaskSpan {
            left: (0, 10),
            right: (0, 10),
        }));
        assert!(spans.contains(&crate::partition::TaskSpan {
            left: (10, 20),
            right: (10, 20),
        }));
        assert!(spans.contains(&crate::partition::TaskSpan {
            left: (0, 10),
            right: (10, 20),
        }));
        assert!(srv.wait_done(Duration::from_secs(1)));
        let report = srv.finish();
        assert_eq!(report.completed_tasks, 1, "plan task merged once");
        assert_eq!(report.total_tasks, 1);
        assert_eq!(report.runtime_splits, 1);
        assert_eq!(report.oversize_rejections, 2);
        assert!(report.plan_misfit.is_none());
    }

    /// The fail-fast satellite: when every node has rejected a task
    /// that cannot be split (no metadata at all here), the server
    /// reports the typed misfit immediately — `wait_outcome` returns
    /// within milliseconds, not at the run timeout.
    #[test]
    fn unsplittable_rejection_fails_fast_with_typed_misfit() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 1)],
            WorkflowServerConfig {
                policy: Policy::Fifo,
                task_mem: [(0u32, 1_000_000u64)].into_iter().collect(),
                ..WorkflowServerConfig::default()
            },
            "127.0.0.1:0",
        )
        .unwrap();
        let mut a = client(srv.addr());
        let svc_a = join_with_budget(&mut a, "tiny-a", 10);
        let mut b = client(srv.addr());
        let svc_b = join_with_budget(&mut b, "tiny-b", 10);
        for (t, svc) in [(&mut a, svc_a), (&mut b, svc_b)] {
            let Message::TaskAssign { task, .. } = t
                .request(&Message::TaskRequest { service: svc })
                .unwrap()
            else {
                panic!("expected assignment");
            };
            let _ = t
                .request(&Message::TaskRejected {
                    service: svc,
                    task_id: task.id,
                })
                .unwrap();
        }
        let started = Instant::now();
        let status = srv.wait_outcome(Duration::from_secs(30));
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "misfit must not burn the timeout"
        );
        let WaitStatus::Misfit(misfit) = status else {
            panic!("expected the typed misfit, got {status:?}");
        };
        assert_eq!(misfit.task_id, 0);
        assert_eq!(misfit.mem_bytes, 1_000_000);
        assert_eq!(misfit.smallest_budget, 10);
        assert!(srv.misfit().is_some());
        // a node polling after the verdict is not crashed out — the
        // engine tears the run down, the protocol stays well-formed
        let reply = a
            .request(&Message::TaskRequest { service: svc_a })
            .unwrap();
        assert!(matches!(reply, Message::NoTask { done: false }));
        let report = srv.finish();
        assert_eq!(report.completed_tasks, 0);
        assert!(report.plan_misfit.is_some());
        assert_eq!(report.runtime_splits, 0);
    }

    /// A service that misses heartbeats is failed and fenced: its
    /// in-flight task is re-queued for others, and everything it sends
    /// afterwards — completions included — is refused with an `Error`
    /// telling it to re-join (the PR-3 zombie fix; it used to be
    /// silently resurrected).
    #[test]
    fn missed_heartbeats_requeue_in_flight_tasks() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 0)],
            WorkflowServerConfig {
                policy: Policy::Fifo,
                heartbeat_timeout: Duration::from_millis(80),
                ..WorkflowServerConfig::default()
            },
            "127.0.0.1:0",
        )
        .unwrap();
        // node A joins, takes the task, then goes silent
        let mut a = client(srv.addr());
        let svc_a = join(&mut a, "doomed");
        let Message::TaskAssign { task: t, .. } = a
            .request(&Message::TaskRequest { service: svc_a })
            .unwrap()
        else {
            panic!("expected assignment");
        };
        std::thread::sleep(Duration::from_millis(300));

        // node B joins and receives the re-queued task
        let mut b = client(srv.addr());
        let svc_b = join(&mut b, "survivor");
        let Message::TaskAssign { task: re, .. } = b
            .request(&Message::TaskRequest { service: svc_b })
            .unwrap()
        else {
            panic!("re-queued task not offered");
        };
        assert_eq!(re.id, t.id);

        // the doomed node's stale completion is fenced with an error…
        let stale = a
            .request(&Message::Complete {
                service: svc_a,
                task_id: t.id,
                comparisons: 1,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        let Message::Error { message } = stale else {
            panic!("zombie completion must be fenced, got {}", stale.kind());
        };
        assert!(message.contains("re-join"), "unclear fence: {message}");
        // …and does not mark the workflow done
        assert!(!srv.wait_done(Duration::from_millis(50)));

        // the survivor's completion does
        let done = b
            .request(&Message::Complete {
                service: svc_b,
                task_id: re.id,
                comparisons: 1,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(matches!(done, Message::NoTask { done: true }));
        assert!(srv.wait_done(Duration::from_secs(1)));
        let report = srv.finish();
        assert_eq!(report.completed_tasks, 1);
        assert_eq!(report.requeued_tasks, 1);
        assert_eq!(report.stale_completions, 1);
    }

    /// Protocol v6: a `StatsRequest` from a separate operator
    /// connection scrapes the live registry mid-run — queue depth,
    /// counters, and the per-node gauges fed by enriched heartbeats.
    #[test]
    fn stats_scrape_reports_live_counters() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 1), task(1, 2, 3)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let svc = join(&mut c, "scraped-node");
        // take one task, leave the other queued
        let Message::TaskAssign { task: t0, .. } =
            c.request(&Message::TaskRequest { service: svc }).unwrap()
        else {
            panic!("expected assignment");
        };
        // an enriched v6 heartbeat feeds the per-node gauges
        let hb = c
            .request(&Message::Heartbeat {
                service: svc,
                busy_ns: 1_000,
                cache_hits: 3,
                cache_misses: 1,
                tasks_done: 0,
            })
            .unwrap();
        assert!(matches!(hb, Message::HeartbeatAck));
        // scrape from a second connection while the run is live
        let mut op = client(srv.addr());
        let reply = op.request(&Message::StatsRequest).unwrap();
        let Message::StatsReport { stats } = reply else {
            panic!("expected StatsReport, got {}", reply.kind());
        };
        let snap = MetricsSnapshot::from_bytes(&stats).unwrap();
        assert_eq!(snap.label("role"), Some("workflow"));
        assert_eq!(snap.gauge("tasks_total"), Some(2));
        assert_eq!(snap.gauge("tasks_completed"), Some(0));
        assert_eq!(snap.gauge("in_flight"), Some(1));
        assert_eq!(snap.gauge("queue_depth"), Some(1));
        assert_eq!(snap.gauge("services_joined"), Some(1));
        assert_eq!(snap.gauge("node.0.busy_ns"), Some(1_000));
        assert_eq!(snap.gauge("node.0.cache_hits"), Some(3));
        assert_eq!(snap.gauge("node.0.cache_misses"), Some(1));
        assert_eq!(snap.counter("heartbeats"), Some(1));
        // drain the run; the final report carries the same registry
        let Message::TaskAssign { task: t1, .. } = c
            .request(&Message::Complete {
                service: svc,
                task_id: t0.id,
                comparisons: 2,
                cached: vec![],
                matches: vec![],
            })
            .unwrap()
        else {
            panic!("expected second assignment");
        };
        let done = c
            .request(&Message::Complete {
                service: svc,
                task_id: t1.id,
                comparisons: 3,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(matches!(done, Message::NoTask { done: true }));
        assert!(srv.wait_done(Duration::from_secs(1)));
        let report = srv.finish();
        assert_eq!(report.stats.counter("comparisons"), Some(5));
        assert_eq!(
            report.stats.gauge("tasks_completed"),
            Some(report.completed_tasks as u64)
        );
    }

    /// A tracer handed in via the config captures a full wire-protocol
    /// run, and the exactly-once verifier certifies it.
    #[test]
    fn configured_tracer_captures_wire_run() {
        let tracer = Tracer::new(1 << 12);
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 1), task(1, 2, 3)],
            WorkflowServerConfig {
                tracer: Some(tracer.clone()),
                ..WorkflowServerConfig::default()
            },
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let svc = join(&mut c, "traced-node");
        let mut next = match c
            .request(&Message::TaskRequest { service: svc })
            .unwrap()
        {
            Message::TaskAssign { task, .. } => task.id,
            other => panic!("expected assignment, got {}", other.kind()),
        };
        loop {
            match c
                .request(&Message::Complete {
                    service: svc,
                    task_id: next,
                    comparisons: 1,
                    cached: vec![],
                    matches: vec![],
                })
                .unwrap()
            {
                Message::TaskAssign { task, .. } => next = task.id,
                Message::NoTask { done } => {
                    assert!(done);
                    break;
                }
                other => panic!("unexpected {}", other.kind()),
            }
        }
        assert!(srv.wait_done(Duration::from_secs(1)));
        srv.finish();
        let summary = tracer.verify_plan(&[0, 1]).expect("trace verifies");
        assert_eq!(summary.plan_tasks, 2);
        assert_eq!(summary.assignments, 2);
        assert_eq!(summary.splits, 0);
    }

    // ---- protocol v7: resident multi-tenant service -------------

    /// A small resident host: dataset, primary store seeded from a
    /// size-based partitioning, and an empty-seed workflow server
    /// that accepts submissions.
    fn resident_host(
        entities: usize,
        seed: u64,
    ) -> (Arc<Dataset>, Arc<DataService>, WorkflowServiceServer) {
        let data = crate::datagen::GeneratorConfig::tiny()
            .with_entities(entities)
            .with_seed(seed)
            .generate();
        let dataset = Arc::new(data.dataset);
        let ids: Vec<crate::model::EntityId> =
            dataset.entities.iter().map(|e| e.id).collect();
        let parts = crate::partition::partition_size_based(&ids, 25);
        let store = Arc::new(DataService::build(&dataset, &parts));
        let srv = WorkflowServiceServer::start(
            Vec::new(),
            WorkflowServerConfig {
                policy: Policy::Fifo,
                tenancy: Some(TenantHostConfig {
                    dataset: dataset.clone(),
                    store: store.clone(),
                    per_tenant_inflight: None,
                }),
                ..WorkflowServerConfig::default()
            },
            "127.0.0.1:0",
        )
        .unwrap();
        (dataset, store, srv)
    }

    /// Build a serialized plan for `dataset` with a fixed partition
    /// size (deterministic §3.1 footprints).
    fn plan_bytes_for(dataset: &Dataset, max_size: usize) -> Vec<u8> {
        let plan = MatchPlan::build(
            dataset,
            &crate::partition::SizeBased {
                max_size: Some(max_size),
            },
            crate::matching::StrategyKind::Wam,
            &crate::cluster::ComputingEnv::new(1, 1, crate::util::GIB),
        )
        .unwrap();
        assert!(plan.n_tasks() > 0, "test premise: plan has work");
        plan.to_bytes()
    }

    /// A one-shot server (no tenancy) refuses submissions with a
    /// clear pointer at resident mode — never a decode error.
    #[test]
    fn one_shot_server_refuses_plan_submission() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 0)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut c = client(srv.addr());
        let reply = c
            .request(&Message::PlanSubmit {
                name: "hopeful".into(),
                plan: vec![1, 2, 3],
            })
            .unwrap();
        let Message::PlanRejected { reason, .. } = reply else {
            panic!("expected PlanRejected, got {}", reply.kind());
        };
        assert!(reason.contains("resident"), "unclear refusal: {reason}");
        let report = srv.finish();
        assert_eq!(report.stats.counter("plans_submitted"), Some(1));
        assert_eq!(report.stats.counter("plans_rejected"), Some(1));
    }

    /// The admission-control satellite: a plan whose aggregate §3.1
    /// footprint exceeds the cluster's join-time budgets is refused
    /// *immediately* with the typed numbers; the same plan is
    /// admitted after a roomier node joins, runs to completion, and
    /// its terminal `PlanResult` is idempotent.
    #[test]
    fn admission_denied_then_admitted_after_roomy_join() {
        let (dataset, _store, srv) = resident_host(60, 9);
        let bytes = plan_bytes_for(&dataset, 20);
        let plan = MatchPlan::from_bytes(&bytes).unwrap();
        let required: u64 = plan.task_mem.iter().sum();
        assert!(required > 1);

        // one live node with a 1-byte budget: nothing fits
        let mut a = client(srv.addr());
        let _svc_a = join_with_budget(&mut a, "cramped", 1);
        let mut sub = client(srv.addr());
        let started = Instant::now();
        let reply = sub
            .request(&Message::PlanSubmit {
                name: "big-plan".into(),
                plan: bytes.clone(),
            })
            .unwrap();
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "admission must answer in seconds, not the run timeout"
        );
        let Message::PlanRejected {
            required: r,
            available,
            reason,
        } = reply
        else {
            panic!("expected PlanRejected, got {}", reply.kind());
        };
        assert_eq!(r, required, "the typed numbers travel");
        assert_eq!(available, 1);
        assert!(reason.contains("admission denied"), "{reason}");

        // a node with an unlimited budget joins → re-submission wins
        let mut b = client(srv.addr());
        let svc_b = join(&mut b, "roomy");
        let reply = sub
            .request(&Message::PlanSubmit {
                name: "big-plan".into(),
                plan: bytes,
            })
            .unwrap();
        let Message::PlanAccepted { plan: plan_id } = reply else {
            panic!("expected PlanAccepted, got {}", reply.kind());
        };
        assert_eq!(plan_id, 1, "plan ids start at 1");

        // the roomy node drains the tenant's tasks; the resident
        // server never reports done (nodes stay attached)
        let mut completed = 0u32;
        let mut reply = b
            .request(&Message::TaskRequest { service: svc_b })
            .unwrap();
        loop {
            match reply {
                Message::TaskAssign { task, .. } => {
                    reply = b
                        .request(&Message::Complete {
                            service: svc_b,
                            task_id: task.id,
                            comparisons: 2,
                            cached: vec![],
                            matches: vec![Correspondence {
                                e1: crate::model::EntityId(completed),
                                e2: crate::model::EntityId(completed + 1),
                                sim: 0.8,
                            }],
                        })
                        .unwrap();
                    completed += 1;
                }
                Message::NoTask { done } => {
                    assert!(!done, "a resident server never says done");
                    break;
                }
                other => panic!("unexpected {}", other.kind()),
            }
        }
        assert_eq!(completed as usize, plan.n_tasks());

        // the status poll settles the lifecycle and returns the
        // tenant's isolated result — twice, identically
        let result = |sub: &mut Transport| {
            match sub
                .request(&Message::PlanStatus { plan: plan_id })
                .unwrap()
            {
                Message::PlanResult {
                    state,
                    comparisons,
                    matches,
                    ..
                } => (state, comparisons, matches.len()),
                other => panic!("expected PlanResult, got {}", other.kind()),
            }
        };
        let first = result(&mut sub);
        assert_eq!(
            first,
            (TENANT_DONE, 2 * completed as u64, completed as usize)
        );
        assert_eq!(result(&mut sub), first, "terminal result idempotent");
        // none of the tenant's matches leaked into the seed channel
        let report = srv.finish();
        assert!(report.correspondences.is_empty());
        assert_eq!(report.stats.counter("plans_rejected"), Some(1));
        assert_eq!(report.stats.counter("plans_completed"), Some(1));
        assert_eq!(
            report.stats.gauge(&format!("tenant.{plan_id}.state")),
            Some(TENANT_DONE as u64)
        );
    }

    /// A plan built against different data is refused at submission
    /// (provenance fingerprint check) — and an unknown plan id polls
    /// to a clear error.
    #[test]
    fn foreign_plan_and_unknown_id_are_refused() {
        let (_dataset, _store, srv) = resident_host(60, 9);
        let other = crate::datagen::GeneratorConfig::tiny()
            .with_entities(40)
            .with_seed(77)
            .generate();
        let bytes = plan_bytes_for(&other.dataset, 20);
        let mut c = client(srv.addr());
        let reply = c
            .request(&Message::PlanSubmit {
                name: "foreign".into(),
                plan: bytes,
            })
            .unwrap();
        let Message::PlanRejected { reason, .. } = reply else {
            panic!("expected PlanRejected, got {}", reply.kind());
        };
        assert!(reason.contains("provenance"), "{reason}");
        let reply =
            c.request(&Message::PlanStatus { plan: 42 }).unwrap();
        assert!(matches!(reply, Message::Error { .. }));
        srv.finish();
    }

    /// The abort-on-disconnect half of the tenant lifecycle: the
    /// submitting client's connection drops mid-run, the plan is
    /// aborted and its tasks drained, the straggling completion is
    /// stale — and an observer connection still reads the terminal
    /// `TENANT_ABORTED` result.
    #[test]
    fn client_disconnect_aborts_running_plan() {
        let (dataset, _store, srv) = resident_host(60, 9);
        let bytes = plan_bytes_for(&dataset, 20);
        let mut node = client(srv.addr());
        let svc = join(&mut node, "worker");
        let mut sub = client(srv.addr());
        let Message::PlanAccepted { plan } = sub
            .request(&Message::PlanSubmit {
                name: "doomed".into(),
                plan: bytes,
            })
            .unwrap()
        else {
            panic!("expected PlanAccepted");
        };
        // one task in flight, the rest queued
        let Message::TaskAssign { task, .. } = node
            .request(&Message::TaskRequest { service: svc })
            .unwrap()
        else {
            panic!("expected assignment");
        };
        drop(sub); // the client vanishes mid-run
        let mut obs = client(srv.addr());
        let deadline = Instant::now() + Duration::from_secs(10);
        let state = loop {
            match obs
                .request(&Message::PlanStatus { plan })
                .unwrap()
            {
                Message::PlanResult { state, matches, .. } => {
                    assert!(matches.is_empty());
                    break state;
                }
                Message::PlanStatusReport { .. } => {
                    assert!(
                        Instant::now() < deadline,
                        "disconnect never aborted the plan"
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                other => panic!("unexpected {}", other.kind()),
            }
        };
        assert_eq!(state, TENANT_ABORTED);
        // the drained in-flight task's completion is stale, and no
        // further tenant work is offered
        let reply = node
            .request(&Message::Complete {
                service: svc,
                task_id: task.id,
                comparisons: 1,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(
            matches!(reply, Message::NoTask { done: false }),
            "drained tenant work must not be re-offered"
        );
        let report = srv.finish();
        assert_eq!(report.stats.counter("plans_aborted"), Some(1));
        assert_eq!(report.stats.counter("stale_completions"), Some(1));
    }

    /// PR 8 satellite regression: a panic while a lock on the shared
    /// server state is held (a frame handler dying mid-request) must
    /// not poison-wedge every other connection — the server keeps
    /// serving joins and assignments afterwards.
    #[test]
    fn poisoned_server_locks_do_not_wedge_other_connections() {
        let srv = WorkflowServiceServer::start(
            vec![task(0, 0, 1), task(1, 2, 3)],
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();
        for mutex in ["sched", "members", "results"] {
            let shared = srv.shared.clone();
            assert!(std::thread::spawn(move || {
                match mutex {
                    "sched" => {
                        let _g = shared.sched.lock().unwrap();
                        panic!("poison sched");
                    }
                    "members" => {
                        let _g = shared.members.lock().unwrap();
                        panic!("poison members");
                    }
                    _ => {
                        let _g = shared.results.lock().unwrap();
                        panic!("poison results");
                    }
                }
            })
            .join()
            .is_err());
        }
        assert!(
            srv.shared.sched.lock().is_err(),
            "scheduler mutex should be poisoned"
        );
        // the server still serves: join, pull, complete, report
        let mut c = client(srv.addr());
        let svc = join(&mut c, "post-poison-node");
        let Message::TaskAssign { task: t0, .. } =
            c.request(&Message::TaskRequest { service: svc }).unwrap()
        else {
            panic!("expected assignment after poisoning");
        };
        let reply = c
            .request(&Message::Complete {
                service: svc,
                task_id: t0.id,
                comparisons: 3,
                cached: vec![],
                matches: vec![],
            })
            .unwrap();
        assert!(matches!(reply, Message::TaskAssign { .. }));
        assert_eq!(srv.completed(), 1);
        srv.abort();
    }
}
