//! The data service as a TCP endpoint (paper §4).
//!
//! Wraps the in-process [`DataService`] store behind a socket loop:
//! match services connect, send [`Message::FetchPartition`], and receive
//! the partition payload (entity ids + precomputed match features).
//! Every response is accounted twice, deliberately:
//!
//! * the store's own [`DataService::traffic`] keeps counting *logical*
//!   payload bytes (`approx_bytes`) — comparable with the simulator;
//! * [`DataServiceServer::wire_traffic`] counts the **actual bytes
//!   written to the socket**, frames included — the number a network
//!   monitor would report.

use crate::net::TrafficStats;
use crate::partition::PartitionId;
use crate::rpc::{encode_partition_message, Message, Transport};
use crate::store::DataService;
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

struct DataShared {
    store: Arc<DataService>,
    wire: TrafficStats,
    shutdown: AtomicBool,
    /// Partition payloads are immutable for a run, so each is
    /// serialized once and the encoded frame reused for every
    /// subsequent fetch (repeat fetches are the common case whenever
    /// match-service caches are small).
    encoded: Mutex<HashMap<PartitionId, Arc<Vec<u8>>>>,
}

impl DataShared {
    /// Logical fetch (store accounting) + cached wire encoding.
    fn encoded_payload(&self, id: PartitionId) -> Option<Arc<Vec<u8>>> {
        let data = self.store.try_fetch(id)?;
        let mut cache = self.encoded.lock().unwrap();
        Some(match cache.get(&id) {
            Some(p) => p.clone(),
            None => {
                let p = Arc::new(encode_partition_message(&data));
                cache.insert(id, p.clone());
                p
            }
        })
    }
}

/// A running data-service endpoint.  Dropping the handle does *not* stop
/// the server; call [`DataServiceServer::shutdown`].
pub struct DataServiceServer {
    addr: SocketAddr,
    shared: Arc<DataShared>,
}

impl DataServiceServer {
    /// Bind `bind` (use `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting fetch connections.
    pub fn start(
        store: Arc<DataService>,
        bind: &str,
    ) -> anyhow::Result<DataServiceServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(DataShared {
            store,
            wire: TrafficStats::new(),
            shutdown: AtomicBool::new(false),
            encoded: Mutex::new(HashMap::new()),
        });
        let accept_shared = shared.clone();
        std::thread::Builder::new()
            .name("pem-data-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))?;
        Ok(DataServiceServer { addr, shared })
    }

    /// The bound address (for clients).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Actual bytes delivered over sockets (frames included).
    pub fn wire_bytes(&self) -> u64 {
        self.shared.wire.total_bytes()
    }

    /// Partition payloads served over sockets.
    pub fn wire_messages(&self) -> u64 {
        self.shared.wire.total_messages()
    }

    /// Stop accepting connections.  Existing connections drain on their
    /// own when clients disconnect.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect_timeout(
            &self.addr,
            Duration::from_millis(200),
        );
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<DataShared>) {
    loop {
        let Ok((stream, _)) = listener.accept() else { break };
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let conn_shared = shared.clone();
        let _ = std::thread::Builder::new()
            .name("pem-data-conn".into())
            .spawn(move || handle_conn(stream, conn_shared));
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<DataShared>) {
    let Ok(mut t) = Transport::from_stream(stream) else {
        return;
    };
    // connection lives until the client disconnects (Err on recv)
    while let Ok(msg) = t.recv() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break; // shut down: drop the connection, unblocking clients
        }
        let sent = match msg {
            Message::FetchPartition { id } => {
                match shared.encoded_payload(id) {
                    Some(payload) => t.send_raw_payload(&payload),
                    None => t.send(&Message::Error {
                        message: format!("unknown partition {id}"),
                    }),
                }
            }
            other => t.send(&Message::Error {
                message: format!(
                    "data service got unexpected {}",
                    other.kind()
                ),
            }),
        };
        match sent {
            Ok(n) => shared.wire.record(n),
            Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::model::EntityId;
    use crate::partition::{partition_size_based, PartitionId};

    fn store() -> Arc<DataService> {
        let data = GeneratorConfig::tiny().with_entities(60).generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, 30);
        Arc::new(DataService::build(&data.dataset, &parts))
    }

    #[test]
    fn serves_partitions_over_tcp_and_accounts_wire_bytes() {
        let store = store();
        let srv = DataServiceServer::start(store.clone(), "127.0.0.1:0")
            .unwrap();
        let mut c = Transport::connect(srv.addr(), Duration::from_secs(5))
            .unwrap();
        let reply = c
            .request(&Message::FetchPartition { id: PartitionId(0) })
            .unwrap();
        let Message::Partition { data } = reply else {
            panic!("expected partition, got {}", reply.kind());
        };
        assert_eq!(data.id, PartitionId(0));
        assert_eq!(data.len(), 30);
        assert_eq!(data.features.len(), 30);
        // wire accounting: really-transferred bytes, nonzero and larger
        // than the raw entity-id array alone
        assert_eq!(srv.wire_messages(), 1);
        assert!(srv.wire_bytes() > 30 * 4);
        // the store-side logical accounting ticked too
        assert_eq!(store.fetches(), 1);
        srv.shutdown();
    }

    #[test]
    fn unknown_partition_and_bad_request_answered_with_error() {
        let srv = DataServiceServer::start(store(), "127.0.0.1:0").unwrap();
        let mut c = Transport::connect(srv.addr(), Duration::from_secs(5))
            .unwrap();
        let reply = c
            .request(&Message::FetchPartition {
                id: PartitionId(999),
            })
            .unwrap();
        assert!(matches!(reply, Message::Error { .. }));
        let reply = c.request(&Message::HeartbeatAck).unwrap();
        assert!(matches!(reply, Message::Error { .. }));
        // the connection survived both errors
        let ok = c
            .request(&Message::FetchPartition { id: PartitionId(1) })
            .unwrap();
        assert!(matches!(ok, Message::Partition { .. }));
        srv.shutdown();
    }
}
