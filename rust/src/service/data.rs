//! The data service as a TCP endpoint (paper §4), now replicable.
//!
//! Wraps the in-process [`DataService`] store behind a socket loop:
//! match services connect, send [`Message::FetchPartition`], and receive
//! the partition payload (entity ids + precomputed match features).
//! Since PR 3 the serving side runs on the readiness-driven
//! [`crate::net::reactor`] — frames decoded incrementally from
//! arbitrary read chunks, multi-megabyte partition replies buffered
//! across partial writes — so hundreds of match workers no longer
//! cost one blocking OS thread each.  Since PR 8 the reactor parks in
//! the kernel (`epoll`/`poll(2)`) instead of spin-ticking, shutdown
//! pokes it through a [`crate::net::poll::Waker`], and several
//! services can share one reactor thread
//! ([`DataServiceServer::start_on`] — the dist engine co-hosts the
//! workflow and data services this way).  Cached partition frames are
//! queued by `Arc` ([`SessionEncoder::queue_shared`]) and written
//! with vectored I/O, so the fetch hot path never copies payload
//! bytes into the encoder.
//!
//! A server runs in one of two roles:
//!
//! * **primary** ([`DataServiceServer::start`]) — authoritative, backed
//!   by the full store; partition frames are encoded once and cached;
//! * **replica** ([`DataServiceServer::start_replica`]) — holds no
//!   store, only the **encoded partition frames pushed from an
//!   upstream server** over a [`Message::SyncRequest`] stream, and
//!   re-serves them byte-identically.  A fetch for a partition the
//!   replica does not (yet) hold is answered with
//!   [`Message::Redirect`] to the upstream, never with an error.
//!
//! Every response is accounted twice, deliberately:
//!
//! * the store's own [`DataService::traffic`] keeps counting *logical*
//!   payload bytes (`approx_bytes`) — comparable with the simulator
//!   (replication pushes use [`DataService::peek`] and are **not**
//!   counted as logical fetches);
//! * [`DataServiceServer::wire_bytes`] counts the **actual bytes
//!   written to the socket**, frames included, per server — so a
//!   replicated run reports per-replica byte accounting.

use crate::net::poll::Waker;
use crate::net::reactor::{Action, ConnId, FrameHandler, Reactor};
use crate::net::TrafficStats;
use crate::obs::{
    system_clock, Clock, Counter, Histogram, MetricsSnapshot, Registry,
    Stopwatch,
};
use crate::partition::PartitionId;
use crate::rpc::session::SessionEncoder;
use crate::rpc::{Message, Transport};
use crate::store::DataService;
use crate::util::lock_poisonless;
use std::collections::{HashMap, HashSet};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What backs this server's partitions.
enum Backing {
    /// Authoritative store; frames come from the tiered
    /// [`PartitionStore`](crate::store::PartitionStore) backend
    /// (resident-cached or re-materialized from spill on fault).
    Primary(Arc<DataService>),
    /// No store: only frames pushed from `upstream`.  Misses redirect.
    Replica {
        /// `host:port` of the server this replica syncs from.
        upstream: String,
        /// Read/connect timeout for the sync connection.
        io_timeout: Duration,
        /// `Some(bytes)`: hold only a *partial* hot set of frames
        /// under this byte budget, shedding the least-fetched ones —
        /// the [`crate::store::Layered`] admission policy applied at
        /// the frame level.  `None`: full replica (the default).
        hot_budget: Option<u64>,
    },
}

/// Outcome of one fetch against the local state.
enum Served {
    /// A complete pre-encoded `Partition` frame payload.
    Payload(Arc<Vec<u8>>),
    /// Not here — client should retry at this address.
    Redirect(String),
    /// The store could not produce the partition (unknown id, or a
    /// spill-tier I/O / corruption failure): protocol error with this
    /// detail.
    Failed(String),
}

/// Frame misses a shed partition must accumulate before the next sync
/// round re-admits it — mirrors [`crate::store::Layered::ADMIT_AFTER`]:
/// one miss records interest, the second proves the partition is hot.
const READMIT_AFTER: u64 = 2;

/// Bookkeeping for a partial replica's frame-level hot set.
#[derive(Default)]
struct ReplicaHot {
    /// Total bytes of frames currently held.
    bytes: u64,
    /// Frame size per held partition (eviction accounting).
    sizes: HashMap<PartitionId, u64>,
    /// Fetch requests per partition since startup — the shed-victim
    /// signal (least-fetched frames are shed first).
    freq: HashMap<PartitionId, u64>,
    /// Partitions deliberately not held (shed under the budget).
    shed: HashSet<PartitionId>,
    /// Misses per shed partition since it was shed — the re-admission
    /// signal.
    redirects: HashMap<PartitionId, u64>,
}

struct DataShared {
    backing: Backing,
    wire: TrafficStats,
    /// Shared with the reactor thread, which tears this server down
    /// when it flips (after a [`Waker`] poke — the reactor parks in
    /// the kernel and no longer polls the flag on a tick).
    shutdown: Arc<AtomicBool>,
    /// Pokes the (possibly shared) reactor out of its kernel wait so
    /// a shutdown is observed immediately.
    waker: Waker,
    /// Replica: the initial sync stream completed.  Primaries are
    /// always "synced".
    synced: AtomicBool,
    /// Replica: a sync thread has been started (guards `begin_sync`).
    sync_started: AtomicBool,
    /// Replica: the upstream connection dropped after sync — the
    /// coordinator is gone and this replica can retire.
    upstream_lost: AtomicBool,
    /// Replica frame set, seeded by the sync stream.  Primaries keep
    /// their frames in the store backend instead (which caches or
    /// spills them per its tier); this map stays empty for them.
    encoded: Mutex<HashMap<PartitionId, Arc<Vec<u8>>>>,
    /// Partial-replica hot-set bookkeeping (only consulted when the
    /// backing is a replica with a hot budget).  Never locked while
    /// `encoded` is held, and vice versa — the two are always taken
    /// in separate critical sections.
    replica_hot: Mutex<ReplicaHot>,
    /// Frames shed by a partial replica to stay under its budget.
    partial_evictions: Arc<Counter>,
    /// This server's metrics; scraped live over the wire by
    /// `StatsRequest` (protocol v6, `pem stats`).
    registry: Arc<Registry>,
    /// Monotonic clock for the fetch-serve latency histogram.
    clock: Arc<dyn Clock>,
    /// Nanoseconds from fetch-frame decode to response queued.
    fetch_serve_ns: Arc<Histogram>,
    /// Fetches answered with a partition payload.
    fetches_served: Arc<Counter>,
    /// Fetches answered with a redirect (unsynced replica).
    redirects: Arc<Counter>,
}

impl DataShared {
    /// Serve a fetch from local state; see [`Served`].
    fn serve(&self, id: PartitionId) -> Served {
        match &self.backing {
            Backing::Primary(store) => {
                // logical fetch accounting on every hit, like the
                // in-process engines; the backend caches the frame
                // (resident) or re-materializes it from spill (fault)
                match store.fetch_frame(id) {
                    Ok(payload) => Served::Payload(payload),
                    Err(e) => Served::Failed(e.to_string()),
                }
            }
            Backing::Replica {
                upstream,
                hot_budget,
                ..
            } => {
                let hit =
                    lock_poisonless(&self.encoded).get(&id).cloned();
                if hot_budget.is_some() {
                    let mut hot = lock_poisonless(&self.replica_hot);
                    *hot.freq.entry(id).or_insert(0) += 1;
                    if hit.is_none() && hot.shed.contains(&id) {
                        *hot.redirects.entry(id).or_insert(0) += 1;
                    }
                }
                match hit {
                    Some(p) => Served::Payload(p),
                    None => Served::Redirect(upstream.clone()),
                }
            }
        }
    }

    /// Ids this server can currently serve without redirecting.
    fn held_ids(&self) -> Vec<PartitionId> {
        match &self.backing {
            Backing::Primary(store) => store.partition_ids(),
            Backing::Replica { .. } => {
                let mut ids: Vec<PartitionId> =
                    lock_poisonless(&self.encoded).keys().copied().collect();
                ids.sort_unstable();
                ids
            }
        }
    }

    /// Refresh the point-in-time gauges and snapshot the registry —
    /// the payload of a `StatsReport` and of
    /// [`DataServiceServer::stats`].  Primaries merge the storage
    /// tier's `store.*` metrics (faults, evictions, spill bytes, the
    /// fault-latency histogram) into the snapshot, so `pem stats`
    /// sees the out-of-core behavior next to the wire counters.
    fn stats_snapshot(&self) -> MetricsSnapshot {
        let r = &self.registry;
        r.gauge("partitions_held").set(self.held_ids().len() as u64);
        r.gauge("wire_bytes").set(self.wire.total_bytes());
        r.gauge("wire_messages").set(self.wire.total_messages());
        r.gauge("synced").set(self.synced.load(Ordering::SeqCst) as u64);
        match &self.backing {
            Backing::Primary(store) => {
                r.snapshot().merge(&store.store_stats().to_snapshot())
            }
            Backing::Replica { hot_budget, .. } => {
                if hot_budget.is_some() {
                    r.gauge("hot_bytes")
                        .set(lock_poisonless(&self.replica_hot).bytes);
                }
                r.snapshot()
            }
        }
    }

    /// The encoded frame for `id` **without** logical fetch accounting
    /// (replication push path).
    fn encoded_for_sync(&self, id: PartitionId) -> Option<Arc<Vec<u8>>> {
        if let Some(p) = lock_poisonless(&self.encoded).get(&id) {
            return Some(p.clone());
        }
        match &self.backing {
            Backing::Primary(store) => store.peek_frame(id),
            Backing::Replica { .. } => None,
        }
    }

    /// Absorb one frame pushed by the sync stream, then (for a partial
    /// replica) shed the least-fetched frames until the hot budget
    /// holds again.  Lock discipline: `encoded` and `replica_hot` are
    /// taken strictly one after the other, never nested.
    fn absorb_sync_frame(&self, id: PartitionId, raw: Vec<u8>) {
        let len = raw.len() as u64;
        let replaced =
            lock_poisonless(&self.encoded).insert(id, Arc::new(raw));
        let Backing::Replica { hot_budget, .. } = &self.backing else {
            return;
        };
        let mut victims: Vec<PartitionId> = Vec::new();
        {
            let mut hot = lock_poisonless(&self.replica_hot);
            if let Some(old) = replaced {
                hot.bytes -= old.len() as u64;
            }
            hot.bytes += len;
            hot.sizes.insert(id, len);
            hot.shed.remove(&id);
            hot.redirects.remove(&id);
            if let Some(budget) = hot_budget {
                while hot.bytes > *budget && !hot.sizes.is_empty() {
                    let victim = hot
                        .sizes
                        .keys()
                        .min_by_key(|p| {
                            (hot.freq.get(*p).copied().unwrap_or(0), p.0)
                        })
                        .copied()
                        .expect("non-empty sizes");
                    let size =
                        hot.sizes.remove(&victim).unwrap_or(0);
                    hot.bytes -= size;
                    hot.shed.insert(victim);
                    hot.redirects.insert(victim, 0);
                    self.partial_evictions.inc();
                    victims.push(victim);
                }
            }
        }
        if !victims.is_empty() {
            let mut encoded = lock_poisonless(&self.encoded);
            for v in &victims {
                encoded.remove(v);
            }
        }
    }

    /// What a sync round claims to already have: every held frame,
    /// plus (for a partial replica) the shed frames that have *not*
    /// accumulated [`READMIT_AFTER`] misses — the upstream only pushes
    /// what is absent from this list, so listing a cold shed frame
    /// keeps it shed while omitting a hot one re-admits it.
    fn sync_have(&self) -> Vec<PartitionId> {
        let mut have: Vec<PartitionId> =
            lock_poisonless(&self.encoded).keys().copied().collect();
        if let Backing::Replica {
            hot_budget: Some(_),
            ..
        } = &self.backing
        {
            let hot = lock_poisonless(&self.replica_hot);
            have.extend(hot.shed.iter().copied().filter(|p| {
                hot.redirects.get(p).copied().unwrap_or(0)
                    < READMIT_AFTER
            }));
        }
        have
    }
}

/// A running data-service endpoint (primary or replica).  Dropping the
/// handle does *not* stop the server; call
/// [`DataServiceServer::shutdown`].
pub struct DataServiceServer {
    addr: SocketAddr,
    shared: Arc<DataShared>,
}

impl DataServiceServer {
    /// Bind `bind` (use `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting fetch connections as the **primary**, backed by `store`.
    pub fn start(
        store: Arc<DataService>,
        bind: &str,
    ) -> anyhow::Result<DataServiceServer> {
        Self::start_inner(Backing::Primary(store), bind, true)
    }

    /// Bind `bind` and start as a **replica** of the data server at
    /// `upstream` (`host:port`): immediately begins pulling every
    /// partition frame over a [`Message::SyncRequest`] stream, serving
    /// redirects for partitions that have not arrived yet.  Use
    /// [`DataServiceServer::wait_synced`] to block until the replica is
    /// complete.
    pub fn start_replica(
        bind: &str,
        upstream: &str,
        io_timeout: Duration,
    ) -> anyhow::Result<DataServiceServer> {
        let srv = Self::start_replica_deferred(bind, upstream, io_timeout)?;
        srv.begin_sync();
        Ok(srv)
    }

    /// Like [`DataServiceServer::start_replica`], but holding only a
    /// **partial** hot set: at most `hot_budget` bytes of frames stay
    /// resident, the least-fetched ones are shed, and a shed frame is
    /// re-admitted by the periodic sync rounds once enough fetch
    /// misses prove it hot again.  Misses keep answering with the
    /// usual [`Message::Redirect`] to the upstream — the protocol is
    /// unchanged.
    pub fn start_replica_partial(
        bind: &str,
        upstream: &str,
        io_timeout: Duration,
        hot_budget: u64,
    ) -> anyhow::Result<DataServiceServer> {
        let srv = Self::start_inner(
            Backing::Replica {
                upstream: upstream.to_string(),
                io_timeout,
                hot_budget: Some(hot_budget),
            },
            bind,
            false,
        )?;
        srv.begin_sync();
        Ok(srv)
    }

    /// Like [`DataServiceServer::start_replica`], but without starting
    /// the sync stream: the replica serves [`Message::Redirect`] for
    /// everything until [`DataServiceServer::begin_sync`] is called.
    /// Lets callers control when replication traffic happens (and tests
    /// exercise the redirect path deterministically).
    pub fn start_replica_deferred(
        bind: &str,
        upstream: &str,
        io_timeout: Duration,
    ) -> anyhow::Result<DataServiceServer> {
        Self::start_inner(
            Backing::Replica {
                upstream: upstream.to_string(),
                io_timeout,
                hot_budget: None,
            },
            bind,
            false,
        )
    }

    /// Register a **primary** on a caller-owned [`Reactor`] instead of
    /// spawning a dedicated one — the dist engine co-hosts the data
    /// and workflow services on a single reactor thread this way.
    /// The caller spawns (or runs) the reactor afterwards.
    pub fn start_on(
        reactor: &mut Reactor,
        store: Arc<DataService>,
        bind: &str,
    ) -> anyhow::Result<DataServiceServer> {
        Self::register_on(reactor, Backing::Primary(store), bind, true)
    }

    fn start_inner(
        backing: Backing,
        bind: &str,
        synced: bool,
    ) -> anyhow::Result<DataServiceServer> {
        let mut reactor = Reactor::build()?;
        let srv = Self::register_on(&mut reactor, backing, bind, synced)?;
        reactor.spawn("pem-data-reactor")?;
        Ok(srv)
    }

    fn register_on(
        reactor: &mut Reactor,
        backing: Backing,
        bind: &str,
        synced: bool,
    ) -> anyhow::Result<DataServiceServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let registry = Arc::new(Registry::new());
        registry.set_label(
            "role",
            if matches!(backing, Backing::Primary(_)) {
                "data-primary"
            } else {
                "data-replica"
            },
        );
        registry.set_label("addr", &addr.to_string());
        let shared = Arc::new(DataShared {
            backing,
            wire: TrafficStats::new(),
            shutdown: shutdown.clone(),
            waker: reactor.waker(),
            synced: AtomicBool::new(synced),
            sync_started: AtomicBool::new(false),
            upstream_lost: AtomicBool::new(false),
            encoded: Mutex::new(HashMap::new()),
            replica_hot: Mutex::new(ReplicaHot::default()),
            partial_evictions: registry.counter("partial_evictions"),
            clock: system_clock(),
            fetch_serve_ns: registry.histogram("fetch_serve_ns"),
            fetches_served: registry.counter("fetches_served"),
            redirects: registry.counter("redirects"),
            registry: registry.clone(),
        });
        reactor.add_server(
            listener,
            Box::new(DataHandler {
                shared: shared.clone(),
            }),
            shutdown,
            &registry,
        )?;
        Ok(DataServiceServer { addr, shared })
    }

    /// Replica: start the background sync stream from the upstream
    /// server.  Idempotent; a no-op on primaries.
    pub fn begin_sync(&self) {
        if !matches!(self.shared.backing, Backing::Replica { .. }) {
            return;
        }
        if self.shared.sync_started.swap(true, Ordering::SeqCst) {
            return; // already running
        }
        let shared = self.shared.clone();
        let _ = std::thread::Builder::new()
            .name("pem-data-sync".into())
            .spawn(move || sync_loop(shared));
    }

    /// The bound address (for clients).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// `true` for servers started with
    /// [`DataServiceServer::start_replica`] /
    /// [`DataServiceServer::start_replica_deferred`].
    pub fn is_replica(&self) -> bool {
        matches!(self.shared.backing, Backing::Replica { .. })
    }

    /// Block until the initial replication stream has completed
    /// (immediately `true` on primaries); `false` on timeout.
    pub fn wait_synced(&self, timeout: Duration) -> bool {
        let waited = Stopwatch::start();
        loop {
            if self.shared.synced.load(Ordering::SeqCst) {
                return true;
            }
            if waited.elapsed() >= timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Replica: the upstream connection dropped after sync (the
    /// coordinator went away) — this replica can retire.
    pub fn upstream_lost(&self) -> bool {
        self.shared.upstream_lost.load(Ordering::SeqCst)
    }

    /// Partitions this server can serve without redirecting.
    pub fn partition_count(&self) -> usize {
        self.shared.held_ids().len()
    }

    /// Ids of the partitions this server holds (for replica
    /// announcements — see [`crate::service::announce_replica`]).
    pub fn partition_ids(&self) -> Vec<PartitionId> {
        self.shared.held_ids()
    }

    /// Actual bytes delivered over sockets (frames included).
    pub fn wire_bytes(&self) -> u64 {
        self.shared.wire.total_bytes()
    }

    /// Partition payloads served over sockets.
    pub fn wire_messages(&self) -> u64 {
        self.shared.wire.total_messages()
    }

    /// A live metrics snapshot of this server — the same payload a
    /// wire `StatsRequest` gets: fetch counters, the fetch-serve
    /// latency histogram, and point-in-time gauges (partitions held,
    /// wire traffic, sync state).
    pub fn stats(&self) -> MetricsSnapshot {
        self.shared.stats_snapshot()
    }

    /// Stop the server: wakes the reactor out of its kernel wait,
    /// which tears this server down and drops its open connections,
    /// unblocking clients with an I/O error.  Co-hosted servers on a
    /// shared reactor are untouched.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.waker.wake();
    }
}

/// The reactor-driven connection handler: one instance serves every
/// fetch and replication connection of this server.
struct DataHandler {
    shared: Arc<DataShared>,
}

impl FrameHandler for DataHandler {
    fn on_frame(
        &mut self,
        _conn: ConnId,
        out: &mut SessionEncoder,
        payload: &[u8],
    ) -> Action {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Action::Close; // drop the connection, unblocking clients
        }
        let msg = match Message::decode(payload) {
            Ok(msg) => msg,
            Err(e) => {
                out.queue_message(&Message::Error {
                    message: format!("undecodable frame: {e}"),
                });
                return Action::Close;
            }
        };
        let sent = match msg {
            Message::FetchPartition { id } => {
                let t0 = self.shared.clock.now_ns();
                let sent = match self.shared.serve(id) {
                    Served::Payload(payload) => {
                        self.shared.fetches_served.inc();
                        // zero-copy: the cached frame is queued by Arc
                        // and written straight from the shared buffer
                        out.queue_shared(payload)
                    }
                    Served::Redirect(addr) => {
                        self.shared.redirects.inc();
                        out.queue_message(&Message::Redirect { addr })
                    }
                    Served::Failed(message) => {
                        out.queue_message(&Message::Error { message })
                    }
                };
                self.shared.fetch_serve_ns.observe(
                    self.shared.clock.now_ns().saturating_sub(t0),
                );
                sent
            }
            Message::SyncRequest { have } => {
                queue_sync(&self.shared, out, &have)
            }
            Message::StatsRequest => out.queue_message(
                &Message::StatsReport {
                    stats: self.shared.stats_snapshot().to_bytes(),
                },
            ),
            other => out.queue_message(&Message::Error {
                message: format!(
                    "data service got unexpected {}",
                    other.kind()
                ),
            }),
        };
        self.shared.wire.record(sent);
        Action::Continue
    }
}

/// Upper bound on the payload bytes one `SyncRequest` response pushes.
/// The reactor queues a whole response before the socket drains it, so
/// an unbounded response would duplicate the entire encoded store in
/// the connection's outbound buffer (and trip the reactor's
/// send-buffer cap on very large stores, wedging replication).
/// Bounding the round keeps peak buffering small; the replica simply
/// issues another round for the remainder (see [`sync_loop`]).
const MAX_SYNC_BATCH_BYTES: u64 = 32 * 1024 * 1024;

/// Queue held partition frames the peer lacks — up to
/// [`MAX_SYNC_BATCH_BYTES`] per round — then `SyncDone`.  Returns the
/// total bytes queued (recorded as one traffic entry — replication is
/// one logical transfer, not thousands of fetches).  The reactor's
/// outbound buffering drains the round across as many writable events
/// as the socket needs.
fn queue_sync(
    shared: &DataShared,
    out: &mut SessionEncoder,
    have: &[PartitionId],
) -> u64 {
    let have: HashSet<PartitionId> = have.iter().copied().collect();
    let mut total = 0u64;
    let mut count = 0u32;
    for id in shared.held_ids() {
        if have.contains(&id) {
            continue;
        }
        // `encoded_for_sync` can only miss if a concurrent shutdown
        // raced the id listing; skip rather than abort the stream
        if let Some(payload) = shared.encoded_for_sync(id) {
            total += out.queue_shared(payload);
            count += 1;
            if total >= MAX_SYNC_BATCH_BYTES {
                break; // bounded round: the next round pulls the rest
            }
        }
    }
    total += out.queue_message(&Message::SyncDone { count });
    total
}

/// One [`Message::SyncRequest`] round: ask upstream for everything not
/// in the local frame set and absorb the pushed frames.  Returns the
/// number of frames received, or an error when the upstream is gone /
/// refused.
fn sync_round(t: &mut Transport, shared: &DataShared) -> anyhow::Result<u32> {
    let have = shared.sync_have();
    t.send(&Message::SyncRequest { have })?;
    let mut received = 0u32;
    loop {
        let raw = t.recv_raw()?;
        match Message::decode(&raw) {
            Ok(Message::Partition { data }) => {
                shared.absorb_sync_frame(data.id, raw);
                received += 1;
            }
            Ok(Message::SyncDone { .. }) => return Ok(received),
            Ok(Message::Error { message }) => {
                anyhow::bail!("upstream refused sync: {message}")
            }
            Ok(other) => {
                anyhow::bail!("unexpected {} in sync stream", other.kind())
            }
            Err(e) => anyhow::bail!("corrupt sync frame: {e}"),
        }
    }
}

/// Replica background thread: pull the full frame set from upstream,
/// then keep heartbeating with incremental sync rounds — which both
/// detects the upstream's departure (the coordinator went away) and
/// heals any frames this replica is missing.
fn sync_loop(shared: Arc<DataShared>) {
    let Backing::Replica {
        upstream,
        io_timeout,
    } = &shared.backing
    else {
        return;
    };
    let mut t = match Transport::connect(upstream.as_str(), *io_timeout) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("data replica: cannot reach upstream {upstream}: {e}");
            shared.upstream_lost.store(true, Ordering::SeqCst);
            return;
        }
    };
    // initial sync: the upstream bounds each round's response, so keep
    // pulling rounds until one pushes nothing new
    loop {
        match sync_round(&mut t, &shared) {
            Ok(0) => break,
            Ok(_) => {}
            Err(e) => {
                eprintln!(
                    "data replica: sync from {upstream} failed: {e:#}"
                );
                shared.upstream_lost.store(true, Ordering::SeqCst);
                return;
            }
        }
    }
    shared.synced.store(true, Ordering::SeqCst);
    let interval = Duration::from_millis(400);
    let step = Duration::from_millis(20);
    'watch: loop {
        let mut slept = Duration::ZERO;
        while slept < interval {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(step);
            slept += step;
        }
        if sync_round(&mut t, &shared).is_err() {
            break 'watch;
        }
    }
    shared.upstream_lost.store(true, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::model::EntityId;
    use crate::partition::{partition_size_based, PartitionId};
    use std::time::Instant;

    fn store() -> Arc<DataService> {
        let data = GeneratorConfig::tiny().with_entities(60).generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, 30);
        Arc::new(DataService::build(&data.dataset, &parts))
    }

    #[test]
    fn serves_partitions_over_tcp_and_accounts_wire_bytes() {
        let store = store();
        let srv = DataServiceServer::start(store.clone(), "127.0.0.1:0")
            .unwrap();
        let mut c = Transport::connect(srv.addr(), Duration::from_secs(5))
            .unwrap();
        let reply = c
            .request(&Message::FetchPartition { id: PartitionId(0) })
            .unwrap();
        let Message::Partition { data } = reply else {
            panic!("expected partition, got {}", reply.kind());
        };
        assert_eq!(data.id, PartitionId(0));
        assert_eq!(data.len(), 30);
        assert_eq!(data.features.len(), 30);
        // wire accounting: really-transferred bytes, nonzero and larger
        // than the raw entity-id array alone
        assert_eq!(srv.wire_messages(), 1);
        assert!(srv.wire_bytes() > 30 * 4);
        // the store-side logical accounting ticked too
        assert_eq!(store.fetches(), 1);
        srv.shutdown();
    }

    #[test]
    fn unknown_partition_and_bad_request_answered_with_error() {
        let srv = DataServiceServer::start(store(), "127.0.0.1:0").unwrap();
        let mut c = Transport::connect(srv.addr(), Duration::from_secs(5))
            .unwrap();
        let reply = c
            .request(&Message::FetchPartition {
                id: PartitionId(999),
            })
            .unwrap();
        assert!(matches!(reply, Message::Error { .. }));
        let reply = c.request(&Message::HeartbeatAck).unwrap();
        assert!(matches!(reply, Message::Error { .. }));
        // the connection survived both errors
        let ok = c
            .request(&Message::FetchPartition { id: PartitionId(1) })
            .unwrap();
        assert!(matches!(ok, Message::Partition { .. }));
        srv.shutdown();
    }

    /// A replica syncs the primary's encoded frames and re-serves them
    /// byte-identically, without touching the primary's logical fetch
    /// accounting.
    #[test]
    fn replica_syncs_and_serves_identical_frames() {
        let store = store();
        let n_parts = store.n_partitions();
        let primary =
            DataServiceServer::start(store.clone(), "127.0.0.1:0").unwrap();
        let replica = DataServiceServer::start_replica(
            "127.0.0.1:0",
            &primary.addr().to_string(),
            Duration::from_secs(5),
        )
        .unwrap();
        assert!(replica.is_replica());
        assert!(!primary.is_replica());
        assert!(replica.wait_synced(Duration::from_secs(10)));
        assert_eq!(replica.partition_count(), n_parts);
        assert_eq!(replica.partition_ids(), store.partition_ids());
        // replication is not a logical fetch
        assert_eq!(store.fetches(), 0);

        let mut cp =
            Transport::connect(primary.addr(), Duration::from_secs(5))
                .unwrap();
        let mut cr =
            Transport::connect(replica.addr(), Duration::from_secs(5))
                .unwrap();
        let req = Message::FetchPartition { id: PartitionId(1) };
        let from_primary = cp.request(&req).unwrap();
        let from_replica = cr.request(&req).unwrap();
        assert_eq!(from_primary.encode(), from_replica.encode());
        // only the direct primary fetch is a logical fetch
        assert_eq!(store.fetches(), 1);
        // both servers account their own wire traffic
        assert!(primary.wire_bytes() > 0);
        assert!(replica.wire_bytes() > 0);
        replica.shutdown();
        primary.shutdown();
    }

    /// Before sync, a replica answers fetches with a redirect to its
    /// upstream; after sync it serves the payload itself.
    #[test]
    fn unsynced_replica_redirects_to_upstream() {
        let primary =
            DataServiceServer::start(store(), "127.0.0.1:0").unwrap();
        let upstream = primary.addr().to_string();
        let replica = DataServiceServer::start_replica_deferred(
            "127.0.0.1:0",
            &upstream,
            Duration::from_secs(5),
        )
        .unwrap();
        let mut c =
            Transport::connect(replica.addr(), Duration::from_secs(5))
                .unwrap();
        let reply = c
            .request(&Message::FetchPartition { id: PartitionId(0) })
            .unwrap();
        let Message::Redirect { addr } = reply else {
            panic!("expected redirect, got {}", reply.kind());
        };
        assert_eq!(addr, upstream);

        replica.begin_sync();
        assert!(replica.wait_synced(Duration::from_secs(10)));
        let reply = c
            .request(&Message::FetchPartition { id: PartitionId(0) })
            .unwrap();
        assert!(matches!(reply, Message::Partition { .. }));
        replica.shutdown();
        primary.shutdown();
    }

    /// A `StatsRequest` over the wire returns the same live snapshot
    /// as [`DataServiceServer::stats`]: role label, fetch counters,
    /// and a fetch-serve latency histogram with one observation per
    /// fetch frame.
    #[test]
    fn stats_request_scrapes_live_fetch_metrics() {
        let srv = DataServiceServer::start(store(), "127.0.0.1:0").unwrap();
        let mut c = Transport::connect(srv.addr(), Duration::from_secs(5))
            .unwrap();
        for id in [PartitionId(0), PartitionId(1), PartitionId(0)] {
            let reply =
                c.request(&Message::FetchPartition { id }).unwrap();
            assert!(matches!(reply, Message::Partition { .. }));
        }
        let reply = c.request(&Message::StatsRequest).unwrap();
        let Message::StatsReport { stats } = reply else {
            panic!("expected stats report, got {}", reply.kind());
        };
        let snap = MetricsSnapshot::from_bytes(&stats).unwrap();
        assert_eq!(snap.label("role"), Some("data-primary"));
        assert_eq!(snap.label("addr"), Some(srv.addr().to_string()).as_deref());
        assert_eq!(snap.counter("fetches_served"), Some(3));
        assert_eq!(snap.counter("redirects"), Some(0));
        assert_eq!(snap.gauge("partitions_held"), Some(2));
        assert_eq!(snap.gauge("synced"), Some(1));
        assert!(snap.gauge("wire_bytes").unwrap() > 0);
        let hist = snap.histogram("fetch_serve_ns").unwrap();
        assert_eq!(hist.count, 3);
        // the in-process accessor agrees (wire gauges may have moved)
        assert_eq!(srv.stats().counter("fetches_served"), Some(3));
        srv.shutdown();
    }

    /// A partial replica sheds frames to its hot budget, redirects for
    /// shed partitions, and re-admits a shed partition once repeated
    /// misses prove it hot — all over the unchanged v7 sync protocol.
    #[test]
    fn partial_replica_sheds_and_readmits_by_demand() {
        let store = store();
        let n_parts = store.n_partitions();
        let primary =
            DataServiceServer::start(store.clone(), "127.0.0.1:0").unwrap();
        // budget ≈ one frame: the replica can hold one partition hot
        let frame = store.peek_frame(PartitionId(0)).unwrap();
        let replica = DataServiceServer::start_replica_partial(
            "127.0.0.1:0",
            &primary.addr().to_string(),
            Duration::from_secs(5),
            frame.len() as u64 + 16,
        )
        .unwrap();
        assert!(replica.wait_synced(Duration::from_secs(10)));
        let held = replica.partition_ids();
        assert!(
            held.len() < n_parts,
            "partial replica held everything: {held:?}"
        );
        assert!(
            replica.stats().counter("partial_evictions").unwrap() > 0
        );

        // a shed partition redirects to the upstream
        let shed = store
            .partition_ids()
            .into_iter()
            .find(|id| !held.contains(id))
            .expect("some partition was shed");
        let mut c =
            Transport::connect(replica.addr(), Duration::from_secs(5))
                .unwrap();
        for _ in 0..READMIT_AFTER {
            let reply = c
                .request(&Message::FetchPartition { id: shed })
                .unwrap();
            assert!(matches!(reply, Message::Redirect { .. }));
        }

        // the next heartbeat sync rounds re-admit the now-hot frame
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let reply = c
                .request(&Message::FetchPartition { id: shed })
                .unwrap();
            match reply {
                Message::Partition { data } => {
                    assert_eq!(data.id, shed);
                    break;
                }
                Message::Redirect { .. } => {
                    assert!(
                        Instant::now() < deadline,
                        "shed partition was never re-admitted"
                    );
                    std::thread::sleep(Duration::from_millis(50));
                }
                other => panic!("unexpected {}", other.kind()),
            }
        }
        // the budget still holds: something else was shed in its place
        assert!(replica.partition_count() < n_parts);
        replica.shutdown();
        primary.shutdown();
    }

    /// A replica notices when its upstream goes away after sync.
    #[test]
    fn replica_detects_upstream_loss() {
        let primary =
            DataServiceServer::start(store(), "127.0.0.1:0").unwrap();
        let replica = DataServiceServer::start_replica(
            "127.0.0.1:0",
            &primary.addr().to_string(),
            Duration::from_secs(5),
        )
        .unwrap();
        assert!(replica.wait_synced(Duration::from_secs(10)));
        assert!(!replica.upstream_lost());
        primary.shutdown();
        // the primary drops the sync connection at its next recv; give
        // the watcher a moment to observe it
        let deadline = Instant::now() + Duration::from_secs(10);
        while !replica.upstream_lost() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(replica.upstream_lost());
        replica.shutdown();
    }
}
