//! A match service as a TCP client node (paper §4).
//!
//! One node = one [`ServiceId`]: it joins the workflow service (the
//! join handshake negotiates the protocol version and delivers the
//! data-plane **replica directory**), runs `threads` match workers
//! that pull tasks over the wire, fetch partitions from the data-plane
//! replicas through a shared [`PartitionCache`], execute them on the
//! configured [`TaskExecutor`] (pure-Rust or accelerated — the same
//! trait the in-process engines use), and report completions with the
//! piggybacked cache status.  A separate heartbeat thread keeps the
//! workflow service's failure detector fed.
//!
//! Each wire fetch picks a data replica through the node-wide
//! [`ReplicaSelector`] (cached-locality first, then
//! least-outstanding-fetches) and **fails over** to the next replica
//! on connection errors; only when every replica is dead does the node
//! abandon its task and stop heartbeating, so the workflow service
//! re-queues it (paper §4 failure handling, now on the data plane too).
//!
//! With `batch > 1` a worker speaks protocol v3: one
//! `TaskRequestBatch` reports every task it finished and pulls up to
//! `batch` new ones — a single control round trip per batch instead of
//! per task — and, while it chews through the batch, a node-wide
//! **prefetcher** thread pulls the upcoming tasks' partitions into the
//! shared cache, overlapping execution with data-plane fetches.  The
//! prefetcher is **deadline-aware**: every queued task is stamped with
//! a node-wide sequence number in `TaskAssignBatch` arrival order (the
//! order workers will execute them), and the prefetcher always serves
//! the lowest-stamped partition next ([`PrefetchQueue`]) — so the
//! partition needed *soonest* is warmed first instead of whichever
//! worker happened to enqueue first.  With a spill-backed data plane
//! this matters twice: a fetch that faults on the primary is slow, and
//! warming in execution order keeps those faults off the critical
//! path.
//!
//! With a `task_memory_budget` the node enforces the paper's §3.1
//! memory model (protocol v4): every assignment carries the task's
//! estimated footprint, and one that exceeds the budget is answered
//! with `TaskRejected` — the coordinator re-queues it marked oversize
//! for this node and routes it to a roomier one.  The budget is also
//! reported at join (v5), so a task *every* node rejects comes back
//! reshaped: the scheduler splits its pair space and each sub-task
//! assignment carries a [`TaskSpan`] telling this node which
//! entity-range rectangle of the fetched partitions to compare.
//! Written-off data replicas are retried after
//! `replica_retry_cooldown` instead of being banned for the rest of
//! the run.
//!
//! The node runs to workflow completion (`NoTask { done: true }` /
//! an empty batch with `done`), then leaves gracefully.
//! `fail_after_tasks` simulates a crash for failure-handling tests:
//! after N completions the node abandons its next assigned task and
//! stops heartbeating, so the workflow service must detect the failure
//! and re-queue.

use crate::coordinator::scheduler::ServiceId;
use crate::obs::{system_clock, Clock, TraceEventKind, Tracer};
use crate::partition::{MatchTask, PartitionId, TaskSpan};
use crate::rpc::{CompletedTask, Message, Transport, PROTOCOL_VERSION};
use crate::service::replica::ReplicaSelector;
use crate::store::PartitionData;
use crate::worker::{task_comparisons, PartitionCache, TaskExecutor};
use anyhow::{bail, Context, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Configuration of one match-service node.
#[derive(Clone, Debug)]
pub struct MatchNodeConfig {
    /// Workflow-service address, `host:port`.
    pub workflow_addr: String,
    /// Data-plane replica addresses, `host:port` each, preference
    /// order.  The directory delivered in `JoinAck` is merged in at
    /// join time (deduplicated, appended), so one seed address is
    /// enough when the coordinator knows the rest.
    pub data_addrs: Vec<String>,
    /// Human-readable node name (shows up in coordinator logs).
    pub name: String,
    /// Match worker threads (the paper's threads-per-node).
    pub threads: usize,
    /// Partition-cache capacity `c` shared by the node's workers
    /// (0 disables caching).
    pub cache_capacity: usize,
    /// Tasks requested per control-plane round trip (protocol v3
    /// batched assignment).  `1` keeps the classic one-task
    /// `TaskRequest`/`Complete` flow; `k > 1` makes each worker pull
    /// up to `k` tasks per `TaskRequestBatch` with its completion
    /// reports piggybacked, and (with a cache) enables the prefetcher
    /// that overlaps execution with partition fetches.
    pub batch: usize,
    /// Liveness signal period; must be well below the workflow
    /// service's heartbeat timeout.
    pub heartbeat_interval: Duration,
    /// Back-off when the open task list is momentarily empty.
    pub poll_interval: Duration,
    /// Connect/read timeout for all sockets.
    pub io_timeout: Duration,
    /// §3.1 memory budget of this node: an assigned task whose
    /// footprint (delivered with the assignment, protocol v4) exceeds
    /// this is answered with `TaskRejected` instead of being executed
    /// — the coordinator re-queues it for nodes with more memory.
    /// `None` accepts every task (the pre-v4 behavior).
    pub task_memory_budget: Option<u64>,
    /// How long a data replica written off after a connection failure
    /// stays excluded before fetches try it again
    /// ([`ReplicaSelector`] re-admission).
    pub replica_retry_cooldown: Duration,
    /// Test hook: simulate a crash after completing this many tasks.
    pub fail_after_tasks: Option<usize>,
    /// Optional in-process lifecycle tracer: each executed task emits
    /// `PartitionsFetched` (both inputs warm) and `Executed` events
    /// tagged with this node's [`ServiceId`].  Useful when the
    /// workflow service runs in the same process (the distributed
    /// engine, integration tests) so node events interleave with the
    /// scheduler's in one replayable stream.
    pub tracer: Option<Arc<Tracer>>,
}

impl MatchNodeConfig {
    /// Config with defaults, seeded with one data-plane address (add
    /// more to [`MatchNodeConfig::data_addrs`] for a replicated run —
    /// or let the `JoinAck` directory supply them).
    pub fn new(workflow_addr: String, data_addr: String) -> MatchNodeConfig {
        MatchNodeConfig {
            workflow_addr,
            data_addrs: vec![data_addr],
            name: "match-node".into(),
            threads: 1,
            cache_capacity: 0,
            batch: 1,
            heartbeat_interval: Duration::from_millis(50),
            poll_interval: Duration::from_millis(2),
            io_timeout: Duration::from_secs(30),
            task_memory_budget: None,
            replica_retry_cooldown:
                crate::service::replica::DEFAULT_RETRY_COOLDOWN,
            fail_after_tasks: None,
            tracer: None,
        }
    }
}

/// What one node did during a run.
#[derive(Debug, Clone)]
pub struct NodeReport {
    /// The [`ServiceId`] granted at join.
    pub service: usize,
    /// Tasks this node completed and reported.
    pub tasks_completed: u64,
    /// Pair comparisons this node evaluated.
    pub comparisons: u64,
    /// Partition-cache hits across the node's workers.
    pub cache_hits: u64,
    /// Partition-cache misses (each one a wire fetch).
    pub cache_misses: u64,
    /// Wire fetches issued per data replica, in selector order
    /// (config addresses first, then directory additions).
    pub fetches_per_replica: Vec<u64>,
    /// Data replicas this node gave up on mid-run (connection errors
    /// answered by failing over to the next replica).
    pub replica_failovers: u64,
    /// Written-off replicas re-admitted after the retry cooldown.
    pub replica_readmissions: u64,
    /// Assignments this node rejected as oversize (§3.1 memory
    /// budget, protocol v4); each was re-queued by the coordinator.
    pub tasks_rejected: u64,
    /// Busy time per worker thread, ns.
    pub busy_ns: Vec<u64>,
    /// The node went down without a graceful leave — either the
    /// simulated crash (`fail_after_tasks`) or a worker hitting an
    /// unrecoverable error while holding a task.  Either way heartbeats
    /// stopped, so the workflow service re-queues its in-flight work.
    pub crashed: bool,
    /// The coordinator went away mid-run (treated as end of workflow).
    pub lost_coordinator: bool,
    /// Partitions evicted from the node cache to stay under capacity
    /// (`cache.evictions` — tells capacity thrash from cold misses).
    pub cache_evictions: u64,
    /// Cost-model bytes resident in the node cache at run end
    /// (`cache.resident_bytes`).
    pub cache_resident_bytes: u64,
}

/// Deadline-aware prefetch queue shared by the workers and the
/// node-wide prefetcher thread.
///
/// Every task accepted from a `TaskAssignBatch` draws a node-wide
/// [`PrefetchQueue::stamp`] in arrival order — the order the workers
/// will execute them.  Workers push the queued tasks' partitions
/// tagged with their task's stamp, and [`PrefetchQueue::pop`] always
/// yields the lowest stamp first: the partition needed *soonest*
/// across the whole node, not whichever worker enqueued first.
struct PrefetchQueue {
    seq: AtomicU64,
    state: Mutex<PrefetchState>,
    cv: Condvar,
}

#[derive(Default)]
struct PrefetchState {
    /// Min-heap of `(assignment stamp, partition id)`.
    heap: BinaryHeap<Reverse<(u64, u32)>>,
    closed: bool,
}

/// What one [`PrefetchQueue::pop`] produced.
enum PrefetchPop {
    /// Warm this partition next (lowest outstanding stamp).
    Job(PartitionId),
    /// Timed out empty — caller re-checks liveness and tries again.
    Idle,
    /// Queue closed and drained: the run is over.
    Closed,
}

impl PrefetchQueue {
    fn new() -> PrefetchQueue {
        PrefetchQueue {
            seq: AtomicU64::new(0),
            state: Mutex::new(PrefetchState::default()),
            cv: Condvar::new(),
        }
    }

    /// Draw the next assignment stamp (one per accepted task, in
    /// `TaskAssignBatch` arrival order).
    fn stamp(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Queue `id` for prefetch on behalf of the task stamped `seq`.
    fn push(&self, seq: u64, id: PartitionId) {
        let mut s = crate::util::lock_poisonless(&self.state);
        s.heap.push(Reverse((seq, id.0)));
        self.cv.notify_one();
    }

    /// Pop the lowest-stamped partition, waiting up to `timeout` when
    /// the queue is empty.
    fn pop(&self, timeout: Duration) -> PrefetchPop {
        let mut s = crate::util::lock_poisonless(&self.state);
        loop {
            if let Some(Reverse((_, id))) = s.heap.pop() {
                return PrefetchPop::Job(PartitionId(id));
            }
            if s.closed {
                return PrefetchPop::Closed;
            }
            let (guard, res) = self
                .cv
                .wait_timeout(s, timeout)
                .unwrap_or_else(|e| e.into_inner());
            s = guard;
            if res.timed_out() {
                return match s.heap.pop() {
                    Some(Reverse((_, id))) => {
                        PrefetchPop::Job(PartitionId(id))
                    }
                    None => PrefetchPop::Idle,
                };
            }
        }
    }

    /// End of run: wake every popper; pops drain what is left, then
    /// report [`PrefetchPop::Closed`].
    fn close(&self) {
        let mut s = crate::util::lock_poisonless(&self.state);
        s.closed = true;
        self.cv.notify_all();
    }
}

/// A configured match-service node; [`MatchServiceNode::run`] blocks
/// until the workflow completes.
pub struct MatchServiceNode {
    cfg: MatchNodeConfig,
}

impl MatchServiceNode {
    /// Wrap a config.
    pub fn new(cfg: MatchNodeConfig) -> MatchServiceNode {
        MatchServiceNode { cfg }
    }

    /// Run to workflow completion (see [`run_match_node`]).
    pub fn run(&self, executor: Arc<dyn TaskExecutor>) -> Result<NodeReport> {
        run_match_node(&self.cfg, executor)
    }
}

/// Join the workflow service over `t`, negotiating the protocol
/// version and reporting this node's §3.1 budget (`None` = unlimited;
/// v5 — it sizes the sub-tasks of runtime splitting); returns the
/// granted [`ServiceId`] and the data-plane replica directory.  A
/// coordinator speaking a different [`PROTOCOL_VERSION`] (or
/// rejecting ours) yields a clear error.
pub fn join_workflow(
    t: &mut Transport,
    name: &str,
    mem_budget: Option<u64>,
) -> Result<(ServiceId, Vec<String>)> {
    match t.request(&Message::Join {
        name: name.to_string(),
        version: PROTOCOL_VERSION,
        // on the wire 0 means "unlimited", so a configured budget of
        // 0 (nothing fits) is reported as 1 — the smallest value that
        // still tells the scheduler this node has a budget
        mem_budget: mem_budget.map_or(0, |b| b.max(1)),
    })? {
        Message::JoinAck {
            service,
            version,
            replicas,
        } => {
            if version != PROTOCOL_VERSION {
                bail!(
                    "protocol version mismatch: coordinator speaks \
                     v{version}, this node speaks v{PROTOCOL_VERSION}"
                );
            }
            Ok((service, replicas))
        }
        Message::Error { message } => bail!("join rejected: {message}"),
        other => bail!("join rejected: got {}", other.kind()),
    }
}

#[derive(Default)]
struct WorkerStats {
    busy_ns: u64,
    completed: u64,
    comparisons: u64,
    rejected: u64,
    lost_coordinator: bool,
}

/// Node-wide load counters the workers bump and the heartbeat thread
/// reads, so every protocol-v6 `Heartbeat` carries a live load report
/// (cache hits/misses come straight from the shared
/// [`PartitionCache`]).
#[derive(Default)]
struct NodeLoad {
    busy_ns: AtomicU64,
    tasks_done: AtomicU64,
}

/// Does `mem_bytes` exceed this node's §3.1 budget?
fn oversize(cfg: &MatchNodeConfig, mem_bytes: u64) -> bool {
    cfg.task_memory_budget.is_some_and(|budget| mem_bytes > budget)
}

/// Join, match until done, leave.  See module docs.
pub fn run_match_node(
    cfg: &MatchNodeConfig,
    executor: Arc<dyn TaskExecutor>,
) -> Result<NodeReport> {
    assert!(cfg.threads >= 1, "a match node needs at least one worker");
    let mut control = Transport::connect(
        cfg.workflow_addr.as_str(),
        cfg.io_timeout,
    )
    .with_context(|| {
        format!("connecting to workflow service {}", cfg.workflow_addr)
    })?;
    let (service, directory) = join_workflow(
        &mut control,
        &cfg.name,
        cfg.task_memory_budget,
    )?;

    // configured replicas first (operator preference), then whatever
    // the coordinator's directory adds; the selector deduplicates
    let mut data_addrs = cfg.data_addrs.clone();
    data_addrs.extend(directory);
    let selector = ReplicaSelector::with_cooldown(
        data_addrs,
        cfg.replica_retry_cooldown,
    );
    if selector.is_empty() {
        bail!("no data-plane address configured and none in the directory");
    }

    let cache = PartitionCache::new(cfg.cache_capacity);
    let dead = AtomicBool::new(false); // crash simulation tripped
    let done = AtomicBool::new(false); // workflow finished
    let completed_total = AtomicUsize::new(0);
    let load = NodeLoad::default();
    let clock = system_clock();
    // batch-mode prefetch queue: workers stamp their accepted tasks
    // in assignment order and push the queued tasks' partitions; the
    // prefetcher warms the shared cache lowest-stamp-first, i.e. in
    // execution order, not first-come-first-served
    let prefetch_queue = (cfg.batch > 1 && cfg.cache_capacity > 0)
        .then(PrefetchQueue::new);

    let worker_results: Vec<Result<WorkerStats>> = std::thread::scope(|s| {
        // heartbeat thread: its own connection, stops on done/dead
        // (joined implicitly at scope exit, right after `done` is set)
        let _heartbeat = s.spawn(|| {
            heartbeat_loop(cfg, service, &done, &dead, &cache, &load)
        });

        if let Some(q) = prefetch_queue.as_ref() {
            let pcache = &cache;
            let pselector = &selector;
            let pdead = &dead;
            s.spawn(move || {
                prefetch_loop(cfg, q, pselector, pcache, pdead)
            });
        }

        let handles: Vec<_> = (0..cfg.threads)
            .map(|_| {
                let ctx = WorkerCtx {
                    cfg,
                    service,
                    executor: executor.as_ref(),
                    cache: &cache,
                    selector: &selector,
                    completed_total: &completed_total,
                    dead: &dead,
                    load: &load,
                    clock: clock.as_ref(),
                    tracer: cfg.tracer.as_deref(),
                };
                let q = prefetch_queue.as_ref();
                s.spawn(move || {
                    if ctx.cfg.batch > 1 {
                        worker_loop_batched(ctx, q)
                    } else {
                        worker_loop(ctx)
                    }
                })
            })
            .collect();
        let results = handles
            .into_iter()
            .map(|h| h.join().expect("match worker panicked"))
            .collect();
        if let Some(q) = prefetch_queue.as_ref() {
            q.close();
        }
        done.store(true, Ordering::SeqCst);
        results
    });

    let crashed = dead.load(Ordering::SeqCst);
    if !crashed {
        let _ = control.request(&Message::Leave { service });
    }

    let mut report = NodeReport {
        service: service.0,
        tasks_completed: 0,
        comparisons: 0,
        cache_hits: cache.hits(),
        cache_misses: cache.misses(),
        fetches_per_replica: selector.fetches_per_replica(),
        replica_failovers: selector.failovers(),
        replica_readmissions: selector.readmissions(),
        tasks_rejected: 0,
        busy_ns: Vec::new(),
        crashed,
        lost_coordinator: false,
        cache_evictions: cache.evictions(),
        cache_resident_bytes: cache.resident_bytes(),
    };
    for r in worker_results {
        let stats = r?;
        report.tasks_completed += stats.completed;
        report.comparisons += stats.comparisons;
        report.tasks_rejected += stats.rejected;
        report.busy_ns.push(stats.busy_ns);
        report.lost_coordinator |= stats.lost_coordinator;
    }
    Ok(report)
}

fn heartbeat_loop(
    cfg: &MatchNodeConfig,
    service: ServiceId,
    done: &AtomicBool,
    dead: &AtomicBool,
    cache: &PartitionCache,
    load: &NodeLoad,
) {
    let Ok(mut t) =
        Transport::connect(cfg.workflow_addr.as_str(), cfg.io_timeout)
    else {
        return;
    };
    let step = Duration::from_millis(5).min(cfg.heartbeat_interval);
    'outer: loop {
        if done.load(Ordering::SeqCst) || dead.load(Ordering::SeqCst) {
            break;
        }
        // liveness + a live load report (protocol v6): the coordinator
        // publishes these as per-node gauges for `pem stats`
        let beat = Message::Heartbeat {
            service,
            busy_ns: load.busy_ns.load(Ordering::Relaxed),
            cache_hits: cache.hits(),
            cache_misses: cache.misses(),
            tasks_done: load.tasks_done.load(Ordering::Relaxed),
        };
        match t.request(&beat) {
            // fenced: the coordinator declared this node dead — stop
            // heartbeating for good (the workers hit the same wall and
            // wind the node down)
            Ok(Message::Error { .. }) => break,
            Ok(_) => {}
            // coordinator gone; workers will notice on their own
            Err(_) => break,
        }
        let mut slept = Duration::ZERO;
        while slept < cfg.heartbeat_interval {
            if done.load(Ordering::SeqCst) || dead.load(Ordering::SeqCst) {
                break 'outer;
            }
            std::thread::sleep(step);
            slept += step;
        }
    }
}

/// Everything a worker (or the prefetcher) needs, bundled so the loop
/// signatures stay readable.
#[derive(Clone, Copy)]
struct WorkerCtx<'a> {
    cfg: &'a MatchNodeConfig,
    service: ServiceId,
    executor: &'a dyn TaskExecutor,
    cache: &'a PartitionCache,
    selector: &'a ReplicaSelector,
    completed_total: &'a AtomicUsize,
    dead: &'a AtomicBool,
    load: &'a NodeLoad,
    clock: &'a dyn Clock,
    tracer: Option<&'a Tracer>,
}

impl WorkerCtx<'_> {
    /// Emit a node-side lifecycle event when a tracer is configured.
    fn trace(&self, task: u32, kind: TraceEventKind) {
        if let Some(t) = self.tracer {
            t.record(task, kind, Some(self.service.0 as u64), None);
        }
    }
}

/// Fetch, execute and account one assigned task — the core both
/// worker loops share.  A runtime-split sub-task arrives with a
/// [`TaskSpan`]: the full partitions are fetched (and cached) as
/// usual, then sliced down to the assigned pair-space rectangle —
/// intra-partition matching only when the span is the diagonal
/// triangle.  A fetch failure sets `dead` (we hold an assigned task
/// we can no longer run: the whole node must go down, stop
/// heartbeating, and let the workflow service's failure detector
/// re-queue it, paper §4) and returns the error.
fn execute_task(
    ctx: WorkerCtx<'_>,
    conns: &mut HashMap<usize, Transport>,
    stats: &mut WorkerStats,
    task: &MatchTask,
    span: Option<TaskSpan>,
) -> Result<CompletedTask> {
    let t0 = ctx.clock.now_ns();
    let same_partition = task.left == task.right;
    let fetched = (|| {
        let left =
            fetch(ctx.cfg, conns, ctx.selector, ctx.cache, task.left)?;
        let right = if same_partition {
            left.clone()
        } else {
            fetch(ctx.cfg, conns, ctx.selector, ctx.cache, task.right)?
        };
        Ok::<_, anyhow::Error>((left, right))
    })();
    let (left, right) = match fetched {
        Ok(pair) => pair,
        Err(e) => {
            ctx.dead.store(true, Ordering::SeqCst);
            return Err(e.context(format!(
                "fetch for task {} failed; abandoning node",
                task.id
            )));
        }
    };
    ctx.trace(task.id, TraceEventKind::PartitionsFetched);
    let (left, right, intra) = match span {
        None => (left, right, same_partition),
        Some(s) => {
            let l = Arc::new(
                left.slice(s.left.0 as usize, s.left.1 as usize),
            );
            if same_partition && s.left == s.right {
                // diagonal sub-task: unordered pairs within the range
                (l.clone(), l, true)
            } else {
                // off-diagonal rectangle (two ranges of one partition,
                // or ranges of two): compared as a cross task
                let r = Arc::new(
                    right.slice(s.right.0 as usize, s.right.1 as usize),
                );
                (l, r, false)
            }
        }
    };
    let found = ctx.executor.execute(&left, &right, intra);
    let n_cmp = if span.is_some() {
        // span-sliced counts: the sliced lengths, with the triangle
        // formula only for the diagonal sub-task
        if intra {
            let n = left.len() as u64;
            n * n.saturating_sub(1) / 2
        } else {
            left.len() as u64 * right.len() as u64
        }
    } else {
        task_comparisons(task, left.len(), right.len())
    };
    ctx.trace(task.id, TraceEventKind::Executed);
    let busy = ctx.clock.now_ns().saturating_sub(t0);
    stats.busy_ns += busy;
    stats.completed += 1;
    stats.comparisons += n_cmp;
    ctx.load.busy_ns.fetch_add(busy, Ordering::Relaxed);
    ctx.load.tasks_done.fetch_add(1, Ordering::Relaxed);
    ctx.completed_total.fetch_add(1, Ordering::SeqCst);
    Ok(CompletedTask {
        task_id: task.id,
        comparisons: n_cmp,
        matches: found,
    })
}

/// The crash-simulation hook shared by both worker loops: `true` when
/// this worker must abandon its work and take the node down.
fn simulated_crash_tripped(ctx: WorkerCtx<'_>) -> bool {
    match ctx.cfg.fail_after_tasks {
        Some(limit)
            if ctx.completed_total.load(Ordering::SeqCst) >= limit =>
        {
            ctx.dead.store(true, Ordering::SeqCst);
            true
        }
        _ => false,
    }
}

fn worker_loop(ctx: WorkerCtx<'_>) -> Result<WorkerStats> {
    let cfg = ctx.cfg;
    let service = ctx.service;
    let mut wf =
        Transport::connect(cfg.workflow_addr.as_str(), cfg.io_timeout)?;
    // per-replica data connections, opened lazily on first use
    let mut conns: HashMap<usize, Transport> = HashMap::new();
    let mut stats = WorkerStats::default();
    let mut outgoing = Message::TaskRequest { service };
    loop {
        if ctx.dead.load(Ordering::SeqCst) {
            break; // node-wide simulated crash: drop everything
        }
        let reply = match wf.request(&outgoing) {
            Ok(r) => r,
            Err(_) => {
                // coordinator went away — treat as end of workflow
                stats.lost_coordinator = true;
                break;
            }
        };
        match reply {
            Message::TaskAssign {
                task,
                mem_bytes,
                span,
            } => {
                if simulated_crash_tripped(ctx) {
                    break; // the in-flight task is abandoned, re-queued
                }
                if oversize(cfg, mem_bytes) {
                    // §3.1: the task does not fit this node — hand it
                    // back instead of paging/OOMing; the reply to the
                    // rejection is the next assignment
                    stats.rejected += 1;
                    outgoing = Message::TaskRejected {
                        service,
                        task_id: task.id,
                    };
                    continue;
                }
                let report = execute_task(
                    ctx, &mut conns, &mut stats, &task, span,
                )?;
                outgoing = Message::Complete {
                    service,
                    task_id: report.task_id,
                    comparisons: report.comparisons,
                    cached: ctx.cache.status(),
                    matches: report.matches,
                };
            }
            Message::NoTask { done: true } => break,
            Message::NoTask { done: false } => {
                // tasks in flight elsewhere may be re-queued — poll
                std::thread::sleep(cfg.poll_interval);
                outgoing = Message::TaskRequest { service };
            }
            Message::Error { message } => {
                ctx.dead.store(true, Ordering::SeqCst);
                bail!("workflow service error: {message}")
            }
            other => {
                ctx.dead.store(true, Ordering::SeqCst);
                bail!("unexpected {} from workflow service", other.kind())
            }
        }
    }
    Ok(stats)
}

/// The protocol-v3 worker: pull up to `cfg.batch` tasks per round
/// trip, report the whole previous batch's completions on the same
/// frame, and feed the prefetcher the queued tasks' partitions —
/// stamped in assignment order so the queue warms them in execution
/// order — while the current task executes.
fn worker_loop_batched(
    ctx: WorkerCtx<'_>,
    prefetch: Option<&PrefetchQueue>,
) -> Result<WorkerStats> {
    let cfg = ctx.cfg;
    let service = ctx.service;
    let mut wf =
        Transport::connect(cfg.workflow_addr.as_str(), cfg.io_timeout)?;
    let mut conns: HashMap<usize, Transport> = HashMap::new();
    let mut stats = WorkerStats::default();
    let mut queue: VecDeque<(MatchTask, Option<TaskSpan>)> =
        VecDeque::new();
    let mut completed: Vec<CompletedTask> = Vec::new();
    let max = cfg.batch.max(1) as u32;
    loop {
        if ctx.dead.load(Ordering::SeqCst) {
            break; // node-wide simulated crash: drop everything
        }
        if queue.is_empty() {
            // one round trip: report everything finished, pull the
            // next batch
            let request = Message::TaskRequestBatch {
                service,
                max,
                cached: ctx.cache.status(),
                completed: std::mem::take(&mut completed),
            };
            let reply = match wf.request(&request) {
                Ok(r) => r,
                Err(_) => {
                    // coordinator went away — treat as end of workflow
                    stats.lost_coordinator = true;
                    break;
                }
            };
            match reply {
                Message::TaskAssignBatch { done, tasks } => {
                    // §3.1 budget check per assignment; oversize ones
                    // are handed back one frame each, and the replies
                    // may carry replacement assignments (checked too)
                    let mut accepted: Vec<(MatchTask, Option<TaskSpan>)> =
                        Vec::with_capacity(tasks.len());
                    let mut rejections: VecDeque<u32> = VecDeque::new();
                    for a in tasks {
                        if oversize(cfg, a.mem_bytes) {
                            stats.rejected += 1;
                            rejections.push_back(a.task.id);
                        } else {
                            accepted.push((a.task, a.span));
                        }
                    }
                    let mut lost = false;
                    while let Some(task_id) = rejections.pop_front() {
                        let reply = match wf.request(
                            &Message::TaskRejected { service, task_id },
                        ) {
                            Ok(r) => r,
                            Err(_) => {
                                lost = true;
                                break;
                            }
                        };
                        match reply {
                            Message::TaskAssign {
                                task,
                                mem_bytes,
                                span,
                            } => {
                                if oversize(cfg, mem_bytes) {
                                    stats.rejected += 1;
                                    rejections.push_back(task.id);
                                } else {
                                    accepted.push((task, span));
                                }
                            }
                            Message::NoTask { .. } => {}
                            Message::Error { message } => {
                                ctx.dead.store(true, Ordering::SeqCst);
                                bail!(
                                    "workflow service error: {message}"
                                )
                            }
                            other => {
                                ctx.dead.store(true, Ordering::SeqCst);
                                bail!(
                                    "unexpected {} from workflow \
                                     service",
                                    other.kind()
                                )
                            }
                        }
                    }
                    if lost {
                        // coordinator went away — end of workflow
                        stats.lost_coordinator = true;
                        break;
                    }
                    if accepted.is_empty() {
                        if done {
                            break;
                        }
                        // tasks in flight elsewhere may be re-queued
                        std::thread::sleep(cfg.poll_interval);
                        continue;
                    }
                    // stamp the accepted tasks in assignment order
                    // (node-wide sequence) and queue the partitions of
                    // everything beyond the first for prefetch: the
                    // first task is fetched inline immediately, the
                    // rest get warmed soonest-needed-first
                    if let Some(q) = prefetch {
                        for (i, (t, _)) in accepted.iter().enumerate() {
                            let seq = q.stamp();
                            if i == 0 {
                                continue;
                            }
                            for p in t.needed_partitions() {
                                q.push(seq, p);
                            }
                        }
                    }
                    queue.extend(accepted);
                }
                Message::Error { message } => {
                    ctx.dead.store(true, Ordering::SeqCst);
                    bail!("workflow service error: {message}")
                }
                other => {
                    ctx.dead.store(true, Ordering::SeqCst);
                    bail!(
                        "unexpected {} from workflow service",
                        other.kind()
                    )
                }
            }
            continue;
        }
        let (task, span) =
            queue.pop_front().expect("queue checked non-empty");
        if simulated_crash_tripped(ctx) {
            // the whole queued batch and the unsent completion reports
            // are abandoned; the failure detector re-queues every one
            break;
        }
        let report =
            execute_task(ctx, &mut conns, &mut stats, &task, span)?;
        completed.push(report);
    }
    Ok(stats)
}

/// Node-wide prefetcher (batch mode with a cache): pops the queued
/// tasks' partitions in assignment order — the [`PrefetchQueue`]
/// always yields the one needed soonest — and pulls the missing ones
/// into the shared cache over its own data-plane connections, so a
/// worker's next task usually starts with both partitions warm.
/// Failures are left for the workers' full fetch logic (failover,
/// node teardown) — the prefetcher never kills anything, it only
/// warms.
fn prefetch_loop(
    cfg: &MatchNodeConfig,
    jobs: &PrefetchQueue,
    selector: &ReplicaSelector,
    cache: &PartitionCache,
    dead: &AtomicBool,
) {
    let mut conns: HashMap<usize, Transport> = HashMap::new();
    loop {
        let id = match jobs.pop(Duration::from_millis(50)) {
            PrefetchPop::Job(id) => id,
            PrefetchPop::Idle => {
                if dead.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            PrefetchPop::Closed => return,
        };
        if dead.load(Ordering::SeqCst) {
            return;
        }
        if cache.contains(id) {
            continue; // already warm (contains() skips hit accounting)
        }
        let Some(idx) = selector.select(id) else {
            return; // every replica dead — nothing left to warm from
        };
        selector.begin_fetch(idx);
        let outcome = fetch_once(cfg, &mut conns, selector, idx, id);
        selector.finish_fetch(idx);
        match outcome {
            Ok(FetchReply::Data(data)) => {
                selector.record_locality(id, idx);
                cache.put(id, data);
            }
            // redirects/denials/conn errors: drop the connection and
            // leave the partition for the worker's fetch path
            Ok(_) => {}
            Err(_) => {
                conns.remove(&idx);
            }
        }
    }
}

/// What one fetch attempt produced at the protocol level.
enum FetchReply {
    /// The partition payload.
    Data(Arc<PartitionData>),
    /// Replica does not hold the partition — retry at this address.
    Redirect(String),
    /// Hard protocol-level refusal (e.g. unknown partition).
    Denied(String),
}

fn classify(reply: Message) -> FetchReply {
    match reply {
        Message::Partition { data } => FetchReply::Data(Arc::new(data)),
        Message::Redirect { addr } => FetchReply::Redirect(addr),
        Message::Error { message } => FetchReply::Denied(message),
        other => FetchReply::Denied(format!(
            "unexpected {} from data service",
            other.kind()
        )),
    }
}

/// One wire fetch against replica `idx`, reusing (or lazily opening)
/// its connection.  `Err` means connection-level failure.
fn fetch_once(
    cfg: &MatchNodeConfig,
    conns: &mut HashMap<usize, Transport>,
    selector: &ReplicaSelector,
    idx: usize,
    id: PartitionId,
) -> io::Result<FetchReply> {
    if !conns.contains_key(&idx) {
        let t = Transport::connect(selector.addr(idx), cfg.io_timeout)?;
        conns.insert(idx, t);
    }
    let t = conns.get_mut(&idx).expect("just inserted");
    Ok(classify(t.request(&Message::FetchPartition { id })?))
}

/// Follow one redirect to `addr`.  `Ok(None)` means the redirect
/// target failed at the connection level (marked dead when it is a
/// known replica) — the caller re-selects.  `Err` is a protocol-level
/// failure (node-fatal, as before).
fn fetch_redirected(
    cfg: &MatchNodeConfig,
    conns: &mut HashMap<usize, Transport>,
    selector: &ReplicaSelector,
    addr: &str,
    id: PartitionId,
) -> Result<Option<Arc<PartitionData>>> {
    let known = selector.index_of(addr);
    let outcome = match known {
        Some(j) => {
            selector.begin_fetch(j);
            let r = fetch_once(cfg, conns, selector, j, id);
            selector.finish_fetch(j);
            r
        }
        None => Transport::connect(addr, cfg.io_timeout)
            .and_then(|mut t| t.request(&Message::FetchPartition { id }))
            .map(classify),
    };
    match outcome {
        Ok(FetchReply::Data(d)) => {
            if let Some(j) = known {
                selector.record_locality(id, j);
            }
            Ok(Some(d))
        }
        Ok(FetchReply::Redirect(_)) => {
            // a redirect must resolve in one hop; a chain means the
            // data plane is misconfigured (e.g. replicas pointing at
            // each other before either synced)
            bail!("redirect loop while fetching partition {id}")
        }
        Ok(FetchReply::Denied(msg)) => bail!("data service error: {msg}"),
        Err(_) => {
            if let Some(j) = known {
                conns.remove(&j);
                selector.mark_dead(j);
            }
            Ok(None)
        }
    }
}

/// Fetch a partition through the node cache, falling back to a wire
/// fetch from a data-plane replica (a cache miss, as in the paper).
/// Replica choice and failover are the [`ReplicaSelector`]'s; every
/// iteration either returns or marks a replica dead, so the loop
/// terminates once all replicas are gone.
fn fetch(
    cfg: &MatchNodeConfig,
    conns: &mut HashMap<usize, Transport>,
    selector: &ReplicaSelector,
    cache: &PartitionCache,
    id: PartitionId,
) -> Result<Arc<PartitionData>> {
    if let Some(d) = cache.get(id) {
        return Ok(d);
    }
    loop {
        let Some(idx) = selector.select(id) else {
            bail!("no live data replica left for partition {id}");
        };
        selector.begin_fetch(idx);
        let outcome = fetch_once(cfg, conns, selector, idx, id);
        selector.finish_fetch(idx);
        match outcome {
            Ok(FetchReply::Data(d)) => {
                selector.record_locality(id, idx);
                cache.put(id, d.clone());
                return Ok(d);
            }
            Ok(FetchReply::Redirect(addr)) => {
                match fetch_redirected(cfg, conns, selector, &addr, id)? {
                    Some(d) => {
                        cache.put(id, d.clone());
                        return Ok(d);
                    }
                    None => {
                        // the replica cannot serve this partition and
                        // its upstream is unreachable: useless here —
                        // fail over past it
                        conns.remove(&idx);
                        selector.mark_dead(idx);
                    }
                }
            }
            Ok(FetchReply::Denied(msg)) => {
                bail!("data service error: {msg}")
            }
            Err(_) => {
                // connection-level failure: next replica
                conns.remove(&idx);
                selector.mark_dead(idx);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::matching::{MatchStrategy, StrategyKind};
    use crate::model::EntityId;
    use crate::partition::{generate_tasks, partition_size_based};
    use crate::service::{
        DataServiceServer, WorkflowServerConfig, WorkflowServiceServer,
    };
    use crate::store::DataService;
    use crate::worker::RustExecutor;

    /// The deadline-aware queue pops in assignment-stamp order no
    /// matter the push order, drains before reporting idle/closed,
    /// and `close` wakes blocked poppers.
    #[test]
    fn prefetch_queue_pops_in_assignment_order() {
        let q = PrefetchQueue::new();
        let s0 = q.stamp();
        let s1 = q.stamp();
        let s2 = q.stamp();
        // pushed out of order (two workers racing)
        q.push(s2, PartitionId(30));
        q.push(s0, PartitionId(10));
        q.push(s1, PartitionId(20));
        let t = Duration::from_millis(10);
        assert!(matches!(q.pop(t), PrefetchPop::Job(PartitionId(10))));
        assert!(matches!(q.pop(t), PrefetchPop::Job(PartitionId(20))));
        assert!(matches!(q.pop(t), PrefetchPop::Job(PartitionId(30))));
        assert!(matches!(q.pop(t), PrefetchPop::Idle));
        // close still drains what is left before reporting Closed
        q.push(q.stamp(), PartitionId(40));
        q.close();
        assert!(matches!(q.pop(t), PrefetchPop::Job(PartitionId(40))));
        assert!(matches!(q.pop(t), PrefetchPop::Closed));
    }

    #[test]
    fn prefetch_queue_close_wakes_blocked_popper() {
        let q = Arc::new(PrefetchQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            q2.pop(Duration::from_secs(30))
        });
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        assert!(matches!(h.join().unwrap(), PrefetchPop::Closed));
    }

    #[test]
    fn single_node_completes_a_small_workflow_over_tcp() {
        let data = GeneratorConfig::tiny().with_entities(120).generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, 40);
        let tasks = generate_tasks(&parts);
        let n_tasks = tasks.len();
        let store =
            Arc::new(DataService::build(&data.dataset, &parts));

        let data_srv =
            DataServiceServer::start(store, "127.0.0.1:0").unwrap();
        let wf_srv = WorkflowServiceServer::start(
            tasks,
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();

        let mut cfg = MatchNodeConfig::new(
            wf_srv.addr().to_string(),
            data_srv.addr().to_string(),
        );
        cfg.threads = 2;
        cfg.cache_capacity = 4;
        let exec: Arc<dyn TaskExecutor> = Arc::new(RustExecutor::new(
            MatchStrategy::new(StrategyKind::Wam),
        ));
        let report = run_match_node(&cfg, exec).unwrap();

        assert_eq!(report.tasks_completed as usize, n_tasks);
        assert!(!report.crashed);
        assert!(report.cache_misses > 0);
        assert_eq!(report.busy_ns.len(), 2);
        assert_eq!(report.fetches_per_replica.len(), 1);
        assert!(report.fetches_per_replica[0] > 0);
        assert_eq!(report.replica_failovers, 0);
        assert!(wf_srv.wait_done(Duration::from_secs(1)));
        let wf_report = wf_srv.finish();
        assert_eq!(wf_report.completed_tasks, n_tasks);
        assert_eq!(wf_report.comparisons, 120 * 119 / 2);
        assert!(data_srv.wire_bytes() > 0);
        data_srv.shutdown();
    }

    /// Batch mode end to end on one node: the workflow completes with
    /// the same totals as the classic flow, while the control plane
    /// sees one batch request per ~`batch` tasks instead of one
    /// `Complete` per task.
    #[test]
    fn batched_node_completes_workflow_with_fewer_round_trips() {
        let data = GeneratorConfig::tiny().with_entities(240).generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, 40);
        let tasks = generate_tasks(&parts);
        let n_tasks = tasks.len();
        assert!(n_tasks >= 20, "need enough tasks for the comparison");
        let store =
            Arc::new(DataService::build(&data.dataset, &parts));

        let data_srv =
            DataServiceServer::start(store, "127.0.0.1:0").unwrap();
        let wf_srv = WorkflowServiceServer::start(
            tasks,
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();

        let mut cfg = MatchNodeConfig::new(
            wf_srv.addr().to_string(),
            data_srv.addr().to_string(),
        );
        cfg.threads = 2;
        cfg.cache_capacity = 4;
        cfg.batch = 4;
        // a sluggish drain poll keeps the pull count comparison honest
        cfg.poll_interval = Duration::from_millis(25);
        let exec: Arc<dyn TaskExecutor> = Arc::new(RustExecutor::new(
            MatchStrategy::new(StrategyKind::Wam),
        ));
        let report = run_match_node(&cfg, exec).unwrap();

        assert_eq!(report.tasks_completed as usize, n_tasks);
        assert!(!report.crashed);
        assert!(wf_srv.wait_done(Duration::from_secs(1)));
        let wf_report = wf_srv.finish();
        assert_eq!(wf_report.completed_tasks, n_tasks);
        assert_eq!(wf_report.comparisons, 240 * 239 / 2);
        assert!(wf_report.batch_requests > 0, "batched path used");
        assert!(
            wf_report.batch_requests < n_tasks as u64,
            "fewer pulls ({}) than tasks ({n_tasks})",
            wf_report.batch_requests
        );
        assert_eq!(wf_report.stale_completions, 0);
        data_srv.shutdown();
    }

    /// §3.1 memory-model parity end to end: a node whose budget no
    /// task fits rejects every assignment with `TaskRejected`, the
    /// coordinator re-queues them marked oversize, and a second node
    /// with enough memory completes the whole workflow — no task is
    /// lost, none executes on the small node.
    #[test]
    fn small_budget_node_rejects_tasks_and_big_node_completes() {
        let data = GeneratorConfig::tiny().with_entities(120).generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, 40);
        let tasks = generate_tasks(&parts);
        let n_tasks = tasks.len();
        let task_mem: std::collections::HashMap<u32, u64> =
            tasks.iter().map(|t| (t.id, 1_000u64)).collect();
        let store = Arc::new(DataService::build(&data.dataset, &parts));
        let data_srv =
            DataServiceServer::start(store, "127.0.0.1:0").unwrap();
        let wf_srv = WorkflowServiceServer::start(
            tasks,
            WorkflowServerConfig {
                task_mem,
                ..WorkflowServerConfig::default()
            },
            "127.0.0.1:0",
        )
        .unwrap();
        let exec: Arc<dyn TaskExecutor> = Arc::new(RustExecutor::new(
            MatchStrategy::new(StrategyKind::Wam),
        ));

        // the small node starts alone, so it is assigned (and
        // rejects) every open task before the big node exists
        let mut small = MatchNodeConfig::new(
            wf_srv.addr().to_string(),
            data_srv.addr().to_string(),
        );
        small.name = "small".into();
        small.task_memory_budget = Some(500); // every task is 1,000 B
        let small_exec = exec.clone();
        let small_handle = std::thread::spawn(move || {
            run_match_node(&small, small_exec)
        });
        std::thread::sleep(Duration::from_millis(150));

        let mut big = MatchNodeConfig::new(
            wf_srv.addr().to_string(),
            data_srv.addr().to_string(),
        );
        big.name = "big".into();
        big.cache_capacity = 4;
        let report_big = run_match_node(&big, exec).unwrap();
        let report_small = small_handle.join().unwrap().unwrap();

        assert_eq!(report_small.tasks_completed, 0, "nothing fits");
        assert!(report_small.tasks_rejected >= 1);
        assert!(!report_small.crashed);
        assert_eq!(report_big.tasks_completed as usize, n_tasks);
        assert_eq!(report_big.tasks_rejected, 0);
        assert!(wf_srv.wait_done(Duration::from_secs(1)));
        let wf_report = wf_srv.finish();
        assert_eq!(wf_report.completed_tasks, n_tasks);
        assert_eq!(
            wf_report.oversize_rejections,
            report_small.tasks_rejected
        );
        assert_eq!(wf_report.comparisons, 120 * 119 / 2, "nothing lost");
        data_srv.shutdown();
    }

    /// A node whose preferred data replica is unreachable fails over
    /// to the next one and still completes the workflow.
    #[test]
    fn node_fails_over_past_a_dead_replica() {
        let data = GeneratorConfig::tiny().with_entities(90).generate();
        let ids: Vec<EntityId> =
            data.dataset.entities.iter().map(|e| e.id).collect();
        let parts = partition_size_based(&ids, 30);
        let tasks = generate_tasks(&parts);
        let n_tasks = tasks.len();
        let store = Arc::new(DataService::build(&data.dataset, &parts));

        // an address nothing listens on: bind an ephemeral port, note
        // it, and close the listener again
        let dead_addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let data_srv =
            DataServiceServer::start(store, "127.0.0.1:0").unwrap();
        let wf_srv = WorkflowServiceServer::start(
            tasks,
            WorkflowServerConfig::default(),
            "127.0.0.1:0",
        )
        .unwrap();

        let mut cfg =
            MatchNodeConfig::new(wf_srv.addr().to_string(), dead_addr);
        cfg.data_addrs.push(data_srv.addr().to_string());
        cfg.cache_capacity = 2;
        let exec: Arc<dyn TaskExecutor> = Arc::new(RustExecutor::new(
            MatchStrategy::new(StrategyKind::Wam),
        ));
        let report = run_match_node(&cfg, exec).unwrap();
        assert_eq!(report.tasks_completed as usize, n_tasks);
        assert!(!report.crashed);
        assert_eq!(report.replica_failovers, 1, "dead replica abandoned");
        assert_eq!(report.fetches_per_replica.len(), 2);
        assert!(
            report.fetches_per_replica[1] > 0,
            "all real traffic on the live replica"
        );
        assert!(wf_srv.wait_done(Duration::from_secs(1)));
        let _ = wf_srv.finish();
        data_srv.shutdown();
    }
}
