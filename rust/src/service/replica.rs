//! Replica selection for the data plane, plus the announcement client.
//!
//! A match node knows several data-service replicas (from its own
//! configuration and from the directory delivered in `JoinAck`).  Each
//! fetch picks one replica by, in order:
//!
//! 1. **cached locality** — the replica that last served this partition
//!    (its encoded-frame cache and the OS page cache are warm there);
//! 2. **least outstanding fetches** — among live replicas, the one with
//!    the fewest in-flight fetches right now;
//! 3. **least total fetches** — tie-break that spreads first-time
//!    fetches round-robin across replicas instead of hammering the
//!    first one;
//! 4. lowest index (deterministic final tie-break).
//!
//! A replica that fails at the connection level is marked dead and its
//! locality entries are dropped; selection then **fails over** to the
//! next live replica.  A dead replica is not banned forever: after
//! [`ReplicaSelector::cooldown`] it is **re-admitted** and selection
//! may try it again — a replica that was only restarting (or dropped a
//! single connection under load) rejoins the rotation instead of
//! leaving the node one failure away from abandoning its run.  If it
//! fails again it is written off for another cooldown.  Selection
//! returns `None` only when every replica is dead *and within its
//! cooldown* — the caller treats that like the old single-data-server
//! fetch failure (abandon the node, let the workflow service re-queue).

use crate::obs::{system_clock, Clock};
use crate::partition::PartitionId;
use crate::rpc::{Message, Transport, PROTOCOL_VERSION};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Default re-admission cooldown for a written-off replica.
pub const DEFAULT_RETRY_COOLDOWN: Duration = Duration::from_secs(3);

struct ReplicaState {
    addr: String,
    alive: AtomicBool,
    /// [`Clock`] timestamp (ns) when the replica was written off
    /// (`None` while alive); guards the re-admission clock.
    dead_since: Mutex<Option<u64>>,
    /// Fetches in flight right now (across this node's workers).
    outstanding: AtomicUsize,
    /// Fetches ever started against this replica.
    fetches: AtomicU64,
}

/// Picks which data-plane replica serves each partition fetch; shared
/// by all workers of one match node.  See the module docs for the
/// selection policy and the re-admission cooldown.
pub struct ReplicaSelector {
    replicas: Vec<ReplicaState>,
    /// partition → replica index that last served it successfully.
    locality: Mutex<HashMap<PartitionId, usize>>,
    failovers: AtomicU64,
    readmissions: AtomicU64,
    /// How long a dead replica stays excluded before selection tries
    /// it again.
    cooldown: Duration,
    /// The monotonic clock driving the cooldown — injectable, so tests
    /// advance it deterministically ([`crate::obs::ManualClock`]).
    clock: Arc<dyn Clock>,
}

impl ReplicaSelector {
    /// Build a selector over `addrs` with the default re-admission
    /// cooldown (duplicates removed, order kept — exact string
    /// comparison, so `"localhost:1"` and `"127.0.0.1:1"` count as
    /// distinct replicas).
    pub fn new(addrs: Vec<String>) -> ReplicaSelector {
        ReplicaSelector::with_cooldown(addrs, DEFAULT_RETRY_COOLDOWN)
    }

    /// Build a selector with an explicit re-admission cooldown, timed
    /// by the system [`Clock`].
    pub fn with_cooldown(
        addrs: Vec<String>,
        cooldown: Duration,
    ) -> ReplicaSelector {
        ReplicaSelector::with_clock(addrs, cooldown, system_clock())
    }

    /// Build a selector with an explicit cooldown **and** clock — the
    /// injection point that lets tests drive re-admission through a
    /// [`crate::obs::ManualClock`] instead of sleeping.
    pub fn with_clock(
        addrs: Vec<String>,
        cooldown: Duration,
        clock: Arc<dyn Clock>,
    ) -> ReplicaSelector {
        let mut seen: Vec<String> = Vec::new();
        for a in addrs {
            if !seen.contains(&a) {
                seen.push(a);
            }
        }
        ReplicaSelector {
            replicas: seen
                .into_iter()
                .map(|addr| ReplicaState {
                    addr,
                    alive: AtomicBool::new(true),
                    dead_since: Mutex::new(None),
                    outstanding: AtomicUsize::new(0),
                    fetches: AtomicU64::new(0),
                })
                .collect(),
            locality: Mutex::new(HashMap::new()),
            failovers: AtomicU64::new(0),
            readmissions: AtomicU64::new(0),
            cooldown,
            clock,
        }
    }

    /// Number of known replicas (live or dead).
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    /// `true` when no replicas are configured at all.
    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Number of replicas not (yet) marked dead.
    pub fn live_count(&self) -> usize {
        self.replicas
            .iter()
            .filter(|r| r.alive.load(Ordering::SeqCst))
            .count()
    }

    /// The configured re-admission cooldown.
    pub fn cooldown(&self) -> Duration {
        self.cooldown
    }

    /// Address of replica `idx`.
    pub fn addr(&self, idx: usize) -> &str {
        &self.replicas[idx].addr
    }

    /// Index of the replica with this exact address, if known.
    pub fn index_of(&self, addr: &str) -> Option<usize> {
        self.replicas.iter().position(|r| r.addr == addr)
    }

    /// Choose a replica for fetching `id`; `None` when all are dead
    /// and still cooling down.
    pub fn select(&self, id: PartitionId) -> Option<usize> {
        let now = self.clock.now_ns();
        self.readmit_due(now);
        if let Some(&i) = crate::util::lock_poisonless(&self.locality).get(&id) {
            if self.replicas[i].alive.load(Ordering::SeqCst) {
                return Some(i);
            }
        }
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.alive.load(Ordering::SeqCst))
            .min_by_key(|(i, r)| {
                (
                    r.outstanding.load(Ordering::SeqCst),
                    r.fetches.load(Ordering::SeqCst),
                    *i,
                )
            })
            .map(|(i, _)| i)
    }

    /// Re-admit every dead replica whose cooldown has elapsed at
    /// `now` (a [`Clock`] timestamp, ns).
    fn readmit_due(&self, now: u64) {
        let cooldown_ns = self.cooldown.as_nanos() as u64;
        for r in &self.replicas {
            if r.alive.load(Ordering::SeqCst) {
                continue;
            }
            let mut dead_since = crate::util::lock_poisonless(&r.dead_since);
            let due = matches!(
                *dead_since,
                Some(at) if now.saturating_sub(at) >= cooldown_ns
            );
            if due {
                *dead_since = None;
                r.alive.store(true, Ordering::SeqCst);
                self.readmissions.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    /// Mark a fetch against `idx` as started (pair with
    /// [`ReplicaSelector::finish_fetch`]).
    pub fn begin_fetch(&self, idx: usize) {
        self.replicas[idx].outstanding.fetch_add(1, Ordering::SeqCst);
        self.replicas[idx].fetches.fetch_add(1, Ordering::SeqCst);
    }

    /// Mark a fetch against `idx` as finished (success or failure).
    pub fn finish_fetch(&self, idx: usize) {
        self.replicas[idx].outstanding.fetch_sub(1, Ordering::SeqCst);
    }

    /// Record that `idx` served `id` — future fetches of `id` prefer it.
    pub fn record_locality(&self, id: PartitionId, idx: usize) {
        crate::util::lock_poisonless(&self.locality).insert(id, idx);
    }

    /// Connection-level failure of `idx`: stop selecting it until the
    /// cooldown elapses and forget its locality entries.  Counts one
    /// failover.
    pub fn mark_dead(&self, idx: usize) {
        if self.replicas[idx].alive.swap(false, Ordering::SeqCst) {
            self.failovers.fetch_add(1, Ordering::SeqCst);
        }
        // (re-)start the cooldown clock even when already dead, so a
        // failure during re-probing pushes the next retry out again
        *crate::util::lock_poisonless(&self.replicas[idx].dead_since) =
            Some(self.clock.now_ns());
        crate::util::lock_poisonless(&self.locality)
            .retain(|_, v| *v != idx);
    }

    /// Fetches ever started, per replica (configuration order).
    pub fn fetches_per_replica(&self) -> Vec<u64> {
        self.replicas
            .iter()
            .map(|r| r.fetches.load(Ordering::SeqCst))
            .collect()
    }

    /// Replicas marked dead so far.
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::SeqCst)
    }

    /// Written-off replicas re-admitted after their cooldown.
    pub fn readmissions(&self) -> u64 {
        self.readmissions.load(Ordering::SeqCst)
    }
}

/// Announce a data-service replica at `data_addr` (holding
/// `partitions`) to the workflow service at `workflow_addr`; returns
/// the directory after the announcement.  Used by the dist engine, by
/// `pem serve` (for its primary) and by `pem serve --role data`.
pub fn announce_replica(
    workflow_addr: &str,
    data_addr: &str,
    partitions: &[PartitionId],
    timeout: Duration,
) -> Result<Vec<String>> {
    let mut t = Transport::connect(workflow_addr, timeout)?;
    match t.request(&Message::ReplicaAnnounce {
        addr: data_addr.to_string(),
        version: PROTOCOL_VERSION,
        partitions: partitions.to_vec(),
    })? {
        Message::ReplicaDirectory { replicas } => Ok(replicas),
        Message::Error { message } => {
            bail!("replica announcement rejected: {message}")
        }
        other => bail!(
            "unexpected {} in reply to ReplicaAnnounce",
            other.kind()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ManualClock;

    fn selector(n: usize) -> ReplicaSelector {
        ReplicaSelector::new(
            (0..n).map(|i| format!("10.0.0.{i}:7402")).collect(),
        )
    }

    #[test]
    fn dedups_addresses_preserving_order() {
        let s = ReplicaSelector::new(vec![
            "a:1".into(),
            "b:2".into(),
            "a:1".into(),
        ]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.addr(0), "a:1");
        assert_eq!(s.addr(1), "b:2");
        assert_eq!(s.index_of("b:2"), Some(1));
        assert_eq!(s.index_of("c:3"), None);
        assert_eq!(s.cooldown(), DEFAULT_RETRY_COOLDOWN);
    }

    #[test]
    fn spreads_first_fetches_then_sticks_by_locality() {
        let s = selector(2);
        // first fetch: both idle with zero fetches → index 0
        let a = s.select(PartitionId(10)).unwrap();
        assert_eq!(a, 0);
        s.begin_fetch(a);
        s.finish_fetch(a);
        s.record_locality(PartitionId(10), a);
        // a different partition now prefers the less-used replica 1
        let b = s.select(PartitionId(11)).unwrap();
        assert_eq!(b, 1);
        s.begin_fetch(b);
        s.finish_fetch(b);
        s.record_locality(PartitionId(11), b);
        // repeat fetches stick to whoever served the partition before,
        // regardless of load counters
        s.begin_fetch(1);
        assert_eq!(s.select(PartitionId(10)).unwrap(), 0);
        assert_eq!(s.select(PartitionId(11)).unwrap(), 1);
        s.finish_fetch(1);
    }

    #[test]
    fn least_outstanding_wins_while_fetches_are_in_flight() {
        let s = selector(3);
        s.begin_fetch(0); // replica 0 busy
        s.begin_fetch(1); // replica 1 busy
        assert_eq!(s.select(PartitionId(5)).unwrap(), 2);
        s.finish_fetch(0);
        s.finish_fetch(1);
    }

    #[test]
    fn failover_skips_dead_replicas_and_drops_their_locality() {
        let s = selector(2);
        s.record_locality(PartitionId(7), 0);
        assert_eq!(s.select(PartitionId(7)).unwrap(), 0);
        s.mark_dead(0);
        assert_eq!(s.live_count(), 1);
        assert_eq!(s.failovers(), 1);
        // locality to the dead replica no longer pins the partition
        assert_eq!(s.select(PartitionId(7)).unwrap(), 1);
        // marking dead twice does not double-count
        s.mark_dead(0);
        assert_eq!(s.failovers(), 1);
        s.mark_dead(1);
        assert_eq!(s.select(PartitionId(7)), None, "all replicas dead");
    }

    /// The ROADMAP follow-up: a written-off replica is retried after
    /// the cooldown instead of being banned for the rest of the run.
    /// Driven through a [`ManualClock`] so the test is deterministic —
    /// no sleeping, time advances only when told to.
    #[test]
    fn dead_replica_readmitted_after_cooldown() {
        let cd = Duration::from_secs(5);
        let cd_ns = cd.as_nanos() as u64;
        let clock = Arc::new(ManualClock::new(0));
        let s = ReplicaSelector::with_clock(
            vec!["a:1".into(), "b:2".into()],
            cd,
            clock.clone(),
        );
        s.mark_dead(0);
        assert_eq!(s.live_count(), 1);
        // within the cooldown the dead replica stays excluded
        clock.set(cd_ns - 1);
        assert_eq!(s.select(PartitionId(1)), Some(1));
        assert_eq!(s.live_count(), 1);
        assert_eq!(s.readmissions(), 0);
        // at the cooldown boundary it rejoins the rotation
        clock.set(cd_ns);
        assert_eq!(s.select(PartitionId(1)), Some(0));
        assert_eq!(s.live_count(), 2);
        assert_eq!(s.readmissions(), 1);
        // a second failure re-starts the clock (and counts a failover)
        s.mark_dead(0);
        assert_eq!(s.failovers(), 2);
        clock.set(cd_ns + cd_ns / 2);
        assert_eq!(s.select(PartitionId(1)), Some(1), "cooling down again");
        clock.set(cd_ns + cd_ns);
        assert_eq!(s.select(PartitionId(1)), Some(0));
        assert_eq!(s.readmissions(), 2);
    }

    /// With every replica dead and cooling down, selection yields
    /// `None` (the caller abandons); once the cooldown passes it
    /// recovers instead of staying dead forever.
    #[test]
    fn all_dead_recovers_after_cooldown() {
        let cd = Duration::from_secs(2);
        let clock = Arc::new(ManualClock::new(0));
        let s = ReplicaSelector::with_clock(
            vec!["a:1".into()],
            cd,
            clock.clone(),
        );
        s.mark_dead(0);
        assert_eq!(s.select(PartitionId(0)), None);
        clock.set(cd.as_nanos() as u64);
        assert_eq!(
            s.select(PartitionId(0)),
            Some(0),
            "sole replica retried after cooldown"
        );
    }

    /// A failure while re-probing pushes the next retry out: the
    /// cooldown clock restarts from the newest failure.
    #[test]
    fn reprobe_failure_restarts_cooldown_clock() {
        let cd = Duration::from_secs(4);
        let cd_ns = cd.as_nanos() as u64;
        let clock = Arc::new(ManualClock::new(0));
        let s = ReplicaSelector::with_clock(
            vec!["a:1".into(), "b:2".into()],
            cd,
            clock.clone(),
        );
        s.mark_dead(0);
        // a later failure report (e.g. the re-probe also failed)
        let second_failure = Duration::from_secs(3).as_nanos() as u64;
        clock.set(second_failure);
        s.mark_dead(0);
        // the original cooldown expiry no longer re-admits it
        clock.set(cd_ns);
        assert_eq!(s.select(PartitionId(9)), Some(1));
        assert_eq!(s.live_count(), 1);
        // only the restarted clock does
        clock.set(second_failure + cd_ns);
        assert_eq!(s.select(PartitionId(9)), Some(0));
    }
}
