//! Run metrics: what every experiment reports.
//!
//! The engines fill a [`RunMetrics`] per workflow execution; the bench
//! harness and the `pem` CLI render them as the paper's tables (execution
//! time, speedup, #tasks, cache hit ratio `hr`, Δ, Δ/t_nc).

use crate::obs::{MetricsSnapshot, Registry};
use crate::util::{fmt_bytes, fmt_nanos};

/// Metrics of one parallel match run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Virtual (simulator) or wall-clock (thread engine) makespan, ns.
    pub makespan_ns: u64,
    /// Executed match tasks.
    pub tasks: usize,
    /// Entity-pair comparisons performed.
    pub comparisons: u64,
    /// Correspondences above threshold.
    pub matches: usize,
    /// Partition accesses served from a match-service cache.
    pub cache_hits: u64,
    /// Partition accesses that had to hit the data service.
    pub cache_misses: u64,
    /// Bytes shipped from the data service to match services.
    pub bytes_fetched: u64,
    /// Control messages (assignment + completion), for overhead reports.
    pub control_messages: u64,
    /// Busy time per thread, ns (load-balance / utilization reporting).
    pub thread_busy_ns: Vec<u64>,
    /// Tasks whose assignment was served by cache affinity.
    pub affinity_hits: u64,
}

impl RunMetrics {
    /// The paper's cache hit ratio `hr`: hits / (hits + misses).
    pub fn hit_ratio(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Average thread utilization over the makespan.
    pub fn utilization(&self) -> f64 {
        if self.makespan_ns == 0 || self.thread_busy_ns.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.thread_busy_ns.iter().sum();
        busy as f64
            / (self.makespan_ns as f64 * self.thread_busy_ns.len() as f64)
    }

    /// Load imbalance: max busy / mean busy (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        if self.thread_busy_ns.is_empty() {
            return 1.0;
        }
        let max = *self.thread_busy_ns.iter().max().unwrap() as f64;
        let mean = self.thread_busy_ns.iter().sum::<u64>() as f64
            / self.thread_busy_ns.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Export these run metrics as a [`MetricsSnapshot`] — the same
    /// mergeable/serializable shape the live services scrape — so
    /// offline runs (threads, sim) and post-run reports share one
    /// vocabulary with `pem stats`.  Derived ratios stay methods on
    /// the consumer side; the snapshot carries raw counts plus the
    /// per-thread busy series as `thread.{i}.busy_ns` gauges.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let reg = Registry::new();
        reg.counter("comparisons").add(self.comparisons);
        reg.counter("cache_hits").add(self.cache_hits);
        reg.counter("cache_misses").add(self.cache_misses);
        reg.counter("bytes_fetched").add(self.bytes_fetched);
        reg.counter("control_messages").add(self.control_messages);
        reg.counter("affinity_hits").add(self.affinity_hits);
        reg.gauge("makespan_ns").set(self.makespan_ns);
        reg.gauge("tasks").set(self.tasks as u64);
        reg.gauge("matches").set(self.matches as u64);
        for (i, busy) in self.thread_busy_ns.iter().enumerate() {
            reg.gauge(&format!("thread.{i}.busy_ns")).set(*busy);
        }
        reg.snapshot()
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "time={} tasks={} pairs={} matches={} hr={:.0}% fetched={} util={:.0}%",
            fmt_nanos(self.makespan_ns),
            self.tasks,
            self.comparisons,
            self.matches,
            self.hit_ratio() * 100.0,
            fmt_bytes(self.bytes_fetched),
            self.utilization() * 100.0,
        )
    }
}

/// Speedup of a set of runs relative to the first (1-thread) run.
pub fn speedups(makespans_ns: &[u64]) -> Vec<f64> {
    assert!(!makespans_ns.is_empty());
    let base = makespans_ns[0] as f64;
    makespans_ns
        .iter()
        .map(|&m| if m == 0 { f64::NAN } else { base / m as f64 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_ratio_and_utilization() {
        let m = RunMetrics {
            makespan_ns: 1000,
            cache_hits: 82,
            cache_misses: 18,
            thread_busy_ns: vec![900, 800],
            ..Default::default()
        };
        assert!((m.hit_ratio() - 0.82).abs() < 1e-12);
        assert!((m.utilization() - 0.85).abs() < 1e-12);
    }

    #[test]
    fn imbalance_balanced_is_one() {
        let m = RunMetrics {
            thread_busy_ns: vec![500, 500, 500],
            ..Default::default()
        };
        assert!((m.imbalance() - 1.0).abs() < 1e-12);
        let skew = RunMetrics {
            thread_busy_ns: vec![900, 100],
            ..Default::default()
        };
        assert!((skew.imbalance() - 1.8).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_defined() {
        let m = RunMetrics::default();
        assert_eq!(m.hit_ratio(), 0.0);
        assert_eq!(m.utilization(), 0.0);
        assert_eq!(m.imbalance(), 1.0);
        assert!(!m.summary().is_empty());
    }

    #[test]
    fn speedup_series() {
        let s = speedups(&[1600, 800, 400, 100]);
        assert_eq!(s, vec![1.0, 2.0, 4.0, 16.0]);
    }
}
