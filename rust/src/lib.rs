//! # pem — Parallel Entity Matching
//!
//! A reproduction of *“Data Partitioning for Parallel Entity Matching”*
//! (Kirsten, Kolb, Hartung, Groß, Köpcke, Rahm — Univ. Leipzig, 2010) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The crate implements the paper's two contributions and every substrate
//! they depend on:
//!
//! * **Partitioning strategies** ([`partition`]): *size-based* partitioning
//!   for evaluating the Cartesian product in parallel (§3.1) and
//!   *blocking-based* partitioning with **partition tuning** — splitting
//!   oversized blocks, aggregating undersized ones, and routing the
//!   *misc* block of unblockable entities (§3.2) — plus the multi-source
//!   variants (§3.3).
//! * **Match infrastructure** ([`coordinator`], [`worker`], [`store`],
//!   [`net`], [`cluster`]): a workflow service holding the central task
//!   list and performing affinity-based scheduling, match services with
//!   LRU partition caches, a data service, dynamic service membership and
//!   failure handling (§4) — available both as in-process objects and as
//!   **real TCP services** ([`rpc`], [`service`]) speaking a versioned
//!   length-prefixed binary wire protocol (spec: `docs/WIRE_PROTOCOL.md`),
//!   with a **replicated data plane**: partition frames push-synced
//!   across data servers, a join-time replica directory,
//!   locality/load-aware replica selection with failover, and
//!   replica-coverage-aware affinity scheduling.  Driven by the
//!   distributed engine ([`engine::dist`]) or as separate processes via
//!   `pem serve` / `pem distmatch` (architecture tour:
//!   `docs/ARCHITECTURE.md`).
//!
//! Supporting subsystems: entity model ([`model`]), synthetic product-offer
//! generator ([`datagen`]), q-gram feature hashing ([`features`]), blocking
//! operators ([`blocking`]), match strategies WAM / LRM ([`matching`]),
//! execution engines — real threads, a deterministic virtual-time
//! simulator, and distributed TCP services ([`engine`]) — the PJRT
//! runtime for the AOT-compiled
//! accelerated match path ([`runtime`]), metrics ([`metrics`]),
//! cluster observability — metrics registry, per-task lifecycle
//! tracing, live `pem stats` scraping ([`obs`]) — and an in-tree
//! micro-benchmark harness ([`mod@bench`]).
//!
//! ## Quick start
//!
//! The workflow API follows the paper's Figure-1 pipeline as a
//! **plan/execute split**: a [`partition::PartitionStrategy`] plans an
//! inspectable [`coordinator::MatchPlan`], an
//! [`engine::backend::ExecutionBackend`] executes it.
//!
//! ```no_run
//! use pem::prelude::*;
//!
//! // 1. Generate a product-offer dataset with known duplicates.
//! let data = pem::datagen::GeneratorConfig::small().generate();
//! // 2. Plan: blocking → partition tuning → task generation.  Stop
//! //    here to inspect task skew before paying for execution.
//! let planned = Workflow::for_dataset(&data.dataset)
//!     .strategy(BlockingBased::product_type())
//!     .backend(Threads)
//!     .env(ComputingEnv::new(1, 4, 3 * pem::util::GIB))
//!     .cache(16)
//!     .plan()
//!     .unwrap();
//! println!("{}", planned.plan().summary());
//! // 3. Execute the plan and merge the per-task results.
//! let outcome = planned.execute().unwrap();
//! println!("{} matches in {:?}", outcome.result.len(), outcome.elapsed);
//! ```
//!
//! The pre-redesign [`coordinator::WorkflowConfig`] +
//! [`coordinator::run_workflow`] API remains as a deprecated shim for
//! one release (`docs/MIGRATION.md` has the mapping).

pub mod bench;
pub mod blocking;
pub mod cluster;
pub mod coordinator;
pub mod datagen;
pub mod engine;
pub mod features;
pub mod io;
pub mod lint;
pub mod matching;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod partition;
pub mod rpc;
pub mod runtime;
pub mod service;
pub mod store;
pub mod util;
pub mod worker;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::blocking::{BlockingMethod, Blocks};
    pub use crate::cluster::ComputingEnv;
    pub use crate::coordinator::{
        run_workflow, MatchPlan, PlannedWorkflow, RunOutcome, Workflow,
        WorkflowConfig, WorkflowOutcome,
    };
    pub use crate::datagen::GeneratorConfig;
    pub use crate::engine::backend::{
        Dist, DistOptions, ExecutionBackend, Sim, SimOptions, Threads,
    };
    pub use crate::matching::{MatchStrategy, StrategyKind};
    pub use crate::model::{Correspondence, Dataset, Entity, MatchResult};
    pub use crate::partition::{
        BlockingBased, MatchTask, PartitionId, PartitionSet,
        PartitionStrategy, SizeBased, SortedNeighborhood,
    };
}
