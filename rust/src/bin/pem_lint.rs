//! `pem_lint` — run the project-native invariant analyzer over the
//! tree.
//!
//! ```text
//! pem_lint [--root <repo-root>] [--write-baseline]
//! ```
//!
//! Walks every `.rs` file under `<root>/rust/src` (or `<root>/src`),
//! checks invariants L1–L5 (see `docs/STATIC_ANALYSIS.md`), prints
//! each violation as `L2 worker/cache.rs:26 <detail>` and exits 1 if
//! any fired.  Warnings (a stale L5 baseline) go to stderr and do not
//! fail the run.  `--write-baseline` regenerates
//! `<root>/scripts/lint_baseline.txt` from the current tree instead
//! of checking — use it only to lock in a *shrink*.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use pem::lint::{self, LintInput, ScannedFile};

/// Collect every `.rs` file under `dir`, sorted by path for stable
/// output.
fn rust_files(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for entry in std::fs::read_dir(&d)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn scan_tree(src_root: &Path) -> std::io::Result<Vec<ScannedFile>> {
    let mut files = Vec::new();
    for path in rust_files(src_root)? {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&path)?;
        files.push(ScannedFile::scan(&rel, &src));
    }
    Ok(files)
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut write_baseline = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("pem_lint: --root needs a directory");
                    return ExitCode::from(2);
                }
            },
            "--write-baseline" => write_baseline = true,
            "--help" | "-h" => {
                println!(
                    "usage: pem_lint [--root <repo-root>] \
                     [--write-baseline]"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("pem_lint: unknown argument {other}");
                return ExitCode::from(2);
            }
        }
    }

    let src_root = if root.join("rust/src").is_dir() {
        root.join("rust/src")
    } else if root.join("src").is_dir() {
        root.join("src")
    } else {
        eprintln!(
            "pem_lint: no rust/src or src under {}",
            root.display()
        );
        return ExitCode::from(2);
    };

    let files = match scan_tree(&src_root) {
        Ok(files) => files,
        Err(e) => {
            eprintln!("pem_lint: scanning {}: {e}", src_root.display());
            return ExitCode::from(2);
        }
    };

    let baseline_path = root.join("scripts/lint_baseline.txt");
    if write_baseline {
        let text = lint::format_baseline(&lint::panic_sites(&files));
        if let Err(e) = std::fs::write(&baseline_path, &text) {
            eprintln!(
                "pem_lint: writing {}: {e}",
                baseline_path.display()
            );
            return ExitCode::from(2);
        }
        println!("wrote {}", baseline_path.display());
        return ExitCode::SUCCESS;
    }

    let wire_doc = std::fs::read_to_string(root.join(lint::WIRE_DOC)).ok();
    let obs_doc = std::fs::read_to_string(root.join(lint::OBS_DOC)).ok();
    let baseline = std::fs::read_to_string(&baseline_path).ok();

    let report = lint::run(&LintInput {
        files,
        wire_doc: wire_doc.as_deref(),
        obs_doc: obs_doc.as_deref(),
        baseline: baseline.as_deref(),
    });

    for warning in &report.warnings {
        eprintln!("warning: {warning}");
    }
    if report.violations.is_empty() {
        println!(
            "pem_lint: clean ({} warnings)",
            report.warnings.len()
        );
        ExitCode::SUCCESS
    } else {
        for v in &report.violations {
            println!("{v}");
        }
        println!(
            "pem_lint: {} violation(s) — see docs/STATIC_ANALYSIS.md",
            report.violations.len()
        );
        ExitCode::FAILURE
    }
}
