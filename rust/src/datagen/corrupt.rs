//! String corruptions for duplicate injection.
//!
//! Offers of the same product from different shops differ by typos,
//! abbreviations, re-ordered tokens, unit spelling and dropped words —
//! exactly the perturbations entity matchers must see through.  Each
//! corruption is small enough that a true duplicate stays above the match
//! threshold with high probability.

use crate::util::Rng;

/// Apply `n` random corruptions to a string.
pub fn corrupt(rng: &mut Rng, s: &str, n: usize) -> String {
    let mut out = s.to_string();
    for _ in 0..n {
        out = match rng.gen_range(6) {
            0 => typo_swap(rng, &out),
            1 => typo_drop(rng, &out),
            2 => typo_dup(rng, &out),
            3 => case_flip(rng, &out),
            4 => token_swap(rng, &out),
            _ => spacing(rng, &out),
        };
    }
    out
}

/// Swap two adjacent characters.
fn typo_swap(rng: &mut Rng, s: &str) -> String {
    let chars: Vec<char> = s.chars().collect();
    if chars.len() < 2 {
        return s.to_string();
    }
    let i = rng.gen_range(chars.len() - 1);
    let mut c = chars;
    c.swap(i, i + 1);
    c.into_iter().collect()
}

/// Drop one character.
fn typo_drop(rng: &mut Rng, s: &str) -> String {
    let chars: Vec<char> = s.chars().collect();
    if chars.len() < 2 {
        return s.to_string();
    }
    let i = rng.gen_range(chars.len());
    chars
        .into_iter()
        .enumerate()
        .filter(|&(j, _)| j != i)
        .map(|(_, c)| c)
        .collect()
}

/// Duplicate one character.
fn typo_dup(rng: &mut Rng, s: &str) -> String {
    let chars: Vec<char> = s.chars().collect();
    if chars.is_empty() {
        return s.to_string();
    }
    let i = rng.gen_range(chars.len());
    let mut out = String::with_capacity(s.len() + 1);
    for (j, c) in chars.into_iter().enumerate() {
        out.push(c);
        if j == i {
            out.push(c);
        }
    }
    out
}

/// Flip the case of one letter.
fn case_flip(rng: &mut Rng, s: &str) -> String {
    let mut chars: Vec<char> = s.chars().collect();
    let letters: Vec<usize> = chars
        .iter()
        .enumerate()
        .filter(|(_, c)| c.is_ascii_alphabetic())
        .map(|(i, _)| i)
        .collect();
    if letters.is_empty() {
        return s.to_string();
    }
    let i = letters[rng.gen_range(letters.len())];
    chars[i] = if chars[i].is_ascii_uppercase() {
        chars[i].to_ascii_lowercase()
    } else {
        chars[i].to_ascii_uppercase()
    };
    chars.into_iter().collect()
}

/// Swap two adjacent whitespace-separated tokens.
fn token_swap(rng: &mut Rng, s: &str) -> String {
    let mut tokens: Vec<&str> = s.split_whitespace().collect();
    if tokens.len() < 2 {
        return s.to_string();
    }
    let i = rng.gen_range(tokens.len() - 1);
    tokens.swap(i, i + 1);
    tokens.join(" ")
}

/// Change unit spacing: "1TB" <-> "1 TB".
fn spacing(rng: &mut Rng, s: &str) -> String {
    if rng.gen_bool(0.5) {
        // insert a space before a trailing unit-like suffix
        for unit in ["TB", "GB", "MB", "rpm"] {
            if let Some(pos) = s.find(unit) {
                if pos > 0
                    && s.as_bytes()[pos - 1].is_ascii_digit()
                {
                    let mut out = s.to_string();
                    out.insert(pos, ' ');
                    return out;
                }
            }
        }
        s.to_string()
    } else {
        // collapse a "1 TB" style gap
        s.replace(" TB", "TB").replace(" GB", "GB")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn corrupt_changes_but_preserves_most() {
        let mut rng = Rng::new(1);
        let s = "Samsung SpinPoint F1 HD103UJ 1TB";
        let c = corrupt(&mut rng, s, 2);
        // still mostly the same string: cheap char-overlap check
        let common = c.chars().filter(|ch| s.contains(*ch)).count();
        assert!(common as f64 >= 0.8 * c.len() as f64, "{c}");
    }

    #[test]
    fn zero_corruptions_is_identity() {
        let mut rng = Rng::new(2);
        assert_eq!(corrupt(&mut rng, "LG GH22NS50", 0), "LG GH22NS50");
    }

    #[test]
    fn corruptions_never_panic_on_edge_inputs() {
        forall("corrupt-edge", 200, |rng| {
            for s in ["", "a", "ab", "1TB", "  ", "ü"] {
                let _ = corrupt(rng, s, 3);
            }
        });
    }

    #[test]
    fn token_swap_preserves_token_multiset() {
        forall("token-swap", 100, |rng| {
            let s = "alpha beta gamma delta";
            let swapped = token_swap(rng, s);
            let mut a: Vec<&str> = s.split_whitespace().collect();
            let mut b: Vec<&str> = swapped.split_whitespace().collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        });
    }

    #[test]
    fn typo_drop_shortens_by_one() {
        forall("typo-drop", 100, |rng| {
            let s = "abcdef";
            assert_eq!(typo_drop(rng, s).chars().count(), 5);
        });
    }

    #[test]
    fn spacing_roundtrips_units() {
        let mut rng = Rng::new(3);
        let variants: Vec<String> =
            (0..20).map(|_| spacing(&mut rng, "WD Caviar 1TB")).collect();
        assert!(variants
            .iter()
            .all(|v| v == "WD Caviar 1TB" || v == "WD Caviar 1 TB"));
    }
}
