//! Synthetic product-offer generator.
//!
//! Substitute for the paper's proprietary dataset of ~114,000 electronic
//! product offers (23 attributes) from a price-comparison portal.  The
//! generator reproduces the properties the partitioning strategies react
//! to (see DESIGN.md §Substitutions):
//!
//! * **skewed blocking keys** — manufacturer and product type are drawn
//!   from Zipf distributions, so key blocking produces a few huge blocks
//!   and a long tail of tiny ones (what makes partition *tuning* matter);
//! * **missing values** — a configurable fraction of offers lack product
//!   type / manufacturer and land in the *misc* block;
//! * **known duplicates** — each base product is offered by several shops
//!   with corrupted titles/descriptions; the generator records the true
//!   duplicate pairs as ground truth for precision/recall reporting.

pub mod catalog;
pub mod corrupt;

use crate::model::{
    Dataset, Entity, EntityId, Schema, ATTR_DESCRIPTION, ATTR_MANUFACTURER,
    ATTR_PRODUCT_TYPE, ATTR_TITLE,
};
use crate::util::{Rng, Zipf};

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    /// Total number of offers (entities) to generate.
    pub n_entities: usize,
    /// Average offers per base product (duplicate cluster size); drawn as
    /// 1 + Poisson(dup_rate).
    pub dup_rate: f64,
    /// Fraction of offers with a missing product type (→ misc block when
    /// blocking by product type).
    pub missing_product_type: f64,
    /// Fraction of offers with a missing manufacturer.
    pub missing_manufacturer: f64,
    /// Zipf exponent for product-type popularity (block-size skew).
    pub type_skew: f64,
    /// Zipf exponent for manufacturer popularity.
    pub manufacturer_skew: f64,
    /// Corruptions applied to a duplicate's title (and half as many to
    /// its description).
    pub corruptions: usize,
    /// Number of distinct manufacturers.  The first
    /// `catalog::MANUFACTURERS.len()` use the real brand names; the long
    /// tail (real price portals list hundreds of niche brands) is
    /// synthesized deterministically.  Drives the block-count/skew of
    /// manufacturer blocking (Fig 7).
    pub n_manufacturers: usize,
    /// PRNG seed — same seed, same dataset, bit for bit.
    pub seed: u64,
}

impl GeneratorConfig {
    /// The paper's small-scale match problem: 20,000 offers.
    pub fn small() -> GeneratorConfig {
        GeneratorConfig {
            n_entities: 20_000,
            ..GeneratorConfig::default()
        }
    }

    /// The paper's large-scale match problem: 114,000 offers.
    pub fn large() -> GeneratorConfig {
        GeneratorConfig {
            n_entities: 114_000,
            ..GeneratorConfig::default()
        }
    }

    /// A tiny dataset for unit tests and the quickstart example.
    pub fn tiny() -> GeneratorConfig {
        GeneratorConfig {
            n_entities: 600,
            ..GeneratorConfig::default()
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_entities(mut self, n: usize) -> Self {
        self.n_entities = n;
        self
    }

    /// Generate the dataset (+ ground truth) for this configuration.
    pub fn generate(&self) -> GeneratedData {
        generate(self)
    }
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            n_entities: 20_000,
            dup_rate: 0.35,
            // the paper's large dataset has ~7 misc partitions of 306 at
            // max size 1000 → ~6% of offers lack a product type (the
            // Fig 3 *example* uses a higher 17%; set per-experiment)
            missing_product_type: 0.06,
            missing_manufacturer: 0.05,
            type_skew: 0.9,
            manufacturer_skew: 1.05,
            corruptions: 2,
            n_manufacturers: 400,
            seed: 2010,
        }
    }
}

/// The full manufacturer name list for a configuration: real brands
/// followed by a deterministic synthesized long tail.
pub fn manufacturer_names(n: usize) -> Vec<String> {
    const PRE: &[&str] = &[
        "Nova", "Digi", "Techno", "Micro", "Ultra", "Prime", "Alpha",
        "Vertex", "Quantum", "Sola", "Hyper", "Omni", "Penta", "Strato",
        "Velo", "Zen", "Arc", "Core", "Flux", "Giga",
    ];
    const SUF: &[&str] = &[
        "tron", "tech", "ware", "dyne", "logic", "com", "sys", "max",
        "link", "core", "data", "vision", "sonic", "point", "line",
        "works", "media", "lab", "net", "plex",
    ];
    let mut names: Vec<String> = catalog::MANUFACTURERS
        .iter()
        .map(|s| s.to_string())
        .collect();
    let mut i = 0usize;
    while names.len() < n {
        let name =
            format!("{}{}", PRE[i % PRE.len()], SUF[(i / PRE.len()) % SUF.len()]);
        let name = if i >= PRE.len() * SUF.len() {
            format!("{name} {}", i / (PRE.len() * SUF.len()))
        } else {
            name
        };
        names.push(name);
        i += 1;
    }
    names.truncate(n);
    names
}

/// Generator output: the dataset plus the injected duplicate pairs.
#[derive(Clone, Debug)]
pub struct GeneratedData {
    pub dataset: Dataset,
    /// True duplicate pairs (offers of the same base product).
    pub truth: Vec<(EntityId, EntityId)>,
    /// Number of distinct base products.
    pub n_products: usize,
}

impl std::ops::Deref for GeneratedData {
    type Target = Dataset;
    fn deref(&self) -> &Dataset {
        &self.dataset
    }
}

struct BaseProduct {
    manufacturer: usize,
    product_type: usize,
    title: String,
    description: String,
    model_number: String,
    price_cents: u64,
}

fn make_base_product(
    rng: &mut Rng,
    manufacturers: &[String],
    man_zipf: &Zipf,
    type_zipf: &Zipf,
) -> BaseProduct {
    let manufacturer = man_zipf.sample(rng);
    let product_type = type_zipf.sample(rng);
    let series = rng.choose(catalog::SERIES);
    let model_number = format!(
        "{}{}{}",
        (b'A' + rng.gen_range(26) as u8) as char,
        (b'A' + rng.gen_range(26) as u8) as char,
        1000 + rng.gen_range(9000)
    );
    let capacity = rng.choose(catalog::CAPACITIES);
    let title = format!(
        "{} {} {} {}",
        manufacturers[manufacturer], series, model_number, capacity
    );
    let n_tokens = 6 + rng.gen_range(10);
    let mut desc_tokens = Vec::with_capacity(n_tokens + 2);
    desc_tokens.push(catalog::PRODUCT_TYPES[product_type].to_string());
    desc_tokens.push(series.to_string());
    for _ in 0..n_tokens {
        desc_tokens.push(rng.choose(catalog::DESC_TOKENS).to_string());
    }
    BaseProduct {
        manufacturer,
        product_type,
        title,
        description: desc_tokens.join(" "),
        model_number,
        price_cents: 500 + rng.gen_range(200_000) as u64,
    }
}

fn make_offer(
    rng: &mut Rng,
    schema: &Schema,
    id: EntityId,
    base: &BaseProduct,
    manufacturers: &[String],
    cfg: &GeneratorConfig,
    is_first_offer: bool,
) -> Entity {
    let mut e = Entity::new(id, schema);
    // Corrupt duplicates; keep the first offer pristine.
    let (title, description) = if is_first_offer {
        (base.title.clone(), base.description.clone())
    } else {
        (
            corrupt::corrupt(rng, &base.title, cfg.corruptions),
            corrupt::corrupt(rng, &base.description, cfg.corruptions / 2),
        )
    };
    e.set(schema, ATTR_TITLE, title);
    e.set(schema, ATTR_DESCRIPTION, description);
    if !rng.gen_bool(cfg.missing_manufacturer) {
        e.set(
            schema,
            ATTR_MANUFACTURER,
            manufacturers[base.manufacturer].clone(),
        );
    }
    if !rng.gen_bool(cfg.missing_product_type) {
        e.set(
            schema,
            ATTR_PRODUCT_TYPE,
            catalog::PRODUCT_TYPES[base.product_type].to_string(),
        );
    }
    // Fill the remaining attributes of the 23-attribute offer schema.
    let shop = rng.choose(catalog::SHOPS);
    let price =
        base.price_cents as f64 / 100.0 * (0.9 + 0.2 * rng.gen_f64());
    e.set(schema, "ean", format!("40{:011}", rng.next_u64() % 100_000_000_000));
    e.set(schema, "sku", format!("{}-{}", &shop[..4], rng.next_u64() % 1_000_000));
    e.set(schema, "model_number", base.model_number.clone());
    e.set(schema, "price", format!("{price:.2}"));
    e.set(schema, "currency", "EUR".to_string());
    e.set(
        schema,
        "availability",
        if rng.gen_bool(0.8) { "in-stock" } else { "2-3 days" }.to_string(),
    );
    e.set(schema, "shop_name", shop.to_string());
    e.set(schema, "shop_url", format!("https://{shop}/p/{}", id.0));
    e.set(
        schema,
        "category_path",
        format!("electronics/{}", catalog::PRODUCT_TYPES[base.product_type]),
    );
    e.set(schema, "color", rng.choose(catalog::COLORS).to_string());
    e.set(schema, "weight_g", format!("{}", 50 + rng.gen_range(5000)));
    e.set(schema, "width_mm", format!("{}", 20 + rng.gen_range(500)));
    e.set(schema, "height_mm", format!("{}", 10 + rng.gen_range(300)));
    e.set(schema, "depth_mm", format!("{}", 10 + rng.gen_range(300)));
    e.set(schema, "warranty_months", format!("{}", 12 * (1 + rng.gen_range(3))));
    e.set(
        schema,
        "energy_label",
        rng.choose(catalog::ENERGY_LABELS).to_string(),
    );
    e.set(schema, "release_year", format!("{}", 2004 + rng.gen_range(7)));
    e.set(schema, "rating", format!("{:.1}", 1.0 + 4.0 * rng.gen_f64()));
    e.set(schema, "delivery_days", format!("{}", 1 + rng.gen_range(10)));
    e
}

/// Generate a dataset per the configuration.
pub fn generate(cfg: &GeneratorConfig) -> GeneratedData {
    let schema = Schema::product_offers();
    let mut rng = Rng::new(cfg.seed);
    let manufacturers = manufacturer_names(cfg.n_manufacturers.max(1));
    let man_zipf = Zipf::new(manufacturers.len(), cfg.manufacturer_skew);
    let type_zipf = Zipf::new(catalog::PRODUCT_TYPES.len(), cfg.type_skew);

    let mut dataset = Dataset::new(schema.clone());
    let mut truth = Vec::new();
    let mut n_products = 0;

    while dataset.len() < cfg.n_entities {
        let base =
            make_base_product(&mut rng, &manufacturers, &man_zipf, &type_zipf);
        n_products += 1;
        let cluster =
            (1 + rng.gen_poisson(cfg.dup_rate) as usize).min(cfg.n_entities - dataset.len());
        let first_id = dataset.len() as u32;
        for k in 0..cluster {
            let id = EntityId(dataset.len() as u32);
            let offer = make_offer(
                &mut rng,
                &schema,
                id,
                &base,
                &manufacturers,
                cfg,
                k == 0,
            );
            dataset.push(offer);
        }
        // all pairs inside the cluster are true duplicates
        for i in 0..cluster {
            for j in (i + 1)..cluster {
                truth.push((
                    EntityId(first_id + i as u32),
                    EntityId(first_id + j as u32),
                ));
            }
        }
    }

    GeneratedData {
        dataset,
        truth,
        n_products,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn tiny() -> GeneratedData {
        GeneratorConfig::tiny().generate()
    }

    #[test]
    fn generates_requested_count() {
        let g = tiny();
        assert_eq!(g.dataset.len(), 600);
        assert!(g.n_products > 0 && g.n_products <= 600);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = GeneratorConfig::tiny().with_seed(7).generate();
        let b = GeneratorConfig::tiny().with_seed(7).generate();
        let c = GeneratorConfig::tiny().with_seed(8).generate();
        assert_eq!(a.dataset.entities, b.dataset.entities);
        assert_eq!(a.truth, b.truth);
        assert_ne!(a.dataset.entities, c.dataset.entities);
    }

    #[test]
    fn ids_are_dense_and_ordered() {
        let g = tiny();
        for (i, e) in g.dataset.entities.iter().enumerate() {
            assert_eq!(e.id, EntityId(i as u32));
        }
    }

    #[test]
    fn truth_pairs_valid_and_within_range() {
        let g = tiny();
        assert!(!g.truth.is_empty(), "duplicates injected");
        for &(a, b) in &g.truth {
            assert!(a < b);
            assert!((b.0 as usize) < g.dataset.len());
        }
    }

    #[test]
    fn misc_fraction_close_to_config() {
        let cfg = GeneratorConfig {
            n_entities: 4000,
            ..GeneratorConfig::default()
        };
        let g = cfg.generate();
        let missing = g
            .dataset
            .entities
            .iter()
            .filter(|e| e.product_type(&g.dataset.schema).is_none())
            .count();
        let frac = missing as f64 / g.dataset.len() as f64;
        assert!(
            (frac - cfg.missing_product_type).abs() < 0.03,
            "misc fraction {frac}"
        );
    }

    #[test]
    fn block_sizes_are_skewed() {
        let g = GeneratorConfig {
            n_entities: 6000,
            ..GeneratorConfig::default()
        }
        .generate();
        let mut sizes: HashMap<&str, usize> = HashMap::new();
        for e in &g.dataset.entities {
            if let Some(t) = e.product_type(&g.dataset.schema) {
                *sizes.entry(t).or_default() += 1;
            }
        }
        let mut counts: Vec<usize> = sizes.values().copied().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // biggest block at least 4x the median block — real skew
        let median = counts[counts.len() / 2];
        assert!(
            counts[0] >= 4 * median.max(1),
            "not skewed: {counts:?}"
        );
    }

    #[test]
    fn duplicates_share_blocking_keys_mostly() {
        let g = tiny();
        let s = &g.dataset.schema;
        let mut same_type = 0;
        let mut total = 0;
        for &(a, b) in &g.truth {
            let (ea, eb) =
                (g.dataset.get(a).unwrap(), g.dataset.get(b).unwrap());
            if let (Some(ta), Some(tb)) =
                (ea.product_type(s), eb.product_type(s))
            {
                total += 1;
                same_type += (ta == tb) as usize;
            }
        }
        assert!(total > 0);
        assert_eq!(same_type, total, "same base product, same type");
    }

    #[test]
    fn titles_of_duplicates_similar() {
        let g = tiny();
        let s = &g.dataset.schema;
        // sample a few truth pairs; titles must share most characters
        for &(a, b) in g.truth.iter().take(20) {
            let ta = g.dataset.get(a).unwrap().title(s).to_lowercase();
            let tb = g.dataset.get(b).unwrap().title(s).to_lowercase();
            let common =
                ta.chars().filter(|c| tb.contains(*c)).count() as f64;
            assert!(
                common / ta.len().max(1) as f64 > 0.6,
                "{ta:?} vs {tb:?}"
            );
        }
    }

    #[test]
    fn all_23_attributes_mostly_filled() {
        let g = tiny();
        let s = &g.dataset.schema;
        let e = &g.dataset.entities[0];
        let filled = s
            .attributes()
            .iter()
            .filter(|a| e.get(s, a).is_some())
            .count();
        assert!(filled >= 21, "only {filled} attributes filled");
    }

    #[test]
    fn manufacturer_tail_synthesized_and_unique() {
        let names = manufacturer_names(400);
        assert_eq!(names.len(), 400);
        assert_eq!(names[0], "Samsung"); // real brands first
        let mut sorted = names.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 400, "names must be unique");
        // deterministic
        assert_eq!(manufacturer_names(400), names);
        // huge n still works (suffix disambiguation)
        assert_eq!(manufacturer_names(1000).len(), 1000);
    }

    #[test]
    fn manufacturer_blocking_has_long_tail() {
        let g = GeneratorConfig {
            n_entities: 5000,
            ..GeneratorConfig::default()
        }
        .generate();
        let blocks = crate::blocking::BlockingMethod::manufacturer()
            .run(&g.dataset);
        assert!(
            blocks.n_blocks() > 150,
            "want a long manufacturer tail, got {}",
            blocks.n_blocks()
        );
        let hist = blocks.size_histogram();
        assert!(hist[0] > 20 * hist[hist.len() - 1].max(1), "skewed");
    }

    #[test]
    fn large_config_sizes() {
        assert_eq!(GeneratorConfig::small().n_entities, 20_000);
        assert_eq!(GeneratorConfig::large().n_entities, 114_000);
    }
}
