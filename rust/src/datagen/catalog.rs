//! Vocabulary catalogs for the synthetic product-offer generator.
//!
//! The paper's dataset is 114k electronic product offers from a price
//! comparison portal.  These catalogs reproduce its *structure*: a skewed
//! manufacturer distribution, a moderate number of product types (the
//! blocking keys), model-number grammars and description vocabulary.

/// Manufacturers, ordered by (approximate) real-world popularity — the
/// Zipf sampler draws indices into this list, so the head brands dominate.
pub const MANUFACTURERS: &[&str] = &[
    "Samsung", "Sony", "LG", "Philips", "Panasonic", "Canon", "HP",
    "Logitech", "Western Digital", "Seagate", "Intel", "AMD", "Asus",
    "Acer", "Toshiba", "Nokia", "Apple", "Lenovo", "Dell", "Epson",
    "Brother", "Kingston", "Corsair", "MSI", "Gigabyte", "Sandisk",
    "TrekStor", "Plextor", "LiteOn", "BenQ", "ViewSonic", "NEC",
    "Fujitsu", "Sharp", "Pioneer", "JVC", "Kenwood", "TomTom",
    "Garmin", "Netgear", "D-Link", "Linksys", "Zyxel", "AVM",
    "Medion", "Grundig", "Siemens", "Bosch", "Braun", "Nikon",
];

/// Product types: the primary blocking key of the evaluation.  The
/// Drives & Storage subset (first ten) reproduces the Figure 3 example.
pub const PRODUCT_TYPES: &[&str] = &[
    // Drives & Storage (Fig. 3 block keys)
    "3.5-drive", "2.5-drive", "DVD-RW", "Blu-ray", "HD-DVD", "CD-RW",
    "USB-stick", "SSD", "NAS", "memory-card",
    // wider electronics catalog
    "LCD-TV", "plasma-TV", "monitor", "projector", "printer", "scanner",
    "digital-camera", "camcorder", "MP3-player", "notebook", "netbook",
    "desktop-PC", "mainboard", "CPU", "RAM", "graphics-card", "keyboard",
    "mouse", "router", "switch", "webcam", "headset", "speaker",
    "sat-receiver", "DVD-player", "navigation", "mobile-phone", "e-reader",
];

/// Product-line words combined into titles.
pub const SERIES: &[&str] = &[
    "SpinPoint", "Caviar", "Barracuda", "Momentus", "UltraMax", "EcoGreen",
    "Xpress", "ProLine", "MediaStar", "PowerEdge", "TravelMate", "Aspire",
    "Pavilion", "ThinkCentre", "Satellite", "VAIO", "Bravia", "Viera",
    "Cyber-shot", "PowerShot", "PIXMA", "LaserJet", "OfficeJet", "Stylus",
    "DataStation", "StoreJet", "Extreme", "Turbo", "Elite", "Vision",
];

/// Adjective/feature tokens for descriptions.
pub const DESC_TOKENS: &[&str] = &[
    "internal", "external", "portable", "high-speed", "silent", "retail",
    "bulk", "black", "white", "silver", "SATA", "SATA-II", "IDE", "USB",
    "USB-2.0", "USB-3.0", "FireWire", "eSATA", "cache", "16MB", "32MB",
    "64MB", "7200rpm", "5400rpm", "10000rpm", "low-power", "energy-saving",
    "shock-resistant", "slim", "compact", "widescreen", "full-hd", "1080p",
    "720p", "wireless", "bluetooth", "ethernet", "gigabit", "dual-layer",
    "lightscribe", "oem", "warranty", "edition", "series", "premium",
    "professional", "entry-level", "gaming", "office", "multimedia",
];

/// Capacity/size tokens appended to titles.
pub const CAPACITIES: &[&str] = &[
    "80GB", "120GB", "160GB", "250GB", "320GB", "400GB", "500GB", "640GB",
    "750GB", "1TB", "1.5TB", "2TB", "4GB", "8GB", "16GB", "32GB", "64GB",
];

/// Shop names (offers of the same product from different shops are the
/// duplicates entity matching must find).
pub const SHOPS: &[&str] = &[
    "techbuy.example", "pricekiller.example", "megawatt.example",
    "cyberport.example", "hardwareville.example", "gadgetworld.example",
    "bitsandparts.example", "electrodome.example", "chipmarket.example",
    "voltbay.example", "pixelhaus.example", "datadepot.example",
];

pub const COLORS: &[&str] =
    &["black", "white", "silver", "grey", "red", "blue"];

pub const ENERGY_LABELS: &[&str] = &["A++", "A+", "A", "B", "C"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogs_nonempty_and_unique() {
        for (name, cat) in [
            ("manufacturers", MANUFACTURERS),
            ("product_types", PRODUCT_TYPES),
            ("series", SERIES),
            ("desc_tokens", DESC_TOKENS),
            ("capacities", CAPACITIES),
            ("shops", SHOPS),
        ] {
            assert!(cat.len() >= 6, "{name} too small");
            let mut sorted: Vec<_> = cat.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), cat.len(), "{name} has duplicates");
        }
    }

    #[test]
    fn fig3_block_keys_present() {
        for key in ["3.5-drive", "2.5-drive", "DVD-RW", "Blu-ray", "HD-DVD", "CD-RW"] {
            assert!(PRODUCT_TYPES.contains(&key), "{key} missing");
        }
    }
}
