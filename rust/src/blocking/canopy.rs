//! Canopy clustering (McCallum, Nigam & Ungar, KDD 2000) as a blocking
//! operator.
//!
//! Uses a cheap similarity (token-set Jaccard on titles) with a *loose*
//! and a *tight* threshold: a random seed entity opens a canopy; every
//! entity within the loose threshold joins it; entities within the tight
//! threshold are removed from the candidate pool.  To fit the disjoint
//! [`Blocks`] model each entity is *assigned* to the first canopy it
//! joins (assignment set), which preserves the property that very
//! similar entities share a block.
//!
//! Entities with empty titles go to *misc*.

use super::Blocks;
use crate::features::TokenSet;
use crate::model::Dataset;
use crate::util::Rng;

pub fn block(dataset: &Dataset, loose: f64, tight: f64) -> Blocks {
    assert!(
        (0.0..=1.0).contains(&loose)
            && (0.0..=1.0).contains(&tight)
            && tight >= loose,
        "need 0 <= loose <= tight <= 1"
    );
    let mut blocks = Blocks::new();
    let mut pool: Vec<usize> = Vec::new();
    let mut tokens: Vec<TokenSet> = Vec::with_capacity(dataset.len());
    for (i, e) in dataset.entities.iter().enumerate() {
        let t = TokenSet::new(e.title(&dataset.schema));
        if t.is_empty() {
            blocks.add_misc(e.id);
        } else {
            pool.push(i);
        }
        tokens.push(t);
    }

    // deterministic seed order from the dataset size
    let mut rng = Rng::new(0xCA0_0917 ^ dataset.len() as u64);
    let mut assigned = vec![false; dataset.len()];
    let mut removed = vec![false; dataset.len()];
    let mut canopy_id = 0usize;

    while let Some(&seed_pos) = {
        // pick a random not-yet-removed pool entry
        let alive: Vec<&usize> =
            pool.iter().filter(|&&i| !removed[i]).collect();
        if alive.is_empty() {
            None
        } else {
            Some(alive[rng.gen_range(alive.len())])
        }
    } {
        let key = format!("canopy:{canopy_id:06}");
        canopy_id += 1;
        removed[seed_pos] = true;
        if !assigned[seed_pos] {
            assigned[seed_pos] = true;
            blocks.add(&key, dataset.entities[seed_pos].id);
        }
        for &i in &pool {
            if i == seed_pos || removed[i] {
                continue;
            }
            let sim = jaccard_sim(&tokens[seed_pos], &tokens[i]);
            if sim >= loose && !assigned[i] {
                assigned[i] = true;
                blocks.add(&key, dataset.entities[i].id);
            }
            if sim >= tight {
                removed[i] = true;
            }
        }
    }
    blocks
}

fn jaccard_sim(a: &TokenSet, b: &TokenSet) -> f64 {
    let inter = a.intersection_size(b);
    let union = a.len() + b.len() - inter;
    if union == 0 {
        0.0
    } else {
        inter as f64 / union as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::model::{Dataset, Entity, EntityId, Schema, ATTR_TITLE};

    fn titled_dataset(titles: &[&str]) -> Dataset {
        let schema = Schema::new(vec![ATTR_TITLE]);
        let mut ds = Dataset::new(schema.clone());
        for (i, t) in titles.iter().enumerate() {
            let mut e = Entity::new(EntityId(i as u32), &schema);
            if !t.is_empty() {
                e.set(&schema, ATTR_TITLE, t.to_string());
            }
            ds.push(e);
        }
        ds
    }

    #[test]
    fn near_duplicates_share_canopy() {
        let ds = titled_dataset(&[
            "samsung spinpoint f1 1tb",
            "samsung spinpoint f1 1tb sata",
            "canon pixma ip4600 printer",
            "canon pixma ip4600",
        ]);
        let b = block(&ds, 0.4, 0.8);
        b.assert_disjoint_cover(4);
        // find the block containing entity 0; it must contain entity 1
        let blk0: Vec<_> = b
            .iter()
            .filter(|(_, ids)| ids.contains(&EntityId(0)))
            .collect();
        assert_eq!(blk0.len(), 1);
        assert!(blk0[0].1.contains(&EntityId(1)));
    }

    #[test]
    fn disjoint_cover_on_generated() {
        let g = GeneratorConfig::tiny().with_seed(1).generate();
        let b = block(&g.dataset, 0.5, 0.8);
        b.assert_disjoint_cover(g.dataset.len());
        assert!(b.n_blocks() > 1);
    }

    #[test]
    fn empty_titles_to_misc() {
        let ds = titled_dataset(&["a b c", "", "d e f"]);
        let b = block(&ds, 0.3, 0.6);
        assert_eq!(b.misc().len(), 1);
        b.assert_disjoint_cover(3);
    }

    #[test]
    #[should_panic]
    fn invalid_thresholds_rejected() {
        let ds = titled_dataset(&["x"]);
        block(&ds, 0.8, 0.3); // loose > tight
    }

    #[test]
    fn deterministic() {
        let g = GeneratorConfig::tiny().with_seed(2).generate();
        let b1 = block(&g.dataset, 0.5, 0.8);
        let b2 = block(&g.dataset, 0.5, 0.8);
        assert_eq!(b1.size_histogram(), b2.size_histogram());
    }
}
