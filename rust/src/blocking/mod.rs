//! Blocking operators (paper §2, §3.2).
//!
//! Blocking logically partitions the input so matching can be restricted
//! to entities of the same block.  Entities that cannot be assigned a
//! unique block (missing key values) go to the dedicated *misc* block,
//! which must later be matched against every other block.
//!
//! Three operators, all emitting the same [`Blocks`] shape so the
//! blocking-based partitioning strategy (paper §3.2) is independent of
//! the operator choice:
//!
//! * [`key`] — range/equality blocking on an attribute (product type,
//!   manufacturer);
//! * [`sorted_neighborhood`] — Hernández/Stolfo merge-purge windowing;
//! * [`canopy`] — McCallum/Nigam/Ungar canopy clustering with a cheap
//!   similarity.

pub mod canopy;
pub mod key;
pub mod sorted_neighborhood;

use crate::model::{Dataset, EntityId};
use std::collections::BTreeMap;

/// Reserved key for the misc block.
pub const MISC_KEY: &str = "\u{0}misc";

/// Output of a blocking operator: named blocks + the misc block.
#[derive(Clone, Debug, Default)]
pub struct Blocks {
    /// key → member entity ids. BTreeMap for deterministic iteration.
    blocks: BTreeMap<String, Vec<EntityId>>,
    misc: Vec<EntityId>,
}

impl Blocks {
    pub fn new() -> Blocks {
        Blocks::default()
    }

    pub fn add(&mut self, key: &str, id: EntityId) {
        debug_assert_ne!(key, MISC_KEY);
        self.blocks.entry(key.to_string()).or_default().push(id);
    }

    pub fn add_misc(&mut self, id: EntityId) {
        self.misc.push(id);
    }

    /// Non-misc blocks in deterministic (key) order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &[EntityId])> {
        self.blocks.iter().map(|(k, v)| (k.as_str(), v.as_slice()))
    }

    pub fn get(&self, key: &str) -> Option<&[EntityId]> {
        self.blocks.get(key).map(|v| v.as_slice())
    }

    pub fn misc(&self) -> &[EntityId] {
        &self.misc
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Total entities across all blocks + misc.
    pub fn total_entities(&self) -> usize {
        self.blocks.values().map(Vec::len).sum::<usize>() + self.misc.len()
    }

    /// Block-size histogram (for reports / skew checks), descending.
    pub fn size_histogram(&self) -> Vec<usize> {
        let mut sizes: Vec<usize> =
            self.blocks.values().map(Vec::len).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        sizes
    }

    /// Invariant check: every entity id appears in exactly one block (or
    /// misc).  Returns the covered id count.
    pub fn assert_disjoint_cover(&self, n_entities: usize) {
        let mut seen = vec![false; n_entities];
        let mark = |seen: &mut Vec<bool>, id: EntityId| {
            let i = id.0 as usize;
            assert!(i < n_entities, "id {i} out of range");
            assert!(!seen[i], "entity {i} in two blocks");
            seen[i] = true;
        };
        for ids in self.blocks.values() {
            for &id in ids {
                mark(&mut seen, id);
            }
        }
        for &id in &self.misc {
            mark(&mut seen, id);
        }
        assert!(
            seen.iter().all(|&s| s),
            "some entities unassigned ({} of {})",
            seen.iter().filter(|&&s| !s).count(),
            n_entities
        );
    }
}

/// Uniform interface over the three operators.
#[derive(Clone, Debug, PartialEq)]
pub enum BlockingMethod {
    /// Equality blocking on an attribute.
    Key { attribute: String },
    /// Sorted neighborhood on an attribute with a window size.
    SortedNeighborhood { attribute: String, window: usize },
    /// Canopy clustering on title trigrams with loose/tight thresholds.
    Canopy { loose: f64, tight: f64 },
}

impl BlockingMethod {
    pub fn product_type() -> BlockingMethod {
        BlockingMethod::Key {
            attribute: crate::model::ATTR_PRODUCT_TYPE.to_string(),
        }
    }

    pub fn manufacturer() -> BlockingMethod {
        BlockingMethod::Key {
            attribute: crate::model::ATTR_MANUFACTURER.to_string(),
        }
    }

    pub fn run(&self, dataset: &Dataset) -> Blocks {
        match self {
            BlockingMethod::Key { attribute } => key::block(dataset, attribute),
            BlockingMethod::SortedNeighborhood { attribute, window } => {
                sorted_neighborhood::block(dataset, attribute, *window)
            }
            BlockingMethod::Canopy { loose, tight } => {
                canopy::block(dataset, *loose, *tight)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_bookkeeping() {
        let mut b = Blocks::new();
        b.add("ssd", EntityId(0));
        b.add("ssd", EntityId(1));
        b.add("nas", EntityId(2));
        b.add_misc(EntityId(3));
        assert_eq!(b.n_blocks(), 2);
        assert_eq!(b.total_entities(), 4);
        assert_eq!(b.get("ssd").unwrap().len(), 2);
        assert_eq!(b.misc().len(), 1);
        assert_eq!(b.size_histogram(), vec![2, 1]);
        b.assert_disjoint_cover(4);
    }

    #[test]
    #[should_panic]
    fn disjoint_cover_detects_duplicates() {
        let mut b = Blocks::new();
        b.add("x", EntityId(0));
        b.add("y", EntityId(0));
        b.assert_disjoint_cover(1);
    }

    #[test]
    #[should_panic]
    fn disjoint_cover_detects_missing() {
        let mut b = Blocks::new();
        b.add("x", EntityId(0));
        b.assert_disjoint_cover(2);
    }

    #[test]
    fn deterministic_iteration_order() {
        let mut b = Blocks::new();
        b.add("zeta", EntityId(0));
        b.add("alpha", EntityId(1));
        let keys: Vec<&str> = b.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["alpha", "zeta"]);
    }
}
