//! Sorted Neighborhood blocking (Hernández & Stolfo, SIGMOD 1995).
//!
//! Sort entities by a key, slide a window of size `w`, and emit each
//! window position as a candidate group.  To fit the paper's
//! disjoint-blocks model (each entity in exactly one block), this
//! implementation emits *non-overlapping* sorted runs of `w` consecutive
//! entities: the classic overlapping windows are recovered during match
//! task generation because adjacent runs are additionally compared when
//! `overlap_adjacent` is set — mirroring how FEVER integrates SN-style
//! blocking with partition-wise matching.
//!
//! Entities with a missing key go to *misc*.

use super::Blocks;
use crate::features::normalize;
use crate::model::Dataset;

pub fn block(dataset: &Dataset, attribute: &str, window: usize) -> Blocks {
    assert!(window >= 2, "window must be >= 2");
    let mut keyed: Vec<(String, crate::model::EntityId)> = Vec::new();
    let mut blocks = Blocks::new();
    for e in &dataset.entities {
        match e.get(&dataset.schema, attribute) {
            Some(v) if !v.trim().is_empty() => {
                keyed.push((normalize(v), e.id));
            }
            _ => blocks.add_misc(e.id),
        }
    }
    // sort by (key, id) — deterministic
    keyed.sort();
    for (run, chunk) in keyed.chunks(window).enumerate() {
        // key runs by their ordinal so same-valued keys across runs stay
        // distinct blocks (runs are positional, not semantic)
        let key = format!("sn:{run:06}");
        for (_, id) in chunk {
            blocks.add(&key, *id);
        }
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::GeneratorConfig;
    use crate::model::{
        Dataset, Entity, EntityId, Schema, ATTR_TITLE,
    };

    fn titled_dataset(titles: &[&str]) -> Dataset {
        let schema = Schema::new(vec![ATTR_TITLE]);
        let mut ds = Dataset::new(schema.clone());
        for (i, t) in titles.iter().enumerate() {
            let mut e = Entity::new(EntityId(i as u32), &schema);
            if !t.is_empty() {
                e.set(&schema, ATTR_TITLE, t.to_string());
            }
            ds.push(e);
        }
        ds
    }

    #[test]
    fn runs_have_window_size() {
        let ds = titled_dataset(&["d", "c", "b", "a", "e", "f", "g"]);
        let b = block(&ds, ATTR_TITLE, 3);
        b.assert_disjoint_cover(7);
        let hist = b.size_histogram();
        assert_eq!(hist, vec![3, 3, 1]);
    }

    #[test]
    fn sorted_adjacency_groups_similar_keys() {
        // lexicographically close titles end up in the same run
        let ds = titled_dataset(&[
            "samsung f1",
            "zzz unrelated",
            "samsung f1 1tb",
            "aaa other",
        ]);
        let b = block(&ds, ATTR_TITLE, 2);
        // sorted: aaa, samsung f1, samsung f1 1tb, zzz
        // runs: [aaa, samsung f1], [samsung f1 1tb, zzz]... window 2
        // the two samsungs are adjacent in sort order; with window 2 and
        // offset they may split — but each run is contiguous in sort order
        let sizes = b.size_histogram();
        assert_eq!(sizes.iter().sum::<usize>(), 4);
        b.assert_disjoint_cover(4);
    }

    #[test]
    fn missing_keys_to_misc() {
        let ds = titled_dataset(&["x", "", "y"]);
        let b = block(&ds, ATTR_TITLE, 2);
        assert_eq!(b.misc().len(), 1);
        b.assert_disjoint_cover(3);
    }

    #[test]
    #[should_panic]
    fn tiny_window_rejected() {
        let ds = titled_dataset(&["x"]);
        block(&ds, ATTR_TITLE, 1);
    }

    #[test]
    fn covers_generated_dataset() {
        let g = GeneratorConfig::tiny().generate();
        let b = block(&g.dataset, ATTR_TITLE, 50);
        b.assert_disjoint_cover(g.dataset.len());
        // all runs except possibly the last have exactly window entities
        let hist = b.size_histogram();
        assert!(hist[0] == 50);
        assert!(hist[hist.len() - 1] <= 50);
    }
}
